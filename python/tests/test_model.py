"""L2 correctness: the MELISO pipeline's device-physics invariants.

These tests pin the *model semantics* that the rust NativeEngine mirrors
bit-for-bit; any change here must be reflected in rust/src/device and
rust/src/crossbar (and vice versa) — the integration test
rust/tests/integration_xla.rs cross-checks the two.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

B, R, C = 8, 32, 32


def ideal_params(states=2048.0, mw=1e6, nu_p=0.0, nu_d=0.0, sig=0.0,
                 k_c2c=2.0, k_base=3.3, s_exp=1.5):
    return jnp.array([states, mw, nu_p, nu_d, sig, k_c2c, k_base, s_exp],
                     dtype=jnp.float32)


def inputs(seed=0, b=B):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.uniform(k[0], (b, R, C), jnp.float32, -1.0, 1.0)
    x = jax.random.uniform(k[1], (b, R), jnp.float32, -1.0, 1.0)
    z = jax.random.normal(k[2], (b, model.NOISE_CHANNELS, R, C), jnp.float32)
    return w, x, z


class TestPulseCurve:
    def test_linear_limit(self):
        t = jnp.linspace(0, 1, 11)
        np.testing.assert_allclose(model.pulse_curve(t, 0.0), t, atol=1e-6)

    def test_endpoints_pinned(self):
        # g(0) = 0, g(1) = 1 regardless of nu: the programmed range
        # always spans the full window.
        for nu in [-5.0, -1.0, 1e-7, 0.5, 2.4, 5.0]:
            np.testing.assert_allclose(model.pulse_curve(jnp.float32(0.0), nu), 0.0, atol=1e-6)
            np.testing.assert_allclose(model.pulse_curve(jnp.float32(1.0), nu), 1.0, rtol=1e-5)

    def test_concave_for_positive_nu(self):
        t = jnp.linspace(0, 1, 21)
        g = model.pulse_curve(t, 2.4)
        assert np.all(np.asarray(g[1:-1]) > np.asarray(t[1:-1]))

    def test_convex_for_negative_nu(self):
        t = jnp.linspace(0, 1, 21)
        g = model.pulse_curve(t, -4.88)
        assert np.all(np.asarray(g[1:-1]) < np.asarray(t[1:-1]))

    def test_monotone(self):
        t = jnp.linspace(0, 1, 101)
        for nu in [-4.88, -0.5, 0.0, 2.4, 5.0]:
            g = np.asarray(model.pulse_curve(t, nu))
            assert np.all(np.diff(g) > -1e-7), f"non-monotone at nu={nu}"

    def test_matches_ref(self):
        t = jnp.linspace(0, 1, 33)
        for nu in [-3.0, 0.0, 1.7]:
            np.testing.assert_allclose(
                model.pulse_curve(t, nu), ref.pulse_curve_ref(t, nu), rtol=1e-6
            )


class TestProgramCrossbar:
    def test_output_in_unit_window(self):
        w, _, z = inputs(1)
        p = ideal_params(states=97.0, mw=12.5, nu_p=2.4, nu_d=-4.88, sig=0.05)
        gp, gn = model.program_crossbar(w, z, p)
        for g in (gp, gn):
            g = np.asarray(g)
            assert g.min() >= 0.0 and g.max() <= 1.0

    def test_complementary_pair_targets(self):
        # With no noise the pair programs (1+w)/2 and (1-w)/2.
        w, _, z = inputs(2)
        p = ideal_params(states=4097.0, mw=12.5)
        gp, gn = model.program_crossbar(w, jnp.zeros_like(z), p)
        gp, gn, wn = np.asarray(gp), np.asarray(gn), np.asarray(w)
        np.testing.assert_allclose(gp, (1 + wn) / 2, atol=1e-3)
        np.testing.assert_allclose(gn, (1 - wn) / 2, atol=1e-3)
        np.testing.assert_allclose(gp + gn, 1.0, atol=2e-3)

    def test_ideal_programming_roundtrip(self):
        # Huge S, no NL, no noise: gp - gn == w to quantization precision.
        w, _, z = inputs(3)
        p = ideal_params(states=65536.0)
        gp, gn = model.program_crossbar(w, jnp.zeros_like(z), p)
        np.testing.assert_allclose(np.asarray(gp - gn), np.asarray(w), atol=1e-4)

    def test_quantization_grid(self):
        # With S states and no non-idealities the programmed levels sit
        # exactly on the S-point grid.
        s = 9.0
        w, _, z = inputs(4)
        p = ideal_params(states=s)
        gp, _ = model.program_crossbar(w, jnp.zeros_like(z), p)
        lev = np.asarray(gp) * (s - 1.0)
        np.testing.assert_allclose(lev, np.round(lev), atol=1e-4)

    def test_nonlinearity_biases_midrange(self):
        w = jnp.full((1, R, C), 0.5)
        z = jnp.zeros((1, model.NOISE_CHANNELS, R, C))
        p0 = ideal_params(states=97.0)
        p1 = ideal_params(states=97.0, nu_p=2.4)
        g0, _ = model.program_crossbar(w, z, p0)
        g1, _ = model.program_crossbar(w, z, p1)
        # Concave LTP overshoots the midrange target.
        assert np.all(np.asarray(g1) > np.asarray(g0))


class TestForward:
    def test_ideal_device_matches_software(self):
        w, x, z = inputs(5)
        p = ideal_params()
        y_hw, y_sw = model.meliso_forward(w, x, jnp.zeros_like(z), p)
        np.testing.assert_allclose(np.asarray(y_hw), np.asarray(y_sw), atol=5e-3)

    def test_software_output_is_exact_dot(self):
        w, x, z = inputs(6)
        _, y_sw = model.meliso_forward(w, x, z, ideal_params(states=4.0, mw=2.0))
        want = jnp.einsum("bi,bij->bj", x, w)
        np.testing.assert_allclose(np.asarray(y_sw), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_pallas_path_matches_ref_path(self):
        w, x, z = inputs(7)
        p = ideal_params(states=97.0, mw=12.5, nu_p=2.4, nu_d=-4.88, sig=0.035)
        a = model.meliso_forward(w, x, z, p)
        b = model.meliso_forward_ref(w, x, z, p)
        for got, want in zip(a, b):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_error_grows_with_fewer_states(self):
        # Fig. 2a shape: error variance decreases monotonically (in the
        # statistical sense) with weight bits.
        w, x, z = inputs(8, b=64)
        var = []
        for s in [2.0, 16.0, 256.0]:
            p = ideal_params(states=s, mw=100.0)
            y_hw, y_sw = model.meliso_forward(w, x, jnp.zeros_like(z), p)
            var.append(float(jnp.var(y_hw - y_sw)))
        assert var[0] > var[1] > var[2]

    def test_error_grows_with_smaller_window(self):
        # Fig. 2b shape.
        w, x, z = inputs(9, b=64)
        var = []
        for mw in [4.43, 12.5, 100.0]:
            p = ideal_params(states=97.0, mw=mw)
            y_hw, y_sw = model.meliso_forward(w, x, z, p)
            var.append(float(jnp.var(y_hw - y_sw)))
        assert var[0] > var[1] > var[2]

    def test_error_grows_with_nonlinearity(self):
        # Fig. 3 shape.
        w, x, z = inputs(10, b=64)
        var = []
        for nu in [0.0, 2.0, 5.0]:
            p = ideal_params(states=97.0, mw=100.0, nu_p=nu, nu_d=-nu)
            y_hw, y_sw = model.meliso_forward(w, x, jnp.zeros_like(z), p)
            var.append(float(jnp.var(y_hw - y_sw)))
        assert var[0] < var[1] < var[2]

    def test_error_grows_with_c2c(self):
        # Fig. 4 shape.
        w, x, z = inputs(11, b=64)
        var = []
        for sig in [0.0, 0.02, 0.05]:
            p = ideal_params(states=97.0, mw=100.0, sig=sig)
            y_hw, y_sw = model.meliso_forward(w, x, z, p)
            var.append(float(jnp.var(y_hw - y_sw)))
        assert var[0] < var[1] < var[2]


class TestMismatchTransform:
    def test_zero_mean(self):
        z = jax.random.normal(jax.random.PRNGKey(0), (200_000,))
        m = model.mismatch_transform(z)
        assert abs(float(jnp.mean(m))) < 0.01

    def test_heavy_tails_and_positive_skew(self):
        z = jax.random.normal(jax.random.PRNGKey(1), (200_000,))
        m = np.asarray(model.mismatch_transform(z))
        mu, sd = m.mean(), m.std()
        skew = float(((m - mu) ** 3).mean() / sd**3)
        kurt = float(((m - mu) ** 4).mean() / sd**4 - 3.0)
        assert skew > 0.1
        assert kurt > 0.5

    def test_matches_ref(self):
        z = jnp.linspace(-4, 4, 101)
        np.testing.assert_allclose(
            model.mismatch_transform(z), ref.mismatch_transform_ref(z), rtol=1e-6
        )


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    states=st.sampled_from([2.0, 40.0, 97.0, 128.0, 2048.0]),
    mw=st.sampled_from([4.43, 10.0, 12.5, 50.2, 100.0]),
    nu_p=st.floats(-5, 5),
    nu_d=st.floats(-5, 5),
    sig=st.floats(0, 0.05),
)
def test_forward_finite_and_bounded_hypothesis(seed, states, mw, nu_p, nu_d, sig):
    """For any Table-I-like parameter combination the pipeline stays
    finite and the hardware output is bounded by the physical row sum."""
    w, x, z = inputs(seed, b=4)
    p = ideal_params(states=states, mw=mw, nu_p=nu_p, nu_d=nu_d, sig=sig)
    y_hw, y_sw = model.meliso_forward(w, x, z, p)
    y_hw = np.asarray(y_hw)
    assert np.all(np.isfinite(y_hw))
    # |y_ideal| <= R; the mismatch residue is bounded by
    # m * sum_i |x_i mm_i| with m = k_base/(mw-1) * capped resolution.
    m = 3.3 / (mw - 1.0) * min((model.S_REF / states) ** 1.5, model.MISMATCH_RES_CAP)
    bound = R * (1.0 + m * 60.0)
    assert np.all(np.abs(y_hw) < bound)
