"""L1 correctness: Pallas crossbar kernel vs the pure-jnp oracle.

This is the core correctness signal for the kernel that every artifact
embeds.  Hypothesis sweeps shapes and value ranges; fixed cases pin the
paper's 32x32 geometry.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.crossbar import crossbar_vmm
from compile.kernels.ref import crossbar_vmm_ref


def rand(key, *shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def check(b, r, c, seed=0, block_batch=8):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    gp = rand(k[0], b, r, c, lo=0.0, hi=1.0)
    gn = rand(k[1], b, r, c, lo=0.0, hi=1.0)
    v = rand(k[2], b, r)
    got = crossbar_vmm(gp, gn, v, block_batch=block_batch)
    want = crossbar_vmm_ref(gp, gn, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


class TestFixedGeometry:
    def test_paper_geometry_32x32(self):
        check(b=64, r=32, c=32)

    def test_batch_one(self):
        check(b=1, r=32, c=32)

    def test_batch_not_multiple_of_block(self):
        # 10 % 8 != 0 -> kernel must fall back to an exact tile.
        check(b=10, r=32, c=32)

    def test_large_batch(self):
        check(b=256, r=32, c=32)

    def test_rect_wide(self):
        check(b=4, r=16, c=48)

    def test_rect_tall(self):
        check(b=4, r=48, c=16)

    def test_block_batch_one(self):
        check(b=5, r=8, c=8, block_batch=1)

    def test_block_batch_equals_batch(self):
        check(b=8, r=8, c=8, block_batch=8)

    def test_zero_voltage_gives_zero_current(self):
        gp = jnp.ones((4, 32, 32))
        gn = jnp.zeros((4, 32, 32))
        v = jnp.zeros((4, 32))
        out = crossbar_vmm(gp, gn, v)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_equal_pair_cancels(self):
        # Gp == Gn -> differential current is exactly zero.
        k = jax.random.PRNGKey(7)
        g = rand(k, 4, 32, 32, lo=0.0, hi=1.0)
        v = rand(jax.random.PRNGKey(8), 4, 32)
        out = crossbar_vmm(g, g, v)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

    def test_identity_conductance_passes_voltage(self):
        # Gp - Gn == I (identity) -> output equals input voltages.
        eye = jnp.broadcast_to(jnp.eye(32), (4, 32, 32))
        v = rand(jax.random.PRNGKey(9), 4, 32)
        out = crossbar_vmm(eye, jnp.zeros((4, 32, 32)), v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(v), rtol=1e-6, atol=1e-6)

    def test_linearity_in_voltage(self):
        k = jax.random.split(jax.random.PRNGKey(10), 4)
        gp = rand(k[0], 2, 16, 16, lo=0.0, hi=1.0)
        gn = rand(k[1], 2, 16, 16, lo=0.0, hi=1.0)
        v1 = rand(k[2], 2, 16)
        v2 = rand(k[3], 2, 16)
        lhs = crossbar_vmm(gp, gn, v1 + v2)
        rhs = crossbar_vmm(gp, gn, v1) + crossbar_vmm(gp, gn, v2)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5, atol=1e-5)

    def test_shape_validation(self):
        gp = jnp.zeros((2, 4, 4))
        with pytest.raises(ValueError):
            crossbar_vmm(gp, jnp.zeros((2, 4, 5)), jnp.zeros((2, 4)))
        with pytest.raises(ValueError):
            crossbar_vmm(gp, gp, jnp.zeros((2, 5)))


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 33),
    r=st.sampled_from([1, 2, 8, 17, 32]),
    c=st.sampled_from([1, 3, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_hypothesis(b, r, c, seed):
    check(b, r, c, seed=seed)


@settings(max_examples=20, deadline=None)
@given(
    block=st.integers(1, 16),
    b=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_kernel_block_size_invariance(block, b, seed):
    """The batch tile size is a perf knob and must not change results."""
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    gp = rand(k[0], b, 8, 8, lo=0.0, hi=1.0)
    gn = rand(k[1], b, 8, 8, lo=0.0, hi=1.0)
    v = rand(k[2], b, 8)
    a = crossbar_vmm(gp, gn, v, block_batch=block)
    ref = crossbar_vmm_ref(gp, gn, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), rtol=1e-5, atol=1e-5)
