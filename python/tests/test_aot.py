"""AOT path smoke tests: every artifact lowers, is non-trivial HLO text,
and the manifest agrees with the emitted files."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, batches=(4,), verbose=False)
    return out, manifest


def test_manifest_schema(built):
    out, manifest = built
    assert manifest["schema"] == aot.SCHEMA_VERSION
    assert manifest["rows"] == 32 and manifest["cols"] == 32
    assert manifest["num_params"] == model.NUM_PARAMS
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {"meliso_fwd", "meliso_vmm", "meliso_program"}


def test_files_exist_and_are_hlo_text(built):
    out, manifest = built
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["file"]
        text = open(path).read()
        assert "HloModule" in text
        assert len(text) > 500


def test_manifest_roundtrips_as_json(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    for a in m["artifacts"]:
        assert {"name", "batch", "file", "inputs", "outputs"} <= set(a)


def test_no_mosaic_custom_calls(built):
    """interpret=True must have lowered the Pallas kernel to plain HLO —
    a Mosaic custom-call would be unloadable by the CPU PJRT client."""
    out, manifest = built
    for a in manifest["artifacts"]:
        text = open(os.path.join(out, a["file"])).read()
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()


def test_fwd_artifact_semantics_via_jit(built):
    """The function that was lowered computes what the model computes."""
    fn, args, _ = aot.entry_fwd(4)
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.uniform(k[0], (4, 32, 32), jnp.float32, -1, 1)
    x = jax.random.uniform(k[1], (4, 32), jnp.float32, -1, 1)
    z = jax.random.normal(k[2], (4, model.NOISE_CHANNELS, 32, 32), jnp.float32)
    params = jnp.array([97.0, 12.5, 2.4, -4.88, 0.035, 4.0, 4.5, 1.5], jnp.float32)
    got = jax.jit(fn)(w, x, z, params)
    want = model.meliso_forward(w, x, z, params)
    for g, wnt in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(wnt), rtol=1e-5, atol=1e-5)
