"""Pure-jnp oracles for the L1 kernel and the L2 MELISO pipeline.

Everything here is the slow, obviously-correct formulation used by the
pytest suite as the ground truth for the Pallas kernel and the fused
model.  Nothing in this file is ever lowered to an artifact.
"""

from __future__ import annotations

import jax.numpy as jnp


def crossbar_vmm_ref(gp, gn, v):
    """Reference differential crossbar read: einsum formulation."""
    return jnp.einsum("bi,bij->bj", v, gp - gn)


def pulse_curve_ref(t, nu, eps=1e-6):
    """Reference LTP/LTD conductance curve g(t) on normalized pulses.

    ``g(t) = (1 - exp(-nu t)) / (1 - exp(-nu))`` with the linear limit at
    ``nu -> 0``.  Concave for ``nu > 0`` (fast early LTP), convex for
    ``nu < 0`` (slow-start LTD-programmed device).
    """
    t = jnp.asarray(t)
    nu = jnp.asarray(nu, dtype=t.dtype)
    safe_nu = jnp.where(jnp.abs(nu) < eps, 1.0, nu)
    num = 1.0 - jnp.exp(-safe_nu * t)
    den = 1.0 - jnp.exp(-safe_nu)
    return jnp.where(jnp.abs(nu) < eps, t, num / den)


def quantize_ref(w, states):
    """Reference magnitude quantization to ``states - 1`` pulse steps."""
    n = states - 1.0
    s_pos = jnp.round(jnp.maximum(w, 0.0) * n)
    s_neg = jnp.round(jnp.maximum(-w, 0.0) * n)
    return s_pos, s_neg


def mismatch_transform_ref(z, a=0.7, b=0.15):
    """Reference heavy-tailed, skewed mismatch noise transform.

    ``sinh(a z)/a`` fattens the tails (excess kurtosis) and
    ``b (z^2 - 1)`` adds positive skew with zero mean — the empirical
    shape of the paper's ideal-case error tails (Table II kurtosis).
    """
    return jnp.sinh(a * z) / a + b * (z * z - 1.0)
