"""L1: Pallas kernel for the batched differential RRAM crossbar read.

The analog crossbar computes, for every batch element ``b`` and bit line
``j``::

    I[b, j] = sum_i V[b, i] * (Gp[b, i, j] - Gn[b, i, j])

i.e. Kirchhoff current summation over the word lines of a differential
conductance pair ``(Gp, Gn)`` driven by read voltages ``V``.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
"hardware" is an analog 32x32 crossbar; on a TPU the natural mapping is a
batch of MXU-shaped 32x32 contractions.  The kernel tiles the batch
dimension with a BlockSpec so each grid step keeps ``2*TB*R*C + TB*R``
floats resident in VMEM and issues a single ``dot_general`` with a batch
dimension — the MXU-friendly formulation (bf16/f32 matmul), not a
thread-block/warp port.

``interpret=True`` is mandatory on this testbed: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and the interpret lowering produces
plain HLO that the rust runtime loads unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch tile.  2 * 32 * 32 * 32 * 4 B + 32 * 32 * 4 B ~= 260 KiB of
# VMEM per grid step — far under the ~16 MiB budget, leaving headroom for
# double buffering of the next tile.
DEFAULT_BLOCK_BATCH = 32


def _crossbar_kernel(gp_ref, gn_ref, v_ref, out_ref):
    """One grid step: TB batched 32x32 crossbar reads.

    ``dot_general`` with a leading batch dimension contracts the word-line
    axis of ``v`` against the word-line axis of the differential
    conductance tile in a single MXU-shaped op.
    """
    g = gp_ref[...] - gn_ref[...]  # (TB, R, C) differential conductance
    v = v_ref[...]  # (TB, R) read voltages
    # (TB, R) x (TB, R, C) -> (TB, C): batch dim 0, contract dim 1 vs 1.
    out_ref[...] = jax.lax.dot_general(
        v,
        g,
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_batch", "interpret"))
def crossbar_vmm(
    gp: jax.Array,
    gn: jax.Array,
    v: jax.Array,
    *,
    block_batch: int = DEFAULT_BLOCK_BATCH,
    interpret: bool = True,
) -> jax.Array:
    """Batched differential crossbar VMM.

    Args:
      gp: positive-device conductances, shape ``(B, R, C)``.
      gn: negative-device conductances, shape ``(B, R, C)``.
      v: read voltages, shape ``(B, R)``.
      block_batch: batch tile size per grid step (VMEM sizing knob).
      interpret: run the Pallas interpreter (required on CPU PJRT).

    Returns:
      Bit-line currents, shape ``(B, C)``.
    """
    b, r, c = gp.shape
    if gn.shape != (b, r, c):
        raise ValueError(f"gn shape {gn.shape} != gp shape {gp.shape}")
    if v.shape != (b, r):
        raise ValueError(f"v shape {v.shape} != ({b}, {r})")

    tb = min(block_batch, b)
    if b % tb != 0:
        # Fall back to a tile size that divides the batch so the grid is
        # exact; correctness over peak utilization for ragged batches.
        tb = next(t for t in range(tb, 0, -1) if b % t == 0)
    grid = (b // tb,)

    return pl.pallas_call(
        _crossbar_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, r, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, r, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(gp, gn, v)
