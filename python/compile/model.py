"""L2: the MELISO forward + backward computation graph in JAX.

This is the device-physics half of the benchmarking pipeline — the part
the paper runs inside MLP+NeuroSim.  It is written once in JAX (calling
the L1 Pallas crossbar kernel for the analog read), lowered once to HLO
text by :mod:`compile.aot`, and executed forever after from the rust
coordinator through PJRT.  Python is never on the request path.

Pipeline (forward step):

  1. *Quantize*: target weight ``w in [-1, 1]`` -> complementary pulse
     counts ``(s_pos, s_neg)`` targeting ``(1+w)/2`` and ``(1-w)/2`` on
     an ``S``-state device (the NeuroSim-style differential pair: both
     devices are actively programmed, so both accumulate C2C noise and
     the pair reproduces ``w`` as ``g_pos - g_neg``).
  2. *Program* (open loop, write-verify off): achieved normalized
     conductance follows the exponential LTP/LTD pulse curve with
     non-linearity ``nu`` instead of the linear target, plus accumulated
     cycle-to-cycle (C2C) noise per pulse, clipped to the physical
     ``[Gmin, Gmax]`` window.
  3. *Read* (L1 kernel): bit-line currents
     ``I[b,j] = sum_i V[b,i] (Gp - Gn)[b,i,j]`` plus a memory-window
     limited baseline-mismatch current (the imperfect ``Gmin``
     cancellation of the differential pair).
  4. *Decode* (backward step): currents are scaled by
     ``1 / (V_read (Gmax - Gmin))`` back into weight units.

All device parameters are **runtime scalars** packed into an 8-vector so
one artifact serves every sweep in the paper; all randomness enters as
explicit standard-normal tensors sampled by the rust coordinator.

Parameter vector layout (keep in sync with rust `device::DeviceParams`):

  params[0] = S        number of conductance states (Table I "CS")
  params[1] = MW       memory window Gmax/Gmin
  params[2] = nu_p     LTP weight-update non-linearity (positive device)
  params[3] = nu_d     LTD weight-update non-linearity (negative device)
  params[4] = sigma_c2c  cycle-to-cycle sigma (fraction of range / pulse)
  params[5] = k_c2c    calibration: accumulated-C2C scale
  params[6] = k_base   calibration: baseline-mismatch scale
  params[7] = s_exp    calibration: state-resolution exponent

Noise tensor layout ``z (B, 3, R, C)``:

  z[:, 0]  C2C programming noise, positive device
  z[:, 1]  C2C programming noise, negative device
  z[:, 2]  baseline-mismatch (device-to-device) noise
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.crossbar import crossbar_vmm

# Shape constants of the mismatch-noise transform (DESIGN.md §4); these
# set the tail weight / skew of the ideal-case error distribution and are
# compile-time constants, not device parameters.
MISMATCH_SINH_A = 0.7
MISMATCH_SKEW_B = 0.15

# Reference state count at which the state-resolution factor is 1, and
# the cap on that factor: the power law is calibrated on the Table I
# range (40-128 states); below ~16 states plain quantization dominates
# the error budget and the mismatch floor saturates.
S_REF = 64.0
MISMATCH_RES_CAP = 8.0

NUM_PARAMS = 8
NOISE_CHANNELS = 3

# Cycle-severity spread (lognormal sigma): each array is programmed in
# its own cycle, and cycle conditions modulate the C2C disturbance of
# that whole array.  Being shared across an array's cells, severity
# survives the CLT of the 32-term column sums — it is what gives the
# error populations their heavy tails and skew (Table II).
SEVERITY_SIGMA = 0.6

# NL-label -> curve-curvature mapping: NeuroSim maps its non-linearity
# *label* to the exponential curve parameter through a nonlinear lookup
# table; we model that lookup as kappa = sign(NL) (e^{gamma |NL|} - 1),
# which reproduces the paper's "exponential dependency" of error
# variance on the NL metric (Fig. 3) at curvatures that keep mid-range
# conductances off the window rails.
NL_GAMMA = 0.35


def pulse_curve(t, nu, eps=1e-6):
    """Normalized conductance after a fraction ``t`` of the pulse train.

    ``g(t) = (1 - exp(-nu t)) / (1 - exp(-nu))``, linear as ``nu -> 0``.
    Concave (fast early potentiation) for ``nu > 0``, convex for
    ``nu < 0``.  Open-loop programming targets the *linear* curve, so the
    deviation ``g(t) - t`` is the non-linearity encoding error.
    """
    safe = jnp.where(jnp.abs(nu) < eps, 1.0, nu)
    num = 1.0 - jnp.exp(-safe * t)
    den = 1.0 - jnp.exp(-safe)
    return jnp.where(jnp.abs(nu) < eps, t, num / den)


def pulse_curve_slope(t, nu, eps=1e-6):
    """dg/dt of the pulse curve: `nu exp(-nu t) / (1 - exp(-nu))`.

    C2C disturbance happens per *pulse*; mapping it through the local
    curve slope means strongly non-linear devices see amplified (and
    state-dependent, hence skewed) conductance noise — the Fig. 4b
    amplification.
    """
    safe = jnp.where(jnp.abs(nu) < eps, 1.0, nu)
    num = safe * jnp.exp(-safe * t)
    den = 1.0 - jnp.exp(-safe)
    return jnp.where(jnp.abs(nu) < eps, jnp.ones_like(t * nu), num / den)


def nl_to_curvature(nu):
    """Map the paper's NL label to the pulse-curve curvature kappa."""
    return jnp.sign(nu) * jnp.expm1(NL_GAMMA * jnp.abs(nu))


def mismatch_transform(z):
    """Heavy-tailed, positively-skewed mismatch noise (zero mean)."""
    a, b = MISMATCH_SINH_A, MISMATCH_SKEW_B
    return jnp.sinh(a * z) / a + b * (z * z - 1.0)


def program_crossbar(w, z, params):
    """Program target weights into differential normalized conductances.

    Args:
      w: target weights ``(B, R, C)`` in ``[-1, 1]``.
      z: standard-normal noise ``(B, NOISE_CHANNELS, R, C)``.
      params: device parameter 8-vector (see module docstring).

    Returns:
      ``(gp_n, gn_n)`` normalized conductances in ``[0, 1]`` (i.e.
      ``(G - Gmin) / (Gmax - Gmin)``), shape ``(B, R, C)`` each.
    """
    s = params[0]
    nu_p, nu_d = params[2], params[3]
    sig_c2c, k_c2c = params[4], params[5]

    n = s - 1.0  # pulse steps
    # Complementary targets: both devices programmed (NeuroSim pair).
    s_pos = jnp.round((1.0 + w) * 0.5 * n)
    s_neg = jnp.round((1.0 - w) * 0.5 * n)
    t_pos = s_pos / n
    t_neg = s_neg / n

    # Per-array cycle severity (see SEVERITY_SIGMA): one lognormal draw
    # per sample, derived from the z0 plane's standardized mean so it
    # needs no extra input tensor.
    cells = w.shape[1] * w.shape[2]
    zeta = jnp.mean(z[:, 0], axis=(1, 2)) * jnp.sqrt(jnp.float32(cells))
    sev = jnp.exp(
        SEVERITY_SIGMA * zeta - 0.5 * SEVERITY_SIGMA * SEVERITY_SIGMA
    )[:, None, None]

    # Open-loop NL deviation (write-verify off): the achieved curve
    # follows the device curvature instead of the linear target.
    kappa_p = nl_to_curvature(nu_p)
    kappa_d = nl_to_curvature(nu_d)
    g_pos = pulse_curve(t_pos, kappa_p)
    g_neg = pulse_curve(t_neg, kappa_d)

    # C2C: each pulse perturbs dG; after s pulses the accumulated walk
    # scales with sqrt(s) (closed form — no pulse loop in the artifact).
    # k_c2c is the single fitted scale (DESIGN.md §7), chosen so the
    # worst Table I device stays below the window-saturation knee —
    # beyond it the clip makes error variance non-monotone in sigma,
    # which contradicts Fig. 4.  Pulse-domain noise maps through the
    # local curve slope and the cycle severity.
    acc = sig_c2c * k_c2c
    g_pos = g_pos + sev * acc * jnp.sqrt(s_pos) * z[:, 0]
    g_neg = g_neg + sev * acc * jnp.sqrt(s_neg) * z[:, 1]

    # Physical window: conductance saturates at Gmin / Gmax.  This clip
    # is what tames large-C2C configurations (the AlOx/HfO2 anomaly in
    # Fig. 5 / Table II).
    g_pos = jnp.clip(g_pos, 0.0, 1.0)
    g_neg = jnp.clip(g_neg, 0.0, 1.0)
    return g_pos, g_neg


def baseline_mismatch_current(x, z_mm, params):
    """Imperfect Gmin cancellation of the differential pair.

    The differential read ideally cancels the ``Gmin`` baseline exactly;
    real arrays leave a residue proportional to the baseline-to-range
    ratio ``r = Gmin / (Gmax - Gmin) = 1 / (MW - 1)`` — the memory-window
    error floor of Fig. 2b — and inversely to the per-state resolution
    ``(S_REF / S) ** s_exp`` — the weight-bit floor of Fig. 2a beyond
    plain quantization.  The noise is heavy-tailed/skewed (Table II
    ideal-case kurtosis).
    """
    s, mw = params[0], params[1]
    k_base, s_exp = params[6], params[7]
    r = 1.0 / (mw - 1.0)
    res = jnp.minimum(jnp.power(S_REF / s, s_exp), MISMATCH_RES_CAP)
    m = k_base * r * res
    mm = mismatch_transform(z_mm)  # (B, R, C)
    # Residue current in decoded units: sum_i x_i * m * mm_ij.
    return jnp.einsum("bi,bij->bj", x, m * mm)


def meliso_forward(w, x, z, params, *, block_batch=8, interpret=True):
    """End-to-end MELISO forward + backward step.

    Args:
      w: target matrices ``(B, R, C)`` in ``[-1, 1]`` (the paper's ``A``,
         transposed into row-major word lines).
      x: input vectors ``(B, R)`` in ``[-1, 1]`` (read voltages, V_read
         normalized to 1).
      z: standard-normal noise ``(B, 3, R, C)``.
      params: device parameter 8-vector.

    Returns:
      ``(y_hw, y_sw)``: the decoded hardware result and the exact
      software dot product, both ``(B, C)``.  The benchmark error
      population is ``y_hw - y_sw``.
    """
    gp, gn = program_crossbar(w, z, params)
    # L1 Pallas kernel: analog crossbar read on normalized conductances.
    # (G = Gmin + range * g_n, and the differential read cancels Gmin, so
    # currents in decoded units are exactly the normalized contraction.)
    y_ideal = crossbar_vmm(gp, gn, x, block_batch=block_batch, interpret=interpret)
    y_hw = y_ideal + baseline_mismatch_current(x, z[:, 2], params)
    y_sw = jnp.einsum("bi,bij->bj", x, w)
    return y_hw, y_sw


def meliso_forward_ref(w, x, z, params):
    """Same pipeline with the einsum reference read (no Pallas)."""
    gp, gn = program_crossbar(w, z, params)
    y_ideal = jnp.einsum("bi,bij->bj", x, gp - gn)
    y_hw = y_ideal + baseline_mismatch_current(x, z[:, 2], params)
    y_sw = jnp.einsum("bi,bij->bj", x, w)
    return y_hw, y_sw
