"""AOT compile path: lower the L2 graph to HLO text + manifest.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts

Emits one HLO-text artifact per (program, batch) pair plus a
``manifest.json`` the rust runtime uses to discover shapes and inputs.

HLO **text** (not ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.crossbar import crossbar_vmm

ROWS = 32
COLS = 32
# Primary batch is the coordinator's chunk size; the small batch serves
# remainder chunks and latency-sensitive callers (solver iterations).
BATCHES = (256, 32, 1)

SCHEMA_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_fwd(batch: int):
    """meliso_fwd: (w, x, z, params) -> (y_hw, y_sw)."""

    def fn(w, x, z, params):
        return model.meliso_forward(w, x, z, params)

    args = (
        f32(batch, ROWS, COLS),
        f32(batch, ROWS),
        f32(batch, model.NOISE_CHANNELS, ROWS, COLS),
        f32(model.NUM_PARAMS),
    )
    return fn, args, {
        "inputs": [
            {"name": "w", "shape": [batch, ROWS, COLS]},
            {"name": "x", "shape": [batch, ROWS]},
            {"name": "z", "shape": [batch, model.NOISE_CHANNELS, ROWS, COLS]},
            {"name": "params", "shape": [model.NUM_PARAMS]},
        ],
        "outputs": [
            {"name": "y_hw", "shape": [batch, COLS]},
            {"name": "y_sw", "shape": [batch, COLS]},
        ],
    }


def entry_vmm(batch: int):
    """meliso_vmm: raw differential crossbar read (L1 kernel only)."""

    def fn(gp, gn, v):
        return (crossbar_vmm(gp, gn, v),)

    args = (f32(batch, ROWS, COLS), f32(batch, ROWS, COLS), f32(batch, ROWS))
    return fn, args, {
        "inputs": [
            {"name": "gp", "shape": [batch, ROWS, COLS]},
            {"name": "gn", "shape": [batch, ROWS, COLS]},
            {"name": "v", "shape": [batch, ROWS]},
        ],
        "outputs": [{"name": "i", "shape": [batch, COLS]}],
    }


def entry_program(batch: int):
    """meliso_program: weight -> conductance encoding only."""

    def fn(w, z, params):
        return model.program_crossbar(w, z, params)

    args = (
        f32(batch, ROWS, COLS),
        f32(batch, model.NOISE_CHANNELS, ROWS, COLS),
        f32(model.NUM_PARAMS),
    )
    return fn, args, {
        "inputs": [
            {"name": "w", "shape": [batch, ROWS, COLS]},
            {"name": "z", "shape": [batch, model.NOISE_CHANNELS, ROWS, COLS]},
            {"name": "params", "shape": [model.NUM_PARAMS]},
        ],
        "outputs": [
            {"name": "gp", "shape": [batch, ROWS, COLS]},
            {"name": "gn", "shape": [batch, ROWS, COLS]},
        ],
    }


ENTRIES = {
    "meliso_fwd": entry_fwd,
    "meliso_vmm": entry_vmm,
    "meliso_program": entry_program,
}


def build(out_dir: str, batches=BATCHES, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "schema": SCHEMA_VERSION,
        "rows": ROWS,
        "cols": COLS,
        "noise_channels": model.NOISE_CHANNELS,
        "num_params": model.NUM_PARAMS,
        "jax_version": jax.__version__,
        "artifacts": [],
    }
    for name, make in ENTRIES.items():
        for batch in batches:
            fn, args, io_spec = make(batch)
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{name}_b{batch}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["artifacts"].append(
                {
                    "name": name,
                    "batch": batch,
                    "file": fname,
                    "sha256_16": digest,
                    **io_spec,
                }
            )
            if verbose:
                print(f"  {fname}: {len(text)} chars sha={digest}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")
    return manifest


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument(
        "--batches",
        default=",".join(str(b) for b in BATCHES),
        help="comma-separated batch sizes",
    )
    ns = p.parse_args(argv)
    batches = tuple(int(b) for b in ns.batches.split(","))
    build(ns.out, batches=batches)


if __name__ == "__main__":
    main()
