//! Integration: the unified telemetry subsystem's *exact* accounting
//! contracts, isolated in their own test binary.
//!
//! The observability gate and registry are process-wide.  Inside the
//! library's unit-test binary, unrelated tests traverse instrumented
//! paths in parallel, so gate-enabling tests there can only assert
//! lower bounds.  This binary holds the strict versions: every test
//! takes [`meliso::obs::test_lock`], so exactly one test touches the
//! registry at a time and nothing else records — the deltas below are
//! exact.
//!
//! * helper-level accounting is exact (counters, gauges, stages);
//! * a known concurrent serving workload (4 workers — the
//!   `MELISO_THREADS=4` matrix width) never under- or over-counts:
//!   the deliberately-`Relaxed` counter contract of DESIGN.md §17;
//! * the enabled path costs the `serve-cached-128` hot loop < 10%;
//! * per-stage sums account for measured end-to-end serve latency to
//!   within 5% (no double-counting, no unattributed gap).

use std::time::Duration;

use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::obs::{self, CounterId, GaugeId, MetricsSnapshot, Stage};
use meliso::serve::{run_serve, ServeOptions};
use meliso::util::bench::{bench, black_box, BenchOpts};
use meliso::util::rng::Xoshiro256;
use meliso::vmm::{DynEngine, NativeEngine, ProgramSpec, VmmEngine};

#[test]
fn exact_registry_accounting_in_isolation() {
    let _guard = obs::test_lock();
    obs::registry().reset();
    obs::set_enabled(true);
    obs::incr(CounterId::RequestsServed);
    obs::add(CounterId::BytesIn, 64);
    obs::gauge_set(GaugeId::CacheEntries, 2);
    obs::record_ns(Stage::QueueWait, 4_096);
    let got = obs::time_stage(Stage::Read, || 7u32);
    assert_eq!(got, 7);
    obs::set_enabled(false);
    let s = obs::registry().snapshot();
    obs::registry().reset();
    assert_eq!(s.counter(CounterId::RequestsServed), 1);
    assert_eq!(s.counter(CounterId::BytesIn), 64);
    assert_eq!(s.gauge(GaugeId::CacheEntries), 2);
    assert_eq!(s.stage(Stage::QueueWait).count, 1);
    assert_eq!(s.stage(Stage::QueueWait).sum, 4_096);
    assert_eq!(s.stage(Stage::Read).count, 1);
    // Everything not recorded stays zero.
    assert_eq!(s.counter(CounterId::FaultsInjected), 0);
    assert_eq!(s.stage(Stage::TransportEncode).count, 0);
    assert_eq!(obs::registry().snapshot(), MetricsSnapshot::empty());
}

#[test]
fn concurrent_serve_counters_never_under_count() {
    // The deliberate-Relaxed ordering contract on migrated counters:
    // 4 scheduler workers (the MELISO_THREADS matrix width) increment
    // concurrently, and a known workload's registry deltas agree
    // exactly with the report assembled from per-instance counters
    // after thread join.
    let _guard = obs::test_lock();
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());
    let opts = ServeOptions {
        clients: 4,
        requests_per_client: 12,
        models: 3,
        rows: 24,
        cols: 24,
        queue_capacity: 16,
        batch_max: 6,
        window: Duration::from_micros(150),
        workers: 4,
        cache: true,
        cache_capacity: 8,
        measure_error: true,
        ..ServeOptions::default()
    };
    obs::registry().reset();
    obs::set_enabled(true);
    let report = run_serve(&engine, &device, &opts).unwrap();
    obs::set_enabled(false);
    let snap = obs::registry().snapshot();
    obs::registry().reset();

    assert_eq!(report.requests, 48);
    assert_eq!(snap.counter(CounterId::RequestsServed), 48);
    assert_eq!(snap.counter(CounterId::BatchesServed), report.batches as u64);
    assert_eq!(snap.counter(CounterId::CacheHits), report.cache.hits);
    assert_eq!(snap.counter(CounterId::CacheMisses), report.cache.misses);
    assert_eq!(snap.counter(CounterId::ProgramsExecuted), report.programs);
    assert_eq!(snap.counter(CounterId::RequestsShed), 0);
    // One queue-wait span per request; at least one hardware read per
    // batch (one per model group).
    assert_eq!(snap.stage(Stage::QueueWait).count, 48);
    assert!(snap.counter(CounterId::ReadsExecuted) >= report.batches as u64);
    assert_eq!(report.latency.count, 48);
}

/// The suite's serve-cached-128 workload, built directly.
fn cached_read_workload() -> (meliso::vmm::ProgrammedVmm, Vec<f32>, usize) {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let (rows, cols) = (128usize, 128);
    let nreq = 8usize;
    let mut rng = Xoshiro256::seed_from_u64(0x53455256); // "SERV"
    let mut w = vec![0.0f32; rows * cols];
    rng.fill_uniform_f32(&mut w, -1.0, 1.0);
    let spec = ProgramSpec::from_seed(rows, cols, w, 0x50524F47); // "PROG"
    let mut x = vec![0.0f32; nreq * rows];
    rng.fill_uniform_f32(&mut x, 0.0, 1.0);
    let programmed = NativeEngine::default().program(&spec, &device).unwrap();
    (programmed, x, nreq)
}

#[test]
fn obs_enabled_overhead_stays_under_budget() {
    // The enabled-path overhead contract (DESIGN.md §17): turning the
    // registry on costs the serve-cached-128 hot path less than 10%.
    // Compared on the *minimum* of nine samples — the same
    // contention-robust estimator as the perf suite's amortization
    // test (a descheduled quantum inflates individual samples of short
    // legs; the min approaches the true cost on both sides).
    let _guard = obs::test_lock();
    let (programmed, x, nreq) = cached_read_workload();
    let bopts = BenchOpts { samples: 9, warmup: 2, items_per_iter: None };
    obs::set_enabled(false);
    let off = bench("serve-cached-128 obs-off", bopts, || {
        black_box(programmed.read(&x, nreq).unwrap());
    });
    obs::registry().reset();
    obs::set_enabled(true);
    let on = bench("serve-cached-128 obs-on", bopts, || {
        black_box(programmed.read(&x, nreq).unwrap());
    });
    obs::set_enabled(false);
    obs::registry().reset();
    assert!(off.min > 0.0 && on.min > 0.0);
    let ratio = on.min / off.min;
    assert!(
        ratio < 1.10,
        "enabled-path overhead {ratio:.4}x exceeds the 10% budget \
         (off {:.6}s, on {:.6}s)",
        off.min,
        on.min
    );
}

#[test]
fn obs_breakdown_sums_to_end_to_end_latency() {
    // Accounting invariant (DESIGN.md §17): with one request per batch
    // and no coalescing window, the per-stage sums (queue-wait +
    // coalesce + cache lookup + program + read) account for the
    // measured end-to-end latency to within 5% — the stage taxonomy
    // covers the serving lifecycle exactly once.  run_serve has no
    // transport hop and no sharded engine here, so every other stage
    // stays empty.
    let _guard = obs::test_lock();
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());
    let opts = ServeOptions {
        clients: 2,
        requests_per_client: 16,
        models: 1,
        rows: 128,
        cols: 128,
        queue_capacity: 8,
        batch_max: 1,
        window: Duration::ZERO,
        workers: 1,
        cache: true,
        cache_capacity: 4,
        measure_error: false,
        ..ServeOptions::default()
    };
    obs::registry().reset();
    obs::set_enabled(true);
    let report = run_serve(&engine, &device, &opts).unwrap();
    obs::set_enabled(false);
    let snap = obs::registry().snapshot();
    obs::registry().reset();

    assert_eq!(report.requests, 32);
    for stage in [Stage::TransportEncode, Stage::TransportDecode, Stage::ShardVerify] {
        assert_eq!(snap.stage(stage).count, 0, "{}", stage.name());
    }
    let e2e = report.latency.sum as f64;
    let staged = snap.stage_sum_ns() as f64;
    assert!(e2e > 0.0 && staged > 0.0);
    let gap = (staged - e2e).abs() / e2e;
    assert!(
        gap <= 0.05,
        "stage sums ({staged:.0}ns) vs end-to-end ({e2e:.0}ns): \
         unattributed gap {:.2}%",
        gap * 100.0
    );
}
