//! Integration: the layered inference pipeline — determinism across
//! thread counts, the depth-1 <-> single-forward equivalence, the
//! engine matrix (native / tiled / mitigated), and the `meliso infer`
//! CLI surface with its CSV + JSON artifacts.

use meliso::cli::{dispatch, Args};
use meliso::device::params::DeviceParams;
use meliso::device::presets;
use meliso::mitigation::MitigationConfig;
use meliso::pipeline::{Activation, NetworkSpec, PipelineOptions, PipelineRunner};
use meliso::util::json::Json;
use meliso::util::pool::Parallelism;
use meliso::vmm::{DynEngine, NativeEngine, TiledEngine, VmmEngine};

fn run_with(
    engine: DynEngine,
    net: &NetworkSpec,
    device: &meliso::device::params::DeviceParams,
    threads: Parallelism,
) -> meliso::pipeline::InferenceReport {
    PipelineRunner::new(engine)
        .run(net, device, &PipelineOptions { chunk: 4, parallelism: threads, ..PipelineOptions::default() })
        .unwrap()
}

/// The subsystem's reproducibility contract: the same seed yields a
/// **bit-identical layer trace** for any thread count, on both the
/// plain and the per-layer-mitigated path.
#[test]
fn layer_trace_bit_identical_across_thread_counts() {
    let device = presets::ag_si().params;
    let mut net = NetworkSpec::uniform(4, 16, Activation::Relu, 99).with_population(12);
    // Mix mitigated and unmitigated layers to cover both paths.
    net.layers[1].mitigation = Some(MitigationConfig::parse("diff,avg:2").unwrap());

    let baseline = run_with(
        DynEngine::new(NativeEngine::sequential()),
        &net,
        &device,
        Parallelism::Fixed(1),
    );
    for threads in [2usize, 3, 8] {
        let par = run_with(
            DynEngine::new(NativeEngine::sequential()),
            &net,
            &device,
            Parallelism::Fixed(threads),
        );
        for (a, b) in baseline.layers.iter().zip(&par.layers) {
            assert_eq!(a.injected.errors(), b.injected.errors(), "threads={threads}");
            assert_eq!(
                a.accumulated.errors(),
                b.accumulated.errors(),
                "threads={threads}"
            );
        }
        assert_eq!(baseline.final_hw, par.final_hw, "threads={threads}");
        assert_eq!(baseline.final_sw, par.final_sw, "threads={threads}");
        assert_eq!(baseline.argmax_agreement, par.argmax_agreement);
    }
    // Engine-internal fan-out composes with the chunk pool without
    // changing a bit either.
    let fanned = run_with(
        DynEngine::new(NativeEngine::default()),
        &net,
        &device,
        Parallelism::Auto,
    );
    assert_eq!(baseline.final_hw, fanned.final_hw);
    for (a, b) in baseline.layers.iter().zip(&fanned.layers) {
        assert_eq!(a.accumulated.errors(), b.accumulated.errors());
    }
}

/// A depth-1 pipeline is exactly one engine forward: the injected
/// error population equals `VmmEngine::forward`'s error vector
/// bit-for-bit on the same seed.
#[test]
fn depth_1_pipeline_matches_single_forward() {
    let device = presets::epiram().params;
    let mut net = NetworkSpec::uniform(1, 32, Activation::Identity, 1234).with_population(16);
    net.layers[0].requant = 1.0;

    // The pipeline's own batch for layer 0 over the whole population…
    let inputs = net.input_spec().chunk(0, 16);
    let batch = net.layer_batch(0, 0, 16, &inputs);
    let engine = NativeEngine::default();
    let direct = engine.forward(&batch, &device).unwrap();

    // …and the pipeline run (one chunk, so the same batch shape).
    let report = PipelineRunner::new(DynEngine::new(engine))
        .run(
            &net,
            &device,
            &PipelineOptions { chunk: 16, parallelism: Parallelism::Fixed(1), ..PipelineOptions::default() },
        )
        .unwrap();

    assert_eq!(report.layers.len(), 1);
    assert_eq!(report.layers[0].injected.errors(), direct.errors().as_slice());
    // With identity activation and unit requantization the final
    // hardware activations are the (saturated) raw outputs.
    let clamped: Vec<f32> = direct.y_hw.iter().map(|&v| v.clamp(-1.0, 1.0)).collect();
    assert_eq!(report.final_hw, clamped);
}

/// The engine matrix of the acceptance criterion: native, tiled, and
/// mitigated engines all run a depth-4 seeded network and report
/// finite, engine-consistent traces.
#[test]
fn depth_4_network_runs_on_native_tiled_and_mitigated() {
    let device = presets::epiram().params;
    let net = NetworkSpec::uniform(4, 32, Activation::Relu, 55).with_population(8);
    let mitigated_net = net
        .clone()
        .with_mitigation(MitigationConfig::parse("avg:2").unwrap());

    let engines: [(&str, DynEngine, &NetworkSpec); 3] = [
        ("native", DynEngine::new(NativeEngine::default()), &net),
        ("tiled", DynEngine::new(TiledEngine::default()), &net),
        ("mitigated", DynEngine::new(NativeEngine::default()), &mitigated_net),
    ];
    for (label, engine, n) in engines {
        let r = PipelineRunner::new(engine)
            .run(n, &device, &PipelineOptions::default())
            .unwrap();
        assert_eq!(r.layers.len(), 4, "{label}");
        assert_eq!(r.end_to_end().len(), 8 * 32, "{label}");
        assert!(
            r.end_to_end().errors().iter().all(|e| e.is_finite()),
            "{label}"
        );
        assert!((0.0..=1.0).contains(&r.argmax_agreement), "{label}");
    }

    // Tiled at the native tile size is the same physics: identical
    // trace to the native engine on the same seed.
    let rn = PipelineRunner::new(DynEngine::new(NativeEngine::default()))
        .run(&net, &device, &PipelineOptions::default())
        .unwrap();
    let rt = PipelineRunner::new(DynEngine::new(TiledEngine::default()))
        .run(&net, &device, &PipelineOptions::default())
        .unwrap();
    for (a, b) in rn.layers.iter().zip(&rt.layers) {
        assert_eq!(a.injected.errors(), b.injected.errors());
    }
}

/// Ideal-device sanity: requantization alone (no noise) keeps the two
/// chains glued together through many layers.
#[test]
fn ideal_device_chain_stays_tight_at_depth_8() {
    let net = NetworkSpec::uniform(8, 16, Activation::Tanh, 7).with_population(8);
    let r = PipelineRunner::new(DynEngine::new(NativeEngine::default()))
        .run(&net, &DeviceParams::ideal(), &PipelineOptions::default())
        .unwrap();
    for l in &r.layers {
        assert!(l.accumulated_mean_abs() < 0.05, "layer {}", l.index);
    }
}

/// `meliso infer` end-to-end through the CLI: runs a depth-4 seeded
/// network and emits the per-layer accumulated-error CSV + JSON.
#[test]
fn infer_cli_emits_per_layer_csv_and_json() {
    let dir = std::env::temp_dir().join("meliso_infer_cli_test");
    let _ = std::fs::remove_dir_all(&dir);
    let out = dir.to_string_lossy().to_string();

    for engine_args in [
        vec!["--engine", "native"],
        vec!["--engine", "tiled"],
        vec!["--engine", "native", "--mitigation", "avg:2"],
    ] {
        let mut argv = vec![
            "infer",
            "--device",
            "epiram",
            "--depth",
            "4",
            "--population",
            "6",
            "--out",
            out.as_str(),
            "--quiet",
        ];
        argv.extend(&engine_args);
        let args = Args::parse(argv.iter().map(|s| s.to_string())).unwrap();
        let code = dispatch(&args).unwrap();
        assert_eq!(code, 0, "{engine_args:?}");

        let csv = std::fs::read_to_string(dir.join("infer/layers.csv")).unwrap();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.contains("accum_mean_abs"), "{header}");
        assert_eq!(lines.count(), 4, "one row per layer ({engine_args:?})");

        let json = std::fs::read_to_string(dir.join("infer/summary.json")).unwrap();
        let summary = Json::parse(&json).unwrap();
        assert_eq!(summary.get("id").unwrap().as_str(), Some("infer"));
        assert_eq!(summary.get("network").unwrap().as_str(), Some("32x32x32x32x32"));
        let layers = summary.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 4);
        for l in layers {
            assert!(l.get("accum_mean_abs").unwrap().as_f64().unwrap().is_finite());
        }
        let agree = summary.get("argmax_agreement").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&agree));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The registry path: `meliso run pipeline` exists and the unknown-id
/// failure lists it.
#[test]
fn registry_knows_the_pipeline_experiment() {
    assert!(meliso::experiments::all_ids().contains(&"pipeline"));
    let dir = std::env::temp_dir().join("meliso_pipeline_reg_msg_test");
    let ctx = meliso::experiments::Ctx::native(4, &dir);
    let err = meliso::experiments::run_by_id("nope", &ctx).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("pipeline"), "{msg}");
    assert!(msg.contains("size-sweep"), "{msg}");
    let _ = std::fs::remove_dir_all(dir);
}
