//! Integration: property and determinism coverage for the mitigation
//! pipeline (ISSUE 2).
//!
//! * On a *perfect* device (no noise channels at all) the linear
//!   strategies are exact identities: mitigated output bit-equals the
//!   unmitigated engine.  (Bit-slicing re-quantizes through the digit
//!   grid, so it is checked to a tight tolerance instead.)
//! * `Fixed(1)` and `Auto` thread counts are bit-identical through
//!   `MitigatedEngine` — mitigation preserves PR 1's determinism
//!   contract.
//! * Replica averaging monotonically shrinks the error variance on the
//!   C2C-dominated EpiRAM.

use meliso::device::params::DeviceParams;
use meliso::device::presets;
use meliso::mitigation::{MitigatedEngine, MitigationConfig};
use meliso::stats::moments::Moments;
use meliso::util::pool::Parallelism;
use meliso::util::rng::Xoshiro256;
use meliso::vmm::{NativeEngine, VmmBatch, VmmEngine};

fn random_batch(b: usize, r: usize, c: usize, seed: u64) -> VmmBatch {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut vb = VmmBatch::zeros(b, r, c);
    rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
    rng.fill_uniform_f32(&mut vb.x, 0.0, 1.0);
    rng.fill_normal_f32(&mut vb.z);
    vb
}

/// An ideal device with the vestigial baseline-mismatch scale zeroed:
/// every noise channel is exactly inert, so mitigation must be an
/// exact linear identity.
fn perfect_device() -> DeviceParams {
    DeviceParams {
        k_base: 0.0,
        ..DeviceParams::ideal()
    }
}

fn mitigated(spec: &str) -> MitigatedEngine<NativeEngine> {
    MitigatedEngine::new(
        NativeEngine::default(),
        MitigationConfig::parse(spec).unwrap(),
    )
}

#[test]
fn perfect_device_mitigated_output_bit_equals_unmitigated() {
    let batch = random_batch(9, 32, 32, 901);
    let device = perfect_device();
    let base = NativeEngine::default().forward(&batch, &device).unwrap();
    // Differential pairing, replica averaging, calibration, and their
    // compositions recombine to the exact same bits: the complementary
    // array reads the exact negation, replicas are bit-identical under
    // zero noise, and the calibration fit collapses to gain 1 offset 0.
    for spec in ["diff", "avg:3", "avg:4", "cal", "diff,avg:4", "diff,avg:2,cal"] {
        let out = mitigated(spec).forward(&batch, &device).unwrap();
        assert_eq!(out.y_hw, base.y_hw, "strategy {spec}");
        assert_eq!(out.y_sw, base.y_sw, "strategy {spec}");
    }
    // Bit-slicing re-quantizes through the digit grid; on the
    // 65536-state perfect device both paths are exact to well below
    // one state.
    let sliced = mitigated("slice:2").forward(&batch, &device).unwrap();
    for (a, b) in sliced.y_hw.iter().zip(base.y_hw.iter()) {
        assert!((a - b).abs() < 1e-3, "slice: {a} vs {b}");
    }
}

#[test]
fn fixed1_and_auto_threads_bit_identical_through_mitigation() {
    let batch = random_batch(37, 32, 32, 902);
    let device = presets::epiram().params;
    let cfg = MitigationConfig::parse("diff,slice:2,avg:2,cal").unwrap();
    let seq = MitigatedEngine::new(NativeEngine::sequential(), cfg)
        .forward(&batch, &device)
        .unwrap();
    for par in [Parallelism::Fixed(3), Parallelism::Auto] {
        let out = MitigatedEngine::new(NativeEngine::with_parallelism(par), cfg)
            .forward(&batch, &device)
            .unwrap();
        assert_eq!(seq.y_hw, out.y_hw, "{par:?}");
        assert_eq!(seq.y_sw, out.y_sw, "{par:?}");
    }
}

#[test]
fn replica_averaging_monotonically_shrinks_variance_on_epiram() {
    let batch = random_batch(48, 32, 32, 903);
    let device = presets::epiram().params;
    let var_of = |spec: &str| -> f64 {
        let out = mitigated(spec).forward(&batch, &device).unwrap();
        Moments::from_slice(&out.errors()).variance()
    };
    let v1 = var_of("none");
    let v2 = var_of("avg:2");
    let v4 = var_of("avg:4");
    assert!(v2 < v1, "avg:2 {v2} !< none {v1}");
    assert!(v4 < v2, "avg:4 {v4} !< avg:2 {v2}");
    // ~1/R C2C shrink on a C2C-dominated device: the 4-replica run
    // must cut well over half of the single-cycle variance.
    assert!(v4 < v1 * 0.6, "v1={v1} v4={v4}");
}

#[test]
fn mitigation_is_deterministic_across_calls() {
    let batch = random_batch(8, 32, 32, 904);
    let device = presets::ag_si().params;
    let eng = mitigated("diff,slice:2,avg:2,cal");
    let a = eng.forward(&batch, &device).unwrap();
    let b = eng.forward(&batch, &device).unwrap();
    assert_eq!(a.y_hw, b.y_hw);
}

#[test]
fn mitigated_solver_operator_reaches_lower_cg_floor() {
    use meliso::solver::{conjugate_gradient, CrossbarOperator, ExactOperator, SolveOpts};

    let n = 48;
    let mut rng = Xoshiro256::seed_from_u64(905);
    // SPD system A = M^T M / n + I.
    let m: Vec<f64> = (0..n * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += m[k * n + i] * m[k * n + j];
            }
            a[i * n + j] = s / n as f64 + if i == j { 1.0 } else { 0.0 };
        }
    }
    let b: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let exact = ExactOperator::new(n, n, a.clone());
    let device = presets::epiram().params;
    let opts = SolveOpts { max_iters: 100, tol: 1e-12 };

    let floor_of = |cfg: &MitigationConfig, rng: &mut Xoshiro256| -> f64 {
        let op = CrossbarOperator::program_mitigated(n, n, &a, &device, rng, cfg);
        let r = conjugate_gradient(&op, &exact, &b, &opts).unwrap();
        let mut floor = f64::INFINITY;
        for &res in &r.residual_history {
            floor = floor.min(res);
        }
        floor
    };
    let plain = floor_of(&MitigationConfig::NONE, &mut rng);
    let mit = floor_of(&MitigationConfig::parse("diff,avg:4").unwrap(), &mut rng);
    assert!(mit < plain, "mitigated floor {mit} !< plain floor {plain}");
}
