//! Integration: the request-serving subsystem end-to-end — the serve
//! driver over real engines, the program cache across pipeline runs
//! (`meliso infer --deploy` semantics), and the registry-facing
//! `serve-sweep` experiment:
//!
//! * a full simulated-client run serves every request with consistent
//!   cache/latency telemetry, on the native and the sharded engine;
//! * the cache is a pure amortization: cached and uncached runs report
//!   the same physics (error telemetry agrees);
//! * a shared [`ProgramCache`] turns the second `meliso infer`-style
//!   pipeline run into all-hits, and deployed traces are deterministic;
//! * the `serve-sweep` experiment runs through the registry;
//! * admission control holds its overload contract: the close-race
//!   ledger is exact (items racing `close` are served or returned,
//!   never dropped) and the `overload-sweep` goodput plateau stays
//!   within 10% of the 1x-capacity leg while shedding monotonically.

use std::sync::Arc;
use std::time::Duration;

use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::experiments::{registry, Ctx};
use meliso::pipeline::{Activation, NetworkSpec, PipelineOptions, PipelineRunner};
use meliso::serve::{run_serve, ProgramCache, ServeOptions};
use meliso::util::pool::Parallelism;
use meliso::vmm::{DynEngine, NativeEngine, ShardedEngine, VmmEngine};

fn opts(cache: bool, workers: usize) -> ServeOptions {
    ServeOptions {
        clients: 4,
        requests_per_client: 12,
        models: 3,
        rows: 24,
        cols: 24,
        queue_capacity: 16,
        batch_max: 6,
        window: Duration::from_micros(150),
        workers,
        cache,
        cache_capacity: 8,
        measure_error: true,
        ..ServeOptions::default()
    }
}

#[test]
fn serving_run_completes_with_consistent_telemetry() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    for engine in [
        DynEngine::new(NativeEngine::default()),
        DynEngine::new(ShardedEngine::new(2, 2)),
    ] {
        let r = run_serve(&engine, &device, &opts(true, 2)).unwrap();
        assert_eq!(r.requests, 48, "{}", engine.name());
        assert!(r.batches >= 1 && r.batches <= 48);
        assert!(r.mean_batch >= 1.0);
        assert!(r.throughput > 0.0);
        assert!(r.p50_ms.is_finite() && r.p50_ms <= r.p95_ms && r.p95_ms <= r.p99_ms);
        // 3 models over 48 requests: repeats must hit; racing workers
        // may at worst double-program each model.
        assert!(r.cache.misses >= 3 && r.cache.misses <= 6, "{:?}", r.cache);
        assert!(r.cache.hits >= 1);
        assert!(r.mean_abs_error.is_finite() && r.mean_abs_error > 0.0);
    }
}

#[test]
fn cache_is_pure_amortization_same_physics_fewer_programs() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());
    let cached = run_serve(&engine, &device, &opts(true, 1)).unwrap();
    let uncached = run_serve(&engine, &device, &opts(false, 1)).unwrap();
    assert_eq!(cached.requests, uncached.requests);
    // One worker: exactly one program per model with the cache on; at
    // least one per batch group without it.
    assert_eq!(cached.programs, 3);
    assert!(uncached.programs > cached.programs);
    // Same per-request outputs, so the same error telemetry (up to
    // f64 reduction order across differently-assembled batches).
    let (a, b) = (cached.mean_abs_error, uncached.mean_abs_error);
    assert!((a - b).abs() < 1e-9 + 1e-9 * a.abs(), "{a} vs {b}");
}

#[test]
fn backpressure_bounded_queue_never_deadlocks() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());
    let mut o = opts(true, 2);
    o.queue_capacity = 1; // every push waits on the scheduler
    let r = run_serve(&engine, &device, &o).unwrap();
    assert_eq!(r.requests, 48);
}

#[test]
fn deployed_pipeline_shares_layer_programs_across_runs() {
    // `meliso infer --deploy`: layer programs resolved through a
    // serving cache persist across pipeline runs in one process — the
    // second run programs nothing.
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let net = NetworkSpec::uniform(3, 16, Activation::Relu, 23).with_population(10);
    let cache = Arc::new(ProgramCache::new(16));
    let runner = PipelineRunner::new(DynEngine::new(NativeEngine::default()));
    let run_opts = |cache: &Arc<ProgramCache>, par| PipelineOptions {
        chunk: 4,
        parallelism: par,
        deploy: Some(Arc::clone(cache)),
    };

    let first = runner
        .run(&net, &device, &run_opts(&cache, Parallelism::Fixed(1)))
        .unwrap();
    let after_first = cache.counts();
    assert_eq!(after_first.entries, 3, "one program per layer");
    assert!(after_first.misses >= 3);

    let second = runner
        .run(&net, &device, &run_opts(&cache, Parallelism::Fixed(1)))
        .unwrap();
    let after_second = cache.counts();
    assert_eq!(after_second.misses, after_first.misses, "second run is all hits");
    assert!(after_second.hits > after_first.hits);
    assert_eq!(first.final_hw, second.final_hw);

    // Deployed traces are deterministic across fresh caches and
    // thread counts.
    let other_cache = Arc::new(ProgramCache::new(16));
    let third = runner
        .run(&net, &device, &run_opts(&other_cache, Parallelism::Auto))
        .unwrap();
    assert_eq!(first.final_hw, third.final_hw);
    assert_eq!(first.final_sw, third.final_sw);
    for (a, b) in first.layers.iter().zip(&third.layers) {
        assert_eq!(a.injected.errors(), b.injected.errors(), "layer {}", a.index);
        assert_eq!(a.accumulated.errors(), b.accumulated.errors());
    }

    // Deployed mode shares one programming draw across samples, so
    // per-sample injected errors exist and are finite but the run is
    // distinct from the per-sample Monte-Carlo path.
    let monte = runner
        .run(&net, &device, &PipelineOptions { chunk: 4, ..PipelineOptions::default() })
        .unwrap();
    assert_eq!(monte.final_hw.len(), first.final_hw.len());
    assert_ne!(monte.final_hw, first.final_hw);
}

#[test]
fn deployed_first_chunk_matches_per_sample_path_for_sample_zero() {
    // The deployed instance is pinned to the sample-0 noise stream, so
    // layer 0's injected error for sample 0 must agree bitwise with
    // the per-sample path's sample 0.
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let net = NetworkSpec::uniform(1, 12, Activation::Identity, 29).with_population(6);
    let runner = PipelineRunner::new(DynEngine::new(NativeEngine::default()));
    let deployed = runner
        .run(
            &net,
            &device,
            &PipelineOptions {
                chunk: 6,
                parallelism: Parallelism::Fixed(1),
                deploy: Some(Arc::new(ProgramCache::new(4))),
            },
        )
        .unwrap();
    let monte = runner
        .run(
            &net,
            &device,
            &PipelineOptions {
                chunk: 6,
                parallelism: Parallelism::Fixed(1),
                ..PipelineOptions::default()
            },
        )
        .unwrap();
    let d = &deployed.layers[0].injected.errors()[..12];
    let m = &monte.layers[0].injected.errors()[..12];
    assert_eq!(d, m, "sample 0 shares the programming draw");
}

#[test]
fn bounded_queue_close_race_loses_nothing() {
    // The close-and-drain contract (DESIGN.md §18): items pushed
    // concurrently with `close` are either served or returned to the
    // pusher via `QueueClosed` — never silently dropped.  Run several
    // trials with close landing at different points in the stream;
    // meaningful at any thread count, exercised in CI at
    // MELISO_THREADS=1 and =4.
    use meliso::serve::BoundedQueue;
    use std::sync::atomic::{AtomicUsize, Ordering};

    for trial in 0..8u64 {
        let q = Arc::new(BoundedQueue::new(4));
        let accepted = Arc::new(AtomicUsize::new(0));
        let rejected = Arc::new(AtomicUsize::new(0));
        let (n_pushers, per) = (4usize, 64usize);
        let mut pushers = Vec::new();
        for p in 0..n_pushers {
            let q = Arc::clone(&q);
            let accepted = Arc::clone(&accepted);
            let rejected = Arc::clone(&rejected);
            pushers.push(std::thread::spawn(move || {
                for i in 0..per {
                    match q.push(p * per + i) {
                        Ok(()) => {
                            accepted.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(closed) => {
                            // The item comes back intact.
                            assert_eq!(closed.into_inner(), p * per + i);
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            }));
        }
        // One consumer drains until the queue reports closed-and-empty.
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = 0usize;
                loop {
                    let batch = q.pop_batch(16, Duration::ZERO);
                    if batch.is_empty() {
                        return got;
                    }
                    got += batch.len();
                }
            })
        };
        // Close while pushers race, at a trial-varied point.
        std::thread::sleep(Duration::from_micros(40 * trial));
        q.close();
        for h in pushers {
            h.join().unwrap();
        }
        let served = consumer.join().unwrap();
        let (acc, rej) = (accepted.load(Ordering::Relaxed), rejected.load(Ordering::Relaxed));
        assert_eq!(acc + rej, n_pushers * per, "trial {trial}: ledger must balance");
        assert_eq!(served, acc, "trial {trial}: every accepted item must be served");
    }
}

#[test]
fn overload_sweep_goodput_plateaus_within_ten_percent() {
    // The overload-hardening perf contract: past saturation, admission
    // control sheds the excess instead of collapsing — goodput at 4x
    // offered load stays within 10% of the 1x-capacity plateau, and
    // the shed rate never falls as offered load rises.
    let dir = std::env::temp_dir().join("meliso_it_overload_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = Ctx::native(32, &dir);
    let s = registry::run_by_id("overload-sweep", &ctx).unwrap();
    let rows = s.get("rows").unwrap().as_arr().unwrap();
    let num = |r: &meliso::util::json::Json, k: &str| r.get(k).unwrap().as_f64().unwrap();
    let goodput_at = |f: f64| {
        rows.iter()
            .find(|r| num(r, "factor") == f)
            .map(|r| num(r, "goodput_req_s"))
            .unwrap()
    };
    let (g1, g4) = (goodput_at(1.0), goodput_at(4.0));
    assert!(
        g4 >= 0.9 * g1,
        "saturated goodput collapsed: {g4:.0} req/s at 4x vs {g1:.0} req/s at 1x"
    );
    let mut prev = 0.0f64;
    for r in rows {
        assert_eq!(num(r, "served") + num(r, "shed"), num(r, "offered"));
        let rate = num(r, "shed_rate");
        assert!(rate >= prev - 0.05, "shed rate fell: {prev} -> {rate}");
        prev = prev.max(rate);
    }
    assert!(dir.join("overload-sweep/series.csv").exists());
    assert!(dir.join("overload-sweep/summary.json").exists());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn serve_sweep_experiment_runs_through_registry() {
    let dir = std::env::temp_dir().join("meliso_it_serve_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = Ctx::native(4, &dir);
    let s = registry::run_by_id("serve-sweep", &ctx).unwrap();
    let rows = s.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 3 * 2 * 2 * 2); // engines x clients x windows x cache
    for row in rows {
        let thr = row.get("throughput_req_s").unwrap().as_f64().unwrap();
        assert!(thr.is_finite() && thr > 0.0);
    }
    assert!(dir.join("serve-sweep/series.csv").exists());
    assert!(dir.join("serve-sweep/summary.json").exists());
    let _ = std::fs::remove_dir_all(dir);
}
