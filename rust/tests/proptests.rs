//! Property-based invariant suites over the coordinator, device model
//! and statistics substrates, using the in-repo `testkit` framework
//! (the offline registry has no `proptest`; see DESIGN.md §6).

use meliso::coordinator::WorkloadSpec;
use meliso::crossbar::array::{CrossbarArray, ProgramNoise};
use meliso::crossbar::kernel;
use meliso::device::params::DeviceParams;
use meliso::device::presets;
use meliso::device::pulse::pulse_curve;
use meliso::mitigation::{MitigatedEngine, MitigationConfig};
use meliso::obs::{self, Clock, HistogramSnapshot, MetricsSnapshot, MockClock};
use meliso::serve::{AdmissionQueue, BoundedQueue, Placement};
use meliso::shard::{ChecksumCode, Verdict};
use meliso::stats::fit::Normal;
use meliso::stats::moments::Moments;
use meliso::testkit::{check, check2, Config, FloatIn, OneOf, Tuple2, Tuple3, UsizeIn};
use meliso::util::pool::Parallelism;
use meliso::util::rng::Xoshiro256;
use meliso::vmm::{
    DynEngine, NativeEngine, ProgramSpec, ShardedEngine, SoftwareEngine, TiledEngine,
    VmmBatch, VmmEngine,
};

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed, max_shrink_steps: 100 }
}

#[test]
fn prop_pulse_curve_is_monotone_and_pinned_for_any_nu() {
    check(cfg(128, 1), &FloatIn { lo: -10.0, hi: 10.0 }, |&nu| {
        let mut prev = pulse_curve(0.0, nu);
        if prev.abs() > 1e-12 {
            return false;
        }
        for i in 1..=64 {
            let g = pulse_curve(i as f64 / 64.0, nu);
            if g < prev - 1e-12 {
                return false;
            }
            prev = g;
        }
        (pulse_curve(1.0, nu) - 1.0).abs() < 1e-9
    });
}

#[test]
fn prop_programmed_conductances_stay_in_window() {
    // For any (sigma, states) combination the clip keeps conductances
    // physical.
    check2(
        cfg(40, 2),
        &FloatIn { lo: 0.0, hi: 0.2 },
        &UsizeIn { lo: 2, hi: 512 },
        |&sigma, &states| {
            let params = DeviceParams::ideal()
                .with_c2c(sigma)
                .with_nonlinearity(2.4, -4.88);
            let params = DeviceParams { states: states as f64, ..params };
            let mut rng = Xoshiro256::seed_from_u64((states as u64) << 8);
            let mut w = vec![0.0f32; 64];
            rng.fill_uniform_f32(&mut w, -1.0, 1.0);
            let noise = ProgramNoise::sample(&mut rng, 64);
            let arr = CrossbarArray::program(8, 8, &w, &params, &noise);
            arr.gp().iter().chain(arr.gn()).all(|&g| (0.0..=1.0).contains(&g))
        },
    );
}

#[test]
fn prop_native_engine_error_vanishes_as_device_idealizes() {
    // Any workload seed: ideal device => tiny error.
    check(cfg(24, 3), &UsizeIn { lo: 0, hi: 1 << 20 }, |&seed| {
        let spec = WorkloadSpec::paper_default(seed as u64);
        let batch = spec.chunk(0, 4);
        let out = NativeEngine::default().forward(&batch, &DeviceParams::ideal()).unwrap();
        out.errors().iter().all(|e| e.abs() < 1e-2)
    });
}

#[test]
fn prop_software_engine_errors_always_zero() {
    check(cfg(24, 4), &UsizeIn { lo: 0, hi: 1 << 20 }, |&seed| {
        let spec = WorkloadSpec::paper_default(seed as u64);
        let batch = spec.chunk(0, 2);
        let out = SoftwareEngine.forward(&batch, &DeviceParams::ideal()).unwrap();
        out.errors().iter().all(|&e| e == 0.0)
    });
}

#[test]
fn prop_workload_chunks_compose_for_any_split() {
    // For any population and split point, chunk(0,n) equals
    // chunk(0,k) ++ chunk(k,n-k).
    check2(
        cfg(24, 5),
        &UsizeIn { lo: 2, hi: 24 },
        &UsizeIn { lo: 1, hi: 23 },
        |&n, &k| {
            let k = k.min(n - 1);
            let spec = WorkloadSpec::paper_default(99);
            let whole = spec.chunk(0, n);
            let a = spec.chunk(0, k);
            let b = spec.chunk(k, n - k);
            let cells = 32 * 32;
            whole.w[..k * cells] == a.w[..]
                && whole.w[k * cells..] == b.w[..]
                && whole.z[..k * 3 * cells] == a.z[..]
                && whole.z[k * 3 * cells..] == b.z[..]
        },
    );
}

#[test]
fn prop_moments_merge_is_associative_enough() {
    // Merging in any grouping agrees with the single stream to fp
    // tolerance.
    check(cfg(32, 6), &UsizeIn { lo: 3, hi: 400 }, |&n| {
        let mut rng = Xoshiro256::seed_from_u64(n as u64 * 31);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_ms(1.0, 3.0)).collect();
        let whole = Moments::from_slice(&xs);
        let k = 1 + n / 3;
        let mut left = Moments::from_slice(&xs[..k]);
        left = left.merge(&Moments::from_slice(&xs[k..]));
        (whole.variance() - left.variance()).abs() < 1e-9
            && (whole.skewness() - left.skewness()).abs() < 1e-6
    });
}

#[test]
fn prop_normal_cdf_is_monotone_and_bounded() {
    check2(
        cfg(48, 7),
        &FloatIn { lo: -5.0, hi: 5.0 },
        &FloatIn { lo: 0.01, hi: 10.0 },
        |&mu, &sigma| {
            let d = Normal::new(mu, sigma);
            let mut prev = 0.0;
            for i in -40..=40 {
                let x = mu + i as f64 * sigma / 8.0;
                let c = d.cdf(x);
                if !(0.0..=1.0).contains(&c) || c < prev - 1e-12 {
                    return false;
                }
                prev = c;
            }
            true
        },
    );
}

#[test]
fn prop_engine_error_scales_with_c2c() {
    // More C2C never reduces the error variance (statistically) — the
    // Fig. 4 monotonicity, randomized over seeds.
    check(cfg(12, 8), &UsizeIn { lo: 0, hi: 1 << 16 }, |&seed| {
        let spec = WorkloadSpec::paper_default(seed as u64);
        let batch = spec.chunk(0, 24);
        let var = |sigma: f64| {
            let p = DeviceParams::ideal()
                .with_weight_bits(7)
                .with_memory_window(100.0)
                .with_c2c(sigma);
            let out = NativeEngine::default().forward(&batch, &p).unwrap();
            Moments::from_slice(&out.errors()).variance()
        };
        var(0.05) > var(0.01) && var(0.01) > var(0.0)
    });
}

#[test]
fn prop_boxplot_quartiles_ordered() {
    check(cfg(32, 9), &UsizeIn { lo: 4, hi: 5000 }, |&n| {
        let mut rng = Xoshiro256::seed_from_u64(n as u64);
        let data: Vec<f64> = (0..n).map(|_| rng.normal_ms(0.0, 2.0)).collect();
        let b = meliso::stats::quantile::BoxPlot::from_data(&data);
        b.whisker_lo <= b.q1
            && b.q1 <= b.median
            && b.median <= b.q3
            && b.q3 <= b.whisker_hi
    });
}

#[test]
fn prop_quantization_identity_on_grid_weights() {
    // Weights already on the S-state grid program exactly (no noise,
    // no NL): the crossbar is lossless on representable values.
    let states = OneOf(vec![3usize, 5, 9, 17, 65]);
    check(cfg(32, 10), &states, |&s| {
        let n = (s - 1) as f32;
        let params = DeviceParams { states: s as f64, ..DeviceParams::ideal() };
        let w: Vec<f32> = (0..s).map(|i| i as f32 / n).collect();
        let arr = CrossbarArray::program(1, s, &w, &params, &ProgramNoise::zeros(s));
        w.iter()
            .enumerate()
            .all(|(i, &wi)| (arr.weight(0, i) - wi).abs() < 1e-6)
    });
}

/// Every serving-capable engine by name, at the given fan-out.
fn engine_by_name(name: &str, par: Parallelism) -> DynEngine {
    match name {
        "native" => DynEngine::new(NativeEngine::with_parallelism(par)),
        "tiled" => DynEngine::new(TiledEngine::with_tile(16).with_parallelism(par)),
        "sharded" => DynEngine::new(ShardedEngine::new(2, 2).with_parallelism(par)),
        "software" => DynEngine::new(SoftwareEngine),
        "mitigated" => DynEngine::new(MitigatedEngine::new(
            NativeEngine::with_parallelism(par),
            MitigationConfig::parse("diff,avg:2").unwrap(),
        )),
        other => panic!("unknown engine {other}"),
    }
}

#[test]
fn prop_cached_programmed_forward_bit_equals_uncached_for_every_engine() {
    // The serving core's contract: a cached `ProgrammedVmm::forward`
    // is bit-identical to the engine's uncached `forward` on a batch
    // carrying the same program `(w, z)` — for random geometries x
    // devices x engines, at Fixed(1) and Auto parallelism, shrinking
    // toward the smallest geometry/batch that still disagrees.
    let geom = Tuple3(
        UsizeIn { lo: 2, hi: 40 },
        UsizeIn { lo: 2, hi: 40 },
        UsizeIn { lo: 1, hi: 3 },
    );
    check(cfg(12, 31), &geom, |&(rows, cols, b)| {
        let mut rng =
            Xoshiro256::seed_from_u64(((rows * 41 + cols) * 7 + b) as u64 ^ 0xCAFE);
        let mut w = vec![0.0f32; rows * cols];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let spec = ProgramSpec::from_seed(
            rows,
            cols,
            w,
            ((rows as u64) << 20) ^ ((cols as u64) << 4) ^ b as u64,
        );
        let mut x = vec![0.0f32; b * rows];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let batch = spec.to_batch(&x, b);
        let devices = [
            DeviceParams::ideal(),
            presets::epiram().params,
            presets::ag_si().params,
        ];
        for device in devices {
            for name in ["native", "tiled", "sharded", "software", "mitigated"] {
                let uncached = engine_by_name(name, Parallelism::Fixed(1))
                    .forward(&batch, &device)
                    .unwrap();
                for par in [Parallelism::Fixed(1), Parallelism::Auto] {
                    let engine = engine_by_name(name, par);
                    let handle = engine.program(&spec, &device).unwrap();
                    let served = handle.forward(&x, b).unwrap();
                    if served.y_hw != uncached.y_hw || served.y_sw != uncached.y_sw {
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// Exact synthetic shard (mirrors the helper the checksum unit tests
/// use): `y_data` and `y_cs` computed from the same `(W, x)` in f64,
/// so the only check discrepancy is f32 rounding of encoded targets.
fn exact_shard(rows: usize, clen: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let code = ChecksumCode::new(clen);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut w = vec![0.0f32; rows * clen];
    let mut x = vec![0.0f32; rows];
    rng.fill_uniform_f32(&mut w, -1.0, 1.0);
    rng.fill_uniform_f32(&mut x, 0.0, 1.0);
    let mut y = vec![0.0f32; clen];
    for j in 0..clen {
        y[j] = (0..rows)
            .map(|i| x[i] as f64 * w[i * clen + j] as f64)
            .sum::<f64>() as f32;
    }
    let mut cs_w = vec![0.0f32; rows * code.extra()];
    for i in 0..rows {
        code.encode_row(
            &w[i * clen..(i + 1) * clen],
            &mut cs_w[i * code.extra()..(i + 1) * code.extra()],
        );
    }
    let mut y_cs = vec![0.0f32; code.extra()];
    for (k, yc) in y_cs.iter_mut().enumerate() {
        *yc = (0..rows)
            .map(|i| x[i] as f64 * cs_w[i * code.extra() + k] as f64)
            .sum::<f64>() as f32;
    }
    (y, y_cs)
}

#[test]
fn prop_checksum_single_fault_corrected_exactly_at_any_column() {
    // Any single gross bit-line fault — random shard shape, random
    // column, random magnitude and sign — is detected, located at
    // exactly that column, and reconstructed from the checksum.
    // Replaces the fixed-case asserts that previously lived in
    // `shard/checksum.rs`.
    let s = Tuple3(
        UsizeIn { lo: 1, hi: 40 },
        UsizeIn { lo: 4, hi: 64 },
        UsizeIn { lo: 0, hi: 1 << 16 },
    );
    check(cfg(64, 32), &s, |&(clen, rows, seed)| {
        let code = ChecksumCode::new(clen);
        let (mut y, y_cs) = exact_shard(rows, clen, 7000 + seed as u64);
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0xFA11);
        let target = rng.below(clen as u64) as usize;
        let sign = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let e = (2.0 + 6.0 * rng.uniform()) * sign;
        let truth = y[target] as f64;
        y[target] = (truth + e) as f32;
        match code.verify(&y, &y_cs, 1.0) {
            Verdict::Fault { col, delta } => {
                col == target && ((y[target] as f64 + delta) - truth).abs() < 0.1
            }
            _ => false,
        }
    });
}

#[test]
fn prop_checksum_equal_double_fault_refused() {
    // Two equal, same-sign gross faults at distinct columns decode
    // every differing locator bit to a ~0.5 ratio — outside both
    // accept windows — so the code must refuse to "correct" rather
    // than damage a healthy column.
    let s = Tuple3(
        UsizeIn { lo: 2, hi: 40 },
        UsizeIn { lo: 4, hi: 48 },
        UsizeIn { lo: 0, hi: 1 << 16 },
    );
    check(cfg(64, 33), &s, |&(clen, rows, seed)| {
        let code = ChecksumCode::new(clen);
        let (mut y, y_cs) = exact_shard(rows, clen, 9000 + seed as u64);
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0xD0B1);
        let a = rng.below(clen as u64) as usize;
        let mut b = rng.below(clen as u64) as usize;
        if b == a {
            b = (a + 1) % clen;
        }
        let e = 3.0 + 5.0 * rng.uniform();
        y[a] = (y[a] as f64 + e) as f32;
        y[b] = (y[b] as f64 + e) as f32;
        code.verify(&y, &y_cs, 1.0) == Verdict::Detected
    });
}

#[test]
fn prop_sharded_any_grid_bit_equals_native_on_exact_device() {
    // f32 addition is not associative, so regrouped shard partials may
    // differ from the native flat sum in the last ulp on a generic
    // device.  On a binary-exact device — 257 states put every
    // conductance on the 2^-8 grid, zero C2C, zero mismatch — every
    // product and partial sum is exactly representable, so ANY
    // row/column partition must reproduce the native engine
    // bit-for-bit.  Extends the fixed 1x1 check in
    // `tests/integration_sharded.rs` to random grids and batch shapes.
    let s = Tuple2(
        Tuple2(UsizeIn { lo: 1, hi: 4 }, UsizeIn { lo: 1, hi: 4 }),
        Tuple2(UsizeIn { lo: 4, hi: 40 }, UsizeIn { lo: 4, hi: 40 }),
    );
    let device = DeviceParams {
        states: 257.0,
        k_base: 0.0, // no mismatch pedestal: reads stay on the grid
        ..DeviceParams::ideal()
    };
    check(cfg(24, 34), &s, |&((gr, gc), (rows, cols))| {
        let b = 2usize;
        let mut rng =
            Xoshiro256::seed_from_u64((rows * 131 + cols * 7 + gr * 3 + gc) as u64);
        let mut vb = VmmBatch::zeros(b, rows, cols);
        rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
        // Drive voltages on the same 2^-8 grid keep products exact.
        for v in vb.x.iter_mut() {
            *v = rng.below(257) as f32 / 256.0;
        }
        let native = NativeEngine::sequential().forward(&vb, &device).unwrap();
        for checksum in [false, true] {
            let out = ShardedEngine::new(gr, gc)
                .with_checksum(checksum)
                .with_threshold(1e9)
                .forward(&vb, &device)
                .unwrap();
            if out.y_hw != native.y_hw || out.y_sw != native.y_sw {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_kernel_matches_reference() {
    // The columnar read kernel's accumulation-order contract
    // (crossbar/kernel.rs): the lane-blocked `dot`/`read_columnar`
    // must be **bit-identical** to the retained naive scalar
    // reference over random ragged geometries — row counts straddle
    // multiples of LANES so empty, partial, and full tails are all
    // exercised, and values span magnitudes where reassociating the
    // f32 sum would visibly change the bits.
    let geom = Tuple3(
        UsizeIn { lo: 1, hi: 4 * kernel::LANES + 3 },
        UsizeIn { lo: 1, hi: 24 },
        UsizeIn { lo: 0, hi: 1 << 16 },
    );
    check(cfg(96, 35), &geom, |&(rows, cols, seed)| {
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0x5EED_DA7A);
        let mut plane = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut plane, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        // Mix in magnitude spread and exact zeros (the no-zero-skip
        // clause) so order-of-accumulation bugs cannot hide.
        for (i, v) in x.iter_mut().enumerate() {
            match i % 5 {
                0 => *v *= 1e4,
                1 => *v *= 1e-4,
                2 => *v = 0.0,
                _ => {}
            }
        }
        for col in plane.chunks_exact(rows) {
            let got = kernel::dot(&x, col);
            let want = kernel::dot_reference(&x, col);
            if got.to_bits() != want.to_bits() {
                return false;
            }
        }
        let mut y = vec![0.0f32; cols];
        let mut yr = vec![0.0f32; cols];
        kernel::read_columnar(&plane, rows, cols, &x, &mut y);
        kernel::read_reference(&plane, rows, cols, &x, &mut yr);
        y.iter().zip(&yr).all(|(a, b)| a.to_bits() == b.to_bits())
    });
}

#[test]
fn prop_placement_assign_is_deterministic_with_full_replication() {
    // Router placement is a pure function of `(nodes, replication,
    // digest)`: two independently built rings agree on every
    // assignment (so every thread/worker computes the same replica
    // set), and each digest maps to exactly
    // `min(replication, live)` *distinct, live* nodes.
    let s = Tuple3(
        UsizeIn { lo: 1, hi: 9 },
        UsizeIn { lo: 1, hi: 4 },
        UsizeIn { lo: 0, hi: 1 << 16 },
    );
    check(cfg(64, 36), &s, |&(nodes, replication, seed)| {
        let a = Placement::new(nodes, replication);
        let b = Placement::new(nodes, replication);
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0x9_F1EE);
        for _ in 0..32 {
            let digest = rng.next_u64();
            let ra = a.assign(digest);
            if ra != b.assign(digest) || ra != a.assign(digest) {
                return false;
            }
            if ra.len() != replication.min(nodes) {
                return false;
            }
            let mut sorted = ra.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != ra.len() || !ra.iter().all(|&n| a.is_alive(n)) {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_placement_failure_only_replaces_affected_digests() {
    // Consistent hashing's minimal-disruption contract: killing one
    // node re-places only the digests whose replica set contained it.
    // Every other digest keeps its assignment bit-for-bit, so a node
    // failure never forces a survivor to re-program models it already
    // held.  Digests that did live on the victim keep their surviving
    // replicas (in order) and only append new ones.
    let s = Tuple3(
        UsizeIn { lo: 2, hi: 9 },
        UsizeIn { lo: 1, hi: 4 },
        UsizeIn { lo: 0, hi: 1 << 16 },
    );
    check(cfg(64, 37), &s, |&(nodes, replication, seed)| {
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0xDEAD_0A11);
        let victim = rng.below(nodes as u64) as usize;
        let before = Placement::new(nodes, replication);
        let mut after = before.clone();
        after.fail(victim);
        if after.live() != nodes - 1 || after.is_alive(victim) {
            return false;
        }
        for _ in 0..32 {
            let digest = rng.next_u64();
            let old = before.assign(digest);
            let new = after.assign(digest);
            if old.contains(&victim) {
                // Survivors keep their spots; replacements only append.
                let kept: Vec<usize> =
                    old.iter().copied().filter(|&n| n != victim).collect();
                if new.len() < kept.len()
                    || new[..kept.len()] != kept[..]
                    || new.contains(&victim)
                {
                    return false;
                }
            } else if new != old {
                return false; // untouched digests must not move
            }
        }
        true
    });
}

#[test]
fn prop_socket_fleet_is_bit_identical_to_in_process() {
    // The loopback-socket transport is a pass-through for any fleet
    // geometry and device physics: length-prefixed framing, the TCP
    // hop, and load-aware placement change where bytes travel, never
    // what they decode to.  Few cases — each runs two full fleets, one
    // of them over real sockets.
    use meliso::serve::{run_fleet, FleetOptions, ServeOptions, SocketOptions, Transport};
    let s = Tuple3(
        UsizeIn { lo: 1, hi: 3 },
        UsizeIn { lo: 8, hi: 24 },
        UsizeIn { lo: 0, hi: 1 << 12 },
    );
    check(cfg(6, 41), &s, |&(nodes, size, seed)| {
        let presets = presets::all_presets();
        let device = presets[seed % presets.len()]
            .params
            .masked(meliso::device::params::NonIdealities::FULL);
        let engine = DynEngine::new(NativeEngine::default());
        let base = FleetOptions {
            serve: ServeOptions {
                clients: 2,
                requests_per_client: 4,
                models: 2,
                rows: size,
                cols: size,
                queue_capacity: 16,
                batch_max: 4,
                window: std::time::Duration::from_micros(100),
                workers: 1,
                cache: true,
                cache_capacity: 4,
                measure_error: false,
                seed: seed as u64 ^ 0x50C2_E7F1,
                ..ServeOptions::default()
            },
            nodes,
            replication: 1,
            fail_rate: 0.0,
            collect_responses: true,
            ..FleetOptions::default()
        };
        let sock = FleetOptions {
            transport: Transport::Socket(SocketOptions {
                connect_timeout: std::time::Duration::from_millis(500),
                read_timeout: std::time::Duration::from_secs(2),
                retries: 2,
            }),
            ..base.clone()
        };
        let a = run_fleet(&engine, &device, &base).unwrap();
        let b = run_fleet(&engine, &device, &sock).unwrap();
        let (ra, rb) = (a.responses.unwrap(), b.responses.unwrap());
        ra.len() == 8
            && ra.len() == rb.len()
            && ra.iter().zip(&rb).all(|((ia, ya), (ib, yb))| {
                ia == ib
                    && ya.len() == yb.len()
                    && ya.iter().zip(yb).all(|(va, vb)| va.to_bits() == vb.to_bits())
            })
    });
}

#[test]
fn prop_placement_spreads_models_across_live_nodes() {
    // The ring's virtual points keep placement from collapsing: over a
    // few hundred random digests, every live node of a small fleet
    // owns at least one primary replica — no node sits idle while the
    // others melt, for any fleet size in range.
    check2(
        cfg(32, 38),
        &UsizeIn { lo: 1, hi: 6 },
        &UsizeIn { lo: 0, hi: 1 << 16 },
        |&nodes, &seed| {
            let p = Placement::new(nodes, 1);
            let mut hit = vec![false; nodes];
            let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0x5B_0BAD);
            for _ in 0..512 {
                hit[p.assign(rng.next_u64())[0]] = true;
            }
            hit.iter().all(|&h| h)
        },
    );
}

#[test]
fn prop_histogram_merge_is_associative_and_order_independent() {
    // The rollup contract (DESIGN.md §17): `HistogramSnapshot::merge`
    // is element-wise addition, so any grouping and any order of a
    // fleet rollup produces the identical merged histogram
    // bit-for-bit, and the exact count/sum fields fold exactly.
    let s = Tuple3(
        UsizeIn { lo: 0, hi: 60 },
        UsizeIn { lo: 0, hi: 60 },
        UsizeIn { lo: 0, hi: 1 << 16 },
    );
    check(cfg(64, 40), &s, |&(na, nb, seed)| {
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0x0B5_CAFE);
        let fill = |n: usize, rng: &mut Xoshiro256| {
            let mut h = HistogramSnapshot::empty();
            for _ in 0..n {
                // Shifts spread values over buckets 0..=47 (bounded so
                // the exact `sum` cannot overflow across three parts).
                h.record(rng.next_u64() >> (16 + rng.below(48)));
            }
            h
        };
        let a = fill(na, &mut rng);
        let b = fill(nb, &mut rng);
        let c = fill(17, &mut rng);
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        let mut ba = b.clone();
        ba.merge(&a);
        let mut c_ba = c.clone();
        c_ba.merge(&ba);
        ab_c == a_bc
            && ab_c == c_ba
            && ab_c.count == a.count + b.count + c.count
            && ab_c.sum == a.sum + b.sum + c.sum
    });
}

#[test]
fn prop_programmed_outputs_bit_identical_with_obs_on_and_off() {
    // The telemetry subsystem's standing invariant: observability
    // never perturbs results.  The same programmed read with the
    // registry gate off and then on must be bit-identical on every
    // serving engine — instrumentation reads clocks and bumps atomics,
    // never touching the numerics.
    let geom = Tuple3(
        UsizeIn { lo: 2, hi: 32 },
        UsizeIn { lo: 2, hi: 32 },
        UsizeIn { lo: 1, hi: 3 },
    );
    check(cfg(10, 41), &geom, |&(rows, cols, b)| {
        let mut rng =
            Xoshiro256::seed_from_u64(((rows * 57 + cols) * 11 + b) as u64 ^ 0x0B5);
        let mut w = vec![0.0f32; rows * cols];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let spec = ProgramSpec::from_seed(rows, cols, w, (rows * 31 + cols) as u64);
        let mut x = vec![0.0f32; b * rows];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let device = presets::ag_si().params;
        // The gate is process-wide: hold the registry lock while
        // flipping it.  Outputs (not registry contents) are compared,
        // so concurrent recording cannot affect the property.
        let _guard = obs::test_lock();
        for name in ["native", "tiled", "sharded"] {
            let engine = engine_by_name(name, Parallelism::Fixed(1));
            obs::set_enabled(false);
            let off = engine.program(&spec, &device).unwrap().forward(&x, b).unwrap();
            obs::registry().reset();
            obs::set_enabled(true);
            let on = engine.program(&spec, &device).unwrap().forward(&x, b).unwrap();
            obs::set_enabled(false);
            obs::registry().reset();
            if off.y_hw != on.y_hw || off.y_sw != on.y_sw {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_metrics_snapshot_melb_round_trips_and_rejects_corrupt_frames() {
    // Seeded fuzz over the METRICS envelope tag: any randomly
    // populated snapshot survives encode -> decode exactly; every
    // strict truncation of the frame and any trailing garbage is a
    // typed error — never a silently-wrong snapshot.
    check(cfg(48, 42), &UsizeIn { lo: 0, hi: 1 << 16 }, |&seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0x3E7A11);
        let mut s = MetricsSnapshot::empty();
        for c in s.counters.iter_mut() {
            if rng.uniform() < 0.7 {
                *c = rng.below(1 << 40);
            }
        }
        for g in s.gauges.iter_mut() {
            if rng.uniform() < 0.7 {
                *g = rng.below(1 << 20);
            }
        }
        for h in s.stages.iter_mut() {
            for _ in 0..rng.below(20) {
                h.record(rng.next_u64() >> (20 + rng.below(44)));
            }
        }
        let frame = s.encode_melb().unwrap();
        if MetricsSnapshot::decode_melb(&frame).unwrap() != s {
            return false;
        }
        for _ in 0..8 {
            let cut = rng.below(frame.len() as u64) as usize;
            if MetricsSnapshot::decode_melb(&frame[..cut]).is_ok() {
                return false;
            }
        }
        let mut padded = frame;
        padded.push(rng.next_u64() as u8);
        MetricsSnapshot::decode_melb(&padded).is_err()
    });
}

#[test]
fn prop_batch_layout_roundtrip() {
    check2(
        cfg(24, 11),
        &UsizeIn { lo: 1, hi: 16 },
        &UsizeIn { lo: 1, hi: 24 },
        |&b, &r| {
            let vb = VmmBatch::zeros(b, r, r);
            vb.check().is_ok()
                && vb.w_of(b - 1).len() == r * r
                && vb.z_of(b - 1, 2).len() == r * r
        },
    );
}

#[test]
fn prop_admission_lanes_preserve_per_client_fifo() {
    // For any lane count and interleaving, round-robin fairness may
    // reorder *across* lanes but each client's own requests come out
    // in submission order (the per-client FIFO contract, DESIGN.md
    // §18).
    check2(
        cfg(32, 40),
        &UsizeIn { lo: 1, hi: 5 },
        &UsizeIn { lo: 0, hi: 1 << 16 },
        |&nlanes, &seed| {
            let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0xFA13);
            let n = 40usize;
            let q = AdmissionQueue::new(n, 1);
            let mut per_lane: Vec<Vec<usize>> = vec![Vec::new(); nlanes];
            for i in 0..n {
                let lane = rng.below(nlanes as u64) as usize;
                q.push(i, lane, None).unwrap();
                per_lane[lane].push(i);
            }
            q.close();
            let mut popped = Vec::new();
            loop {
                let max = 1 + rng.below(8) as usize;
                let b = q.pop_batch(0, max, std::time::Duration::ZERO);
                if b.is_empty() {
                    break;
                }
                popped.extend(b);
            }
            popped.len() == n
                && per_lane.iter().all(|lane_items| {
                    let got: Vec<usize> = popped
                        .iter()
                        .copied()
                        .filter(|v| lane_items.contains(v))
                        .collect();
                    got == *lane_items
                })
        },
    );
}

#[test]
fn prop_admission_ledger_balances_under_overload() {
    // For any capacity and random overload trace (full-queue sheds,
    // admission-expired rejects, in-queue deadline drops, interleaved
    // pops), every offered item is accounted exactly once:
    // served + dropped + rejected == offered.
    check2(
        cfg(24, 41),
        &UsizeIn { lo: 1, hi: 8 },
        &UsizeIn { lo: 0, hi: 1 << 16 },
        |&cap, &seed| {
            let clock = std::sync::Arc::new(MockClock::new());
            let q = AdmissionQueue::new(cap, 2)
                .with_shed_on_full(true)
                .with_clock(std::sync::Arc::clone(&clock) as std::sync::Arc<dyn Clock>);
            let mut rng = Xoshiro256::seed_from_u64(seed as u64 ^ 0x9E37);
            let offered = 60usize;
            let (mut accepted, mut rejected, mut served) = (0usize, 0usize, 0usize);
            // `pop_batch` blocks while the queue is open and holds no
            // live work, so a mid-trace pop is only safe while at
            // least one deadline-free entry (which can never expire)
            // is known to be queued.  Track them by item id.
            let mut deadlines: Vec<Option<u64>> = vec![None; offered];
            let mut queued_forever = 0usize;
            for i in 0..offered {
                let lane = rng.below(3) as usize;
                let deadline = match rng.below(3) {
                    0 => None,
                    1 => Some(clock.now_ns() + 1 + rng.below(40)),
                    _ => Some(clock.now_ns()), // already expired at admission
                };
                deadlines[i] = deadline;
                match q.push(i, lane, deadline) {
                    Ok(()) => {
                        accepted += 1;
                        if deadline.is_none() {
                            queued_forever += 1;
                        }
                    }
                    Err(r) => {
                        // The item comes back intact with its reason.
                        if r.item != i {
                            return false;
                        }
                        rejected += 1;
                    }
                }
                clock.advance(rng.below(20));
                if queued_forever > 0 && rng.below(3) == 0 {
                    let w = rng.below(2) as usize;
                    let b =
                        q.pop_batch(w, 1 + rng.below(4) as usize, std::time::Duration::ZERO);
                    for &id in &b {
                        if deadlines[id].is_none() {
                            queued_forever -= 1;
                        }
                    }
                    served += b.len();
                }
            }
            q.close();
            loop {
                let b = q.pop_batch(0, 8, std::time::Duration::ZERO);
                if b.is_empty() {
                    break;
                }
                served += b.len();
            }
            accepted + rejected == offered
                && served + q.dropped() as usize == accepted
                && q.is_empty()
        },
    );
}

#[test]
fn prop_single_lane_admission_queue_matches_bounded_queue() {
    // At width 1 (one shard, one lane, no deadlines, no shedding) the
    // admission core is bit-identical to the plain bounded FIFO it
    // replaced — the standing determinism invariant behind the
    // [`BoundedQueue`] facade.
    check(cfg(32, 42), &UsizeIn { lo: 0, hi: 1 << 16 }, |&seed| {
        let mut rng = Xoshiro256::seed_from_u64(seed as u64 | 1);
        let n = 30usize;
        let aq = AdmissionQueue::new(n, 1);
        let bq = BoundedQueue::new(n);
        for i in 0..n {
            aq.push(i, 0, None).unwrap();
            bq.push(i).unwrap();
        }
        aq.close();
        bq.close();
        loop {
            let max = 1 + rng.below(9) as usize;
            let a = aq.pop_batch(0, max, std::time::Duration::ZERO);
            let b = bq.pop_batch(max, std::time::Duration::ZERO);
            if a != b {
                return false;
            }
            if a.is_empty() {
                return true;
            }
        }
    });
}
