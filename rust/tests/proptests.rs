//! Property-based invariant suites over the coordinator, device model
//! and statistics substrates, using the in-repo `testkit` framework
//! (the offline registry has no `proptest`; see DESIGN.md §6).

use meliso::coordinator::WorkloadSpec;
use meliso::crossbar::array::{CrossbarArray, ProgramNoise};
use meliso::device::params::DeviceParams;
use meliso::device::pulse::pulse_curve;
use meliso::stats::fit::Normal;
use meliso::stats::moments::Moments;
use meliso::testkit::{check, check2, Config, FloatIn, OneOf, UsizeIn};
use meliso::util::rng::Xoshiro256;
use meliso::vmm::{NativeEngine, SoftwareEngine, VmmBatch, VmmEngine};

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed, max_shrink_steps: 100 }
}

#[test]
fn prop_pulse_curve_is_monotone_and_pinned_for_any_nu() {
    check(cfg(128, 1), &FloatIn { lo: -10.0, hi: 10.0 }, |&nu| {
        let mut prev = pulse_curve(0.0, nu);
        if prev.abs() > 1e-12 {
            return false;
        }
        for i in 1..=64 {
            let g = pulse_curve(i as f64 / 64.0, nu);
            if g < prev - 1e-12 {
                return false;
            }
            prev = g;
        }
        (pulse_curve(1.0, nu) - 1.0).abs() < 1e-9
    });
}

#[test]
fn prop_programmed_conductances_stay_in_window() {
    // For any (sigma, states) combination the clip keeps conductances
    // physical.
    check2(
        cfg(40, 2),
        &FloatIn { lo: 0.0, hi: 0.2 },
        &UsizeIn { lo: 2, hi: 512 },
        |&sigma, &states| {
            let params = DeviceParams::ideal()
                .with_c2c(sigma)
                .with_nonlinearity(2.4, -4.88);
            let params = DeviceParams { states: states as f64, ..params };
            let mut rng = Xoshiro256::seed_from_u64((states as u64) << 8);
            let mut w = vec![0.0f32; 64];
            rng.fill_uniform_f32(&mut w, -1.0, 1.0);
            let noise = ProgramNoise::sample(&mut rng, 64);
            let arr = CrossbarArray::program(8, 8, &w, &params, &noise);
            arr.gp().iter().chain(arr.gn()).all(|&g| (0.0..=1.0).contains(&g))
        },
    );
}

#[test]
fn prop_native_engine_error_vanishes_as_device_idealizes() {
    // Any workload seed: ideal device => tiny error.
    check(cfg(24, 3), &UsizeIn { lo: 0, hi: 1 << 20 }, |&seed| {
        let spec = WorkloadSpec::paper_default(seed as u64);
        let batch = spec.chunk(0, 4);
        let out = NativeEngine::default().forward(&batch, &DeviceParams::ideal()).unwrap();
        out.errors().iter().all(|e| e.abs() < 1e-2)
    });
}

#[test]
fn prop_software_engine_errors_always_zero() {
    check(cfg(24, 4), &UsizeIn { lo: 0, hi: 1 << 20 }, |&seed| {
        let spec = WorkloadSpec::paper_default(seed as u64);
        let batch = spec.chunk(0, 2);
        let out = SoftwareEngine.forward(&batch, &DeviceParams::ideal()).unwrap();
        out.errors().iter().all(|&e| e == 0.0)
    });
}

#[test]
fn prop_workload_chunks_compose_for_any_split() {
    // For any population and split point, chunk(0,n) equals
    // chunk(0,k) ++ chunk(k,n-k).
    check2(
        cfg(24, 5),
        &UsizeIn { lo: 2, hi: 24 },
        &UsizeIn { lo: 1, hi: 23 },
        |&n, &k| {
            let k = k.min(n - 1);
            let spec = WorkloadSpec::paper_default(99);
            let whole = spec.chunk(0, n);
            let a = spec.chunk(0, k);
            let b = spec.chunk(k, n - k);
            let cells = 32 * 32;
            whole.w[..k * cells] == a.w[..]
                && whole.w[k * cells..] == b.w[..]
                && whole.z[..k * 3 * cells] == a.z[..]
                && whole.z[k * 3 * cells..] == b.z[..]
        },
    );
}

#[test]
fn prop_moments_merge_is_associative_enough() {
    // Merging in any grouping agrees with the single stream to fp
    // tolerance.
    check(cfg(32, 6), &UsizeIn { lo: 3, hi: 400 }, |&n| {
        let mut rng = Xoshiro256::seed_from_u64(n as u64 * 31);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_ms(1.0, 3.0)).collect();
        let whole = Moments::from_slice(&xs);
        let k = 1 + n / 3;
        let mut left = Moments::from_slice(&xs[..k]);
        left = left.merge(&Moments::from_slice(&xs[k..]));
        (whole.variance() - left.variance()).abs() < 1e-9
            && (whole.skewness() - left.skewness()).abs() < 1e-6
    });
}

#[test]
fn prop_normal_cdf_is_monotone_and_bounded() {
    check2(
        cfg(48, 7),
        &FloatIn { lo: -5.0, hi: 5.0 },
        &FloatIn { lo: 0.01, hi: 10.0 },
        |&mu, &sigma| {
            let d = Normal::new(mu, sigma);
            let mut prev = 0.0;
            for i in -40..=40 {
                let x = mu + i as f64 * sigma / 8.0;
                let c = d.cdf(x);
                if !(0.0..=1.0).contains(&c) || c < prev - 1e-12 {
                    return false;
                }
                prev = c;
            }
            true
        },
    );
}

#[test]
fn prop_engine_error_scales_with_c2c() {
    // More C2C never reduces the error variance (statistically) — the
    // Fig. 4 monotonicity, randomized over seeds.
    check(cfg(12, 8), &UsizeIn { lo: 0, hi: 1 << 16 }, |&seed| {
        let spec = WorkloadSpec::paper_default(seed as u64);
        let batch = spec.chunk(0, 24);
        let var = |sigma: f64| {
            let p = DeviceParams::ideal()
                .with_weight_bits(7)
                .with_memory_window(100.0)
                .with_c2c(sigma);
            let out = NativeEngine::default().forward(&batch, &p).unwrap();
            Moments::from_slice(&out.errors()).variance()
        };
        var(0.05) > var(0.01) && var(0.01) > var(0.0)
    });
}

#[test]
fn prop_boxplot_quartiles_ordered() {
    check(cfg(32, 9), &UsizeIn { lo: 4, hi: 5000 }, |&n| {
        let mut rng = Xoshiro256::seed_from_u64(n as u64);
        let data: Vec<f64> = (0..n).map(|_| rng.normal_ms(0.0, 2.0)).collect();
        let b = meliso::stats::quantile::BoxPlot::from_data(&data);
        b.whisker_lo <= b.q1
            && b.q1 <= b.median
            && b.median <= b.q3
            && b.q3 <= b.whisker_hi
    });
}

#[test]
fn prop_quantization_identity_on_grid_weights() {
    // Weights already on the S-state grid program exactly (no noise,
    // no NL): the crossbar is lossless on representable values.
    let states = OneOf(vec![3usize, 5, 9, 17, 65]);
    check(cfg(32, 10), &states, |&s| {
        let n = (s - 1) as f32;
        let params = DeviceParams { states: s as f64, ..DeviceParams::ideal() };
        let w: Vec<f32> = (0..s).map(|i| i as f32 / n).collect();
        let arr = CrossbarArray::program(1, s, &w, &params, &ProgramNoise::zeros(s));
        w.iter()
            .enumerate()
            .all(|(i, &wi)| (arr.weight(0, i) - wi).abs() < 1e-6)
    });
}

#[test]
fn prop_batch_layout_roundtrip() {
    check2(
        cfg(24, 11),
        &UsizeIn { lo: 1, hi: 16 },
        &UsizeIn { lo: 1, hi: 24 },
        |&b, &r| {
            let vb = VmmBatch::zeros(b, r, r);
            vb.check().is_ok()
                && vb.w_of(b - 1).len() == r * r
                && vb.z_of(b - 1, 2).len() == r * r
        },
    );
}
