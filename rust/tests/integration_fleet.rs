//! Integration: the node/router fleet fabric end-to-end —
//!
//! * a 1-node, replication-1, no-failure fleet is bit-identical to the
//!   single-process `run_serve` path: every served output equals a
//!   fresh per-request reference read, and the aggregate error
//!   telemetry agrees with `run_serve` on the same seeds;
//! * failure injection loses nothing: the router detects dead nodes
//!   through typed push rejections, re-routes every shed request to a
//!   surviving replica, the survivor re-programs re-placed models on
//!   first touch, and the outputs stay bit-identical to the
//!   failure-free run;
//! * per-node engines (sharded) roll honest per-node ABFT telemetry up
//!   into the fleet report;
//! * the `fleet-sweep` experiment runs through the registry.
//!
//! The determinism matrix in CI runs this file at `MELISO_THREADS=1`
//! and `=4`: every assertion here must hold for any thread count.

use std::time::Duration;

use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::experiments::{registry, Ctx};
use meliso::serve::{
    run_fleet, run_fleet_nodes, run_serve, FleetOptions, ServeOptions, SocketOptions, Transport,
};
use meliso::vmm::{DynEngine, NativeEngine, ShardedEngine, VmmEngine};

fn serve_opts() -> ServeOptions {
    ServeOptions {
        clients: 4,
        requests_per_client: 12,
        models: 5,
        rows: 24,
        cols: 24,
        queue_capacity: 16,
        batch_max: 6,
        window: Duration::from_micros(150),
        workers: 2,
        cache: true,
        cache_capacity: 8,
        measure_error: true,
        ..ServeOptions::default()
    }
}

fn fleet_opts(nodes: usize, replication: usize, fail_rate: f64) -> FleetOptions {
    FleetOptions {
        serve: serve_opts(),
        nodes,
        replication,
        fail_rate,
        collect_responses: true,
        ..FleetOptions::default()
    }
}

#[test]
fn single_node_fleet_is_bit_identical_to_run_serve() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());
    let opts = fleet_opts(1, 1, 0.0);
    let fleet = run_fleet(&engine, &device, &opts).unwrap();
    assert_eq!(fleet.aggregate.requests, 48);
    assert_eq!(fleet.shed, 0);
    assert!(fleet.failed_nodes.is_empty());

    // Every served output equals a fresh per-request reference read:
    // `y` is a pure function of (spec, device, x) under the
    // program-once contract, independent of batching, placement, or
    // thread count — bitwise, not approximately.
    let specs = opts.serve.model_specs();
    let inputs = opts.serve.request_inputs();
    let programmed: Vec<_> = specs
        .iter()
        .map(|s| engine.program(s, &device).unwrap())
        .collect();
    let responses = fleet.responses.as_ref().unwrap();
    assert_eq!(responses.len(), 48);
    for (id, y) in responses {
        let model = *id as usize % opts.serve.models;
        let x = inputs.sample(*id as usize);
        let reference = programmed[model].read(&x, 1).unwrap();
        assert_eq!(y, &reference, "request {id} drifted from the reference");
    }

    // Same seeds through the pre-fleet single-process driver: same
    // requests, same physics (error telemetry agrees to f64
    // reduction-order tolerance across differently-assembled batches).
    let serve = run_serve(&engine, &device, &opts.serve).unwrap();
    assert_eq!(serve.requests, fleet.aggregate.requests);
    let (a, b) = (fleet.aggregate.mean_abs_error, serve.mean_abs_error);
    assert!((a - b).abs() < 1e-9 + 1e-9 * a.abs(), "{a} vs {b}");
    // One node with the cache on: between 5 (no worker races) and 10
    // (every model double-programmed) programs, on both drivers.
    for programs in [fleet.aggregate.programs, serve.programs] {
        assert!((5..=10).contains(&(programs as usize)), "{programs}");
    }
}

#[test]
fn failure_injection_recovers_every_request() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());

    let calm = run_fleet(&engine, &device, &fleet_opts(2, 1, 0.0)).unwrap();
    let stormy = run_fleet(&engine, &device, &fleet_opts(2, 1, 1.0)).unwrap();

    // Exactly one of the two nodes dies (fail_rate 1.0, one survivor
    // always kept), mid-stream by the seeded plan.
    assert_eq!(stormy.failed_nodes.len(), 1);
    let dead = stormy.failed_nodes[0];
    assert!(!stormy.nodes[dead].alive);

    // Zero lost requests: every request is served to completion, shed
    // ones re-routed to the survivor.
    assert_eq!(stormy.aggregate.requests, 48);
    let responses = stormy.responses.as_ref().unwrap();
    assert_eq!(responses.len(), 48);
    let by_node: usize = stormy.nodes.iter().map(|n| n.requests).sum();
    assert_eq!(by_node, 48, "every request served by exactly one node");

    // The victim is the heaviest model owner and the threshold fires
    // before the stream ends, so the recovery path is genuinely
    // exercised: typed rejections detected and re-routed (shed), and
    // the victim's models re-programmed on the survivor.
    assert!(stormy.shed >= 1, "no push ever hit the dead node");
    assert!(stormy.recovered_models >= 1);
    // Re-programming on the survivor costs extra programming cycles
    // over the failure-free run's per-node maximum.
    assert!(stormy.aggregate.programs >= stormy.recovered_models);

    // Recovery changes where requests are served, never what they
    // return: outputs are bit-identical to the failure-free fleet.
    assert_eq!(calm.aggregate.requests, 48);
    assert_eq!(calm.shed, 0);
    let calm_responses = calm.responses.as_ref().unwrap();
    assert_eq!(calm_responses, responses, "failure changed served outputs");
}

#[test]
fn per_node_engines_roll_up_shard_telemetry() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let opts = fleet_opts(2, 2, 0.0);
    let engines: Vec<DynEngine> = (0..2)
        .map(|_| DynEngine::new(ShardedEngine::new(2, 2)))
        .collect();
    let r = run_fleet_nodes(engines, &device, &opts).unwrap();
    assert_eq!(r.aggregate.requests, 48);
    assert_eq!(r.replication, 2);
    // Distinct per-node engines: every node carries its own ABFT
    // counters and the fleet report sums them.
    for n in &r.nodes {
        assert!(n.shard.is_some(), "sharded node {} lost its counters", n.id);
    }
    // The fleet rollup is exactly the sum of the per-node deltas.
    let fleet_shard = r.shard.expect("fleet-wide shard rollup");
    let summed: u64 = r.nodes.iter().map(|n| n.shard.unwrap().detected).sum();
    assert_eq!(fleet_shard.detected, summed);
    assert_eq!(fleet_shard.injected, 0, "no faults injected");
    // Replication 2 over 2 nodes: every model lives on both, so each
    // node programs every model it actually served.
    assert!(r.aggregate.programs as usize >= opts.serve.models);
}

fn socket_opts() -> SocketOptions {
    SocketOptions {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(2),
        retries: 2,
    }
}

#[test]
fn socket_fleet_is_bit_identical_to_in_process() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());

    let inproc = run_fleet(&engine, &device, &fleet_opts(2, 1, 0.0)).unwrap();
    let sock_opts = FleetOptions {
        transport: Transport::Socket(socket_opts()),
        ..fleet_opts(2, 1, 0.0)
    };
    let socket = run_fleet(&engine, &device, &sock_opts).unwrap();

    // The wire is a pass-through: same requests, same outputs, bit for
    // bit — serialization, framing, and the loopback hop change where
    // bytes travel, never what they decode to.
    assert_eq!(socket.aggregate.requests, 48);
    assert_eq!(socket.shed, 0);
    let a = inproc.responses.as_ref().unwrap();
    let b = socket.responses.as_ref().unwrap();
    assert_eq!(a.len(), b.len());
    for ((ia, ya), (ib, yb)) in a.iter().zip(b) {
        assert_eq!(ia, ib);
        assert_eq!(ya.len(), yb.len());
        for (va, vb) in ya.iter().zip(yb) {
            assert_eq!(va.to_bits(), vb.to_bits(), "request {ia} drifted on the wire");
        }
    }
}

#[test]
fn socket_fleet_loses_nothing_under_total_failure_pressure() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let engine = DynEngine::new(NativeEngine::default());
    let opts = FleetOptions {
        transport: Transport::Socket(socket_opts()),
        ..fleet_opts(3, 2, 1.0)
    };
    let r = run_fleet(&engine, &device, &opts).unwrap();

    // fail_rate 1.0 kills ceil(1.0 * (3 - 1)) = 2 of 3 nodes mid-
    // stream; over sockets the router sees that as NAKs and peer
    // disconnects instead of typed queue rejections — and must still
    // detour every request to the survivor. Offered == served: shed
    // requests are re-routes, never losses.
    assert_eq!(r.failed_nodes.len(), 2);
    assert_eq!(r.aggregate.requests, 48);
    assert_eq!(r.responses.as_ref().unwrap().len(), 48);
    assert!(r.shed >= 1, "no request ever hit a dead node");
    let by_node: usize = r.nodes.iter().map(|n| n.requests).sum();
    assert_eq!(by_node, 48, "every request served by exactly one node");

    // And the detours are invisible in the outputs: bit-identical to a
    // calm in-process run of the same traffic.
    let calm = run_fleet(&engine, &device, &fleet_opts(3, 2, 0.0)).unwrap();
    assert_eq!(calm.responses.as_ref().unwrap(), r.responses.as_ref().unwrap());
}

#[test]
fn fleet_sweep_experiment_runs_through_registry() {
    let dir = std::env::temp_dir().join("meliso_it_fleet_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = Ctx::native(4, &dir);
    let s = registry::run_by_id("fleet-sweep", &ctx).unwrap();
    let rows = s.get("rows").unwrap().as_arr().unwrap();
    // n1: 1 cell; n2, n3: 4 cells each — every cell run on both the
    // in-process and loopback-socket transports.
    assert_eq!(rows.len(), 18);
    let mut sockets = 0;
    for row in rows {
        // Zero lost requests in every cell, failure legs included.
        assert_eq!(row.get("requests").unwrap().as_f64(), Some(12.0));
        let thr = row.get("throughput_req_s").unwrap().as_f64().unwrap();
        assert!(thr.is_finite() && thr > 0.0);
        if row.get("transport").unwrap().as_str() == Some("socket") {
            sockets += 1;
        }
    }
    assert_eq!(sockets, 9, "every cell has a socket leg");
    assert!(dir.join("fleet-sweep/series.csv").exists());
    assert!(dir.join("fleet-sweep/summary.json").exists());
    let _ = std::fs::remove_dir_all(dir);
}
