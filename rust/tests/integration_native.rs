//! Integration: coordinator + native engine + statistics over real
//! benchmark configurations (no artifacts required).

use meliso::coordinator::{BenchmarkConfig, Coordinator};
use meliso::device::params::{DeviceParams, NonIdealities};
use meliso::device::presets;
use meliso::stats::fit::FittedModel;
use meliso::util::pool::Parallelism;
use meliso::vmm::{NativeEngine, SoftwareEngine};

fn run(device: DeviceParams, population: usize) -> meliso::coordinator::ErrorPopulation {
    let cfg = BenchmarkConfig::paper_default(device).with_population(population);
    Coordinator::new(NativeEngine::default()).run(&cfg).unwrap()
}

#[test]
fn software_engine_has_exactly_zero_error() {
    let cfg = BenchmarkConfig::paper_default(presets::ag_si().params).with_population(50);
    let pop = Coordinator::new(SoftwareEngine).run(&cfg).unwrap();
    assert_eq!(pop.len(), 50 * 32);
    assert!(pop.errors().iter().all(|&e| e == 0.0));
}

#[test]
fn ideal_device_error_is_negligible() {
    let pop = run(DeviceParams::ideal(), 100);
    assert!(pop.stats().std_dev() < 1e-2, "std={}", pop.stats().std_dev());
}

#[test]
fn paper_population_size_contract() {
    let pop = run(presets::epiram().params.masked(NonIdealities::FULL), 1000);
    // 1000 VMMs x 32 outputs = the paper's 32000-sample error vector.
    assert_eq!(pop.len(), 32_000);
}

#[test]
fn fig5_full_ordering_with_protocol_population() {
    let var = |p: DeviceParams| run(p, 300).stats().variance();

    // Ideal panel ordering (Fig. 5a / Table II): EpiRAM < TaOx < Ag << AlOx.
    let epi = var(presets::epiram().params.masked(NonIdealities::IDEAL));
    let ta = var(presets::taox_hfox().params.masked(NonIdealities::IDEAL));
    let ag = var(presets::ag_si().params.masked(NonIdealities::IDEAL));
    let al = var(presets::alox_hfo2().params.masked(NonIdealities::IDEAL));
    assert!(epi < ta && ta < ag && ag < al, "ideal: {epi} {ta} {ag} {al}");
    assert!(al / epi > 50.0, "AlOx must be far worse than EpiRAM (ideal)");

    // Non-ideal panel: EpiRAM still best, Ag/TaOx strongly degraded.
    let epi_f = var(presets::epiram().params.masked(NonIdealities::FULL));
    let ag_f = var(presets::ag_si().params.masked(NonIdealities::FULL));
    let ta_f = var(presets::taox_hfox().params.masked(NonIdealities::FULL));
    assert!(epi_f < ag_f && epi_f < ta_f);
    assert!(ag_f / ag > 5.0, "Ag degradation {ag} -> {ag_f}");
    assert!(ta_f / ta > 5.0, "TaOx degradation {ta} -> {ta_f}");
}

#[test]
fn nonideal_ag_si_is_skewed_heavy_tailed() {
    // The Table II headline: non-normal shape with positive skew.
    // (Paper: skew 3.34, kurt 15.7 — our Ag noise is partially window-
    // saturated, which trims the extreme tail; see EXPERIMENTS.md.)
    let pop = run(presets::ag_si().params.masked(NonIdealities::FULL), 500);
    let s = pop.summary();
    assert!(s.skewness.abs() > 0.2, "skew={}", s.skewness);
    // And the best fit must not be a plain normal.
    let fit = pop.best_fit().unwrap();
    assert!(
        !matches!(fit.model, FittedModel::Normal(_)),
        "got {}",
        fit.model.name()
    );
}

#[test]
fn nonideal_epiram_has_heavy_tails() {
    // EpiRAM's noise is far from the window rails, so the cycle-
    // severity mixture shows through: clear excess kurtosis + skew.
    let pop = run(presets::epiram().params.masked(NonIdealities::FULL), 500);
    let s = pop.summary();
    assert!(s.skewness.abs() > 0.1, "skew={}", s.skewness);
    assert!(s.excess_kurtosis > 1.0, "kurt={}", s.excess_kurtosis);
}

#[test]
fn population_is_engine_schedule_and_thread_invariant() {
    // Sequential engine so the Fixed(1)-vs-Fixed(8) budget reaches the
    // chunk pool instead of being absorbed by the engine fan-out
    // division; engine-level thread invariance is covered by
    // integration_tiled.rs.
    let device = presets::taox_hfox().params.masked(NonIdealities::FULL);
    let mut cfg = BenchmarkConfig::paper_default(device).with_population(64);
    cfg.parallelism = Parallelism::Fixed(1);
    cfg.chunk = 64;
    let a = Coordinator::new(NativeEngine::sequential()).run(&cfg).unwrap();
    cfg.parallelism = Parallelism::Fixed(8);
    cfg.chunk = 5;
    let b = Coordinator::new(NativeEngine::sequential()).run(&cfg).unwrap();
    assert_eq!(a.errors(), b.errors());
}

#[test]
fn seeds_change_samples_not_statistics() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let a = Coordinator::new(NativeEngine::default())
        .run(&BenchmarkConfig::paper_default(device).with_population(400).with_seed(1))
        .unwrap();
    let b = Coordinator::new(NativeEngine::default())
        .run(&BenchmarkConfig::paper_default(device).with_population(400).with_seed(2))
        .unwrap();
    assert_ne!(a.errors()[..32], b.errors()[..32]);
    // Statistically equivalent: variance within 20%.
    let (va, vb) = (a.stats().variance(), b.stats().variance());
    assert!((va / vb - 1.0).abs() < 0.2, "va={va} vb={vb}");
}

#[test]
fn error_telemetry_counts_match() {
    let device = presets::ag_si().params;
    let cfg = BenchmarkConfig::paper_default(device).with_population(123);
    let (pop, tel) = Coordinator::new(NativeEngine::default())
        .run_with_telemetry(&cfg)
        .unwrap();
    assert_eq!(tel.samples, 123);
    assert_eq!(pop.len(), 123 * 32);
    assert!(tel.engine_secs > 0.0);
    assert!(tel.wall_secs > 0.0);
}
