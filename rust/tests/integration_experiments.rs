//! Integration: the experiment registry end-to-end — every paper
//! artifact regenerates, writes its files, and carries the paper's
//! qualitative shape (at reduced population for test speed; the full
//! protocol is exercised by `meliso run all` / EXPERIMENTS.md).

use std::path::PathBuf;

use meliso::experiments::{registry, Ctx};
use meliso::util::json::Json;

fn ctx(tag: &str, population: usize) -> (Ctx, PathBuf) {
    let dir = std::env::temp_dir().join(format!("meliso_it_exp_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    (Ctx::native(population, &dir), dir)
}

#[test]
fn every_registered_experiment_runs_and_writes_summary() {
    let (ctx, dir) = ctx("all", 32);
    for id in registry::all_ids() {
        let summary = registry::run_by_id(id, &ctx).unwrap();
        assert_eq!(summary.get("id").unwrap().as_str(), Some(id));
        assert!(
            dir.join(id).join("summary.json").exists(),
            "{id} missing summary.json"
        );
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig2a_series_covers_1_to_11_bits_and_falls() {
    let (ctx, dir) = ctx("fig2a", 64);
    let s = registry::run_by_id("fig2a", &ctx).unwrap();
    let series = s.get("series").unwrap().as_arr().unwrap();
    assert_eq!(series.len(), 11);
    let first = series[0].get("variance").unwrap().as_f64().unwrap();
    let last = series[10].get("variance").unwrap().as_f64().unwrap();
    assert!(first / last > 10.0, "1-bit {first} vs 11-bit {last}");
    // CSV series written with a header + 11 rows.
    let csv = std::fs::read_to_string(dir.join("fig2a/series.csv")).unwrap();
    assert_eq!(csv.lines().count(), 12);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig4c_shows_nl_amplification() {
    let (ctx, dir) = ctx("fig4c", 48);
    let s = registry::run_by_id("fig4c", &ctx).unwrap();
    let series = s.get("series").unwrap().as_arr().unwrap();
    let last = &series[series.len() - 1];
    let no_nl = last.get("var_no_nl").unwrap().as_f64().unwrap();
    let with_nl = last.get("var_with_nl").unwrap().as_f64().unwrap();
    assert!(with_nl > no_nl, "NL must amplify C2C error: {with_nl} vs {no_nl}");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig5_writes_histograms_for_all_devices() {
    let (ctx, dir) = ctx("fig5", 48);
    registry::run_by_id("fig5b", &ctx).unwrap();
    for id in ["ag-si", "taox-hfox", "alox-hfo2", "epiram"] {
        assert!(
            dir.join("fig5b").join(format!("hist_{id}.csv")).exists(),
            "missing hist for {id}"
        );
    }
    assert!(dir.join("fig5b/boxplot.csv").exists());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn table2_best_fits_are_flexible_families_for_nonideal_devices() {
    let (ctx, dir) = ctx("table2", 96);
    let s = registry::run_by_id("table2", &ctx).unwrap();
    let rows = s.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 8);
    // Every non-ideal row of Table II picks a shape/mixture family
    // (the paper reports no plain-normal winners).
    for r in rows {
        if r.get("nonideal").unwrap() == &Json::Bool(true) {
            let fit = r.get("best_fit").unwrap().as_str().unwrap();
            assert_ne!(fit, "Normal", "device {:?}", r.get("device"));
        }
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn run_summaries_are_valid_json_documents() {
    let (ctx, dir) = ctx("json", 24);
    registry::run_by_id("fig3", &ctx).unwrap();
    let text = std::fs::read_to_string(dir.join("fig3/summary.json")).unwrap();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed.get("id").unwrap().as_str(), Some("fig3"));
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn registry_and_paper_sets_consistent() {
    assert!(registry::paper_ids().len() >= 10);
    for id in registry::paper_ids() {
        assert!(registry::all_ids().contains(&id));
    }
}
