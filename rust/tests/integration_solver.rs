//! Integration: in-memory solvers on crossbar operators — device error
//! propagating into algorithm behaviour.

use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::solver::{
    conjugate_gradient, jacobi, power_iteration, richardson, CrossbarOperator,
    ExactOperator, SolveOpts,
};
use meliso::util::rng::Xoshiro256;

/// SPD test system A = M^T M / n + I.
fn spd(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let m: Vec<f64> = (0..n * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += m[k * n + i] * m[k * n + j];
            }
            a[i * n + j] = s / n as f64 + if i == j { 1.0 } else { 0.0 };
        }
    }
    let b: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    (a, b)
}

#[test]
fn cg_on_ideal_crossbar_converges_like_software() {
    let n = 64;
    let (a, b) = spd(n, 401);
    let exact = ExactOperator::new(n, n, a.clone());
    let mut rng = Xoshiro256::seed_from_u64(402);
    let op = CrossbarOperator::program(
        n,
        n,
        &a,
        &meliso::device::params::DeviceParams::ideal(),
        &mut rng,
    );
    // Ideal-device floor is set by f32 quantization of the (1±w)/2
    // complementary encoding (~1e-4 relative).
    let opts = SolveOpts { max_iters: 150, tol: 5e-4 };
    let hw = conjugate_gradient(&op, &exact, &b, &opts).unwrap();
    assert!(hw.converged, "floor: {:?}", hw.residual_history.last());
}

#[test]
fn noisy_crossbar_sets_residual_floor_ordered_by_device_quality() {
    let n = 64;
    let (a, b) = spd(n, 403);
    let exact = ExactOperator::new(n, n, a.clone());
    let opts = SolveOpts { max_iters: 100, tol: 1e-12 };
    let mut rng = Xoshiro256::seed_from_u64(404);

    let mut floor = |device| {
        let op = CrossbarOperator::program(n, n, &a, &device, &mut rng);
        let r = conjugate_gradient(&op, &exact, &b, &opts).unwrap();
        r.residual_history
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    };

    let f_epi = floor(presets::epiram().params.masked(NonIdealities::FULL));
    let f_al = floor(presets::alox_hfo2().params.masked(NonIdealities::FULL));
    let f_sw = {
        let r = conjugate_gradient(&exact, &exact, &b, &opts).unwrap();
        r.residual_history
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    };
    assert!(f_sw < 1e-10);
    assert!(f_epi > f_sw, "noisy floor above software");
    assert!(f_epi < f_al, "EpiRAM floor {f_epi} must beat AlOx {f_al}");
    // Floors sit in physically sensible ranges.
    assert!(f_epi < 0.3, "EpiRAM floor unexpectedly high: {f_epi}");
}

#[test]
fn jacobi_and_richardson_tolerate_mild_noise() {
    // Diagonally dominant system, EpiRAM operator: stationary methods
    // should still drive the residual well below 10%.
    let n = 48;
    let mut rng = Xoshiro256::seed_from_u64(405);
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        let mut row = 0.0;
        for j in 0..n {
            if i != j {
                let v = rng.uniform_in(-0.4, 0.4);
                a[i * n + j] = v;
                row += v.abs();
            }
        }
        a[i * n + i] = row + 1.0;
    }
    let b: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let exact = ExactOperator::new(n, n, a.clone());
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let op = CrossbarOperator::program(n, n, &a, &device, &mut rng);
    let diag: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    let opts = SolveOpts { max_iters: 200, tol: 1e-12 };

    // Static D2D mismatch perturbs the operator; stationary methods
    // converge to the perturbed system's solution, so the honest floor
    // is ||E x|| / ||b|| — well under 20% for EpiRAM-class mismatch.
    let ja = jacobi(&op, &exact, &diag, &b, &opts).unwrap();
    let ri = richardson(&op, &exact, &b, 0.1, &opts).unwrap();
    let floor = |h: &[f64]| h.iter().copied().fold(f64::INFINITY, f64::min);
    assert!(floor(&ja.residual_history) < 0.2, "jacobi floor {}", floor(&ja.residual_history));
    assert!(floor(&ri.residual_history) < 0.2, "richardson floor {}", floor(&ri.residual_history));
}

#[test]
fn power_iteration_on_crossbar_approximates_spectrum() {
    let n = 32;
    let (a, _) = spd(n, 406);
    let exact = ExactOperator::new(n, n, a.clone());
    let truth = power_iteration(&exact, 1000, 1e-12).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(407);
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let op = CrossbarOperator::program(n, n, &a, &device, &mut rng);
    let est = power_iteration(&op, 1000, 1e-9).unwrap();
    let rel = (est.eigenvalue - truth.eigenvalue).abs() / truth.eigenvalue;
    assert!(rel < 0.25, "eigenvalue {} vs {}", est.eigenvalue, truth.eigenvalue);
}
