//! Integration: the XLA path (AOT artifacts through PJRT) against the
//! native engine — the lock-step contract between the rust physics and
//! the L2/L1 python pipeline.
//!
//! These tests need `make artifacts` **and** a vendored PJRT binding
//! (see `meliso::xla`); neither ships with the offline build, so the
//! suite skips (with a warning) when the engine is unavailable.
//! Environments that do provide both can enforce the full contract
//! with `MELISO_REQUIRE_XLA_TESTS=1`, which turns the skip into a
//! loud failure.

use meliso::coordinator::{BenchmarkConfig, Coordinator};
use meliso::device::params::{DeviceParams, NonIdealities};
use meliso::device::presets;
use meliso::runtime::XlaRuntime;
use meliso::vmm::{NativeEngine, VmmBatch, VmmEngine, XlaEngine};

fn engine_or_skip() -> Option<XlaEngine> {
    match XlaEngine::from_default_dir() {
        Ok(e) => Some(e),
        Err(err) => {
            if std::env::var("MELISO_REQUIRE_XLA_TESTS").as_deref() == Ok("1") {
                panic!(
                    "MELISO_REQUIRE_XLA_TESTS=1 but the XLA engine is \
                     unavailable — run `make artifacts` and vendor the \
                     PJRT binding ({err})"
                )
            }
            eprintln!("skipping XLA test: {err}");
            None
        }
    }
}

fn random_batch(b: usize, seed: u64) -> VmmBatch {
    use meliso::util::rng::Xoshiro256;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut vb = VmmBatch::zeros(b, 32, 32);
    rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
    rng.fill_uniform_f32(&mut vb.x, -1.0, 1.0);
    rng.fill_normal_f32(&mut vb.z);
    vb
}

#[test]
fn manifest_loads_and_compiles() {
    let Some(engine) = engine_or_skip() else { return };
    let n = engine.runtime().warmup().unwrap();
    assert!(n >= 9, "expected >= 9 artifacts, got {n}");
    assert_eq!(engine.runtime().manifest().rows, 32);
}

#[test]
fn raw_vmm_kernel_matches_software_contraction() {
    let Some(engine) = engine_or_skip() else { return };
    use meliso::util::rng::Xoshiro256;
    let b = 32;
    let mut rng = Xoshiro256::seed_from_u64(301);
    let mut gp = vec![0.0f32; b * 32 * 32];
    let mut gn = vec![0.0f32; b * 32 * 32];
    let mut v = vec![0.0f32; b * 32];
    rng.fill_uniform_f32(&mut gp, 0.0, 1.0);
    rng.fill_uniform_f32(&mut gn, 0.0, 1.0);
    rng.fill_uniform_f32(&mut v, -1.0, 1.0);

    // The L1 Pallas kernel through PJRT…
    let y = engine.raw_vmm(&gp, &gn, &v, b).unwrap();
    // …against a plain f64 software contraction.
    for s in 0..b {
        for j in 0..32 {
            let want: f64 = (0..32)
                .map(|i| {
                    v[s * 32 + i] as f64
                        * (gp[(s * 32 + i) * 32 + j] as f64
                            - gn[(s * 32 + i) * 32 + j] as f64)
                })
                .sum();
            let got = y[s * 32 + j] as f64;
            assert!(
                (got - want).abs() < 1e-3,
                "sample {s} col {j}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn program_artifact_matches_native_conductances() {
    let Some(engine) = engine_or_skip() else { return };
    use meliso::crossbar::array::{CrossbarArray, ProgramNoise};

    let batch = random_batch(32, 302);
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let (gp, gn) = engine
        .program(&batch.w, &batch.z, &device, 32)
        .unwrap();

    let mut noise = ProgramNoise::zeros(32 * 32);
    for s in 0..32 {
        noise.z0.copy_from_slice(batch.z_of(s, 0));
        noise.z1.copy_from_slice(batch.z_of(s, 1));
        noise.z2.copy_from_slice(batch.z_of(s, 2));
        let arr = CrossbarArray::program(32, 32, batch.w_of(s), &device, &noise);
        for c in 0..32 * 32 {
            let idx = s * 32 * 32 + c;
            assert!(
                (arr.gp()[c] - gp[idx]).abs() < 2e-4,
                "sample {s} cell {c}: native gp {} vs xla {}",
                arr.gp()[c],
                gp[idx]
            );
            assert!((arr.gn()[c] - gn[idx]).abs() < 2e-4);
        }
    }
}

#[test]
fn fwd_artifact_matches_native_engine_per_sample() {
    let Some(engine) = engine_or_skip() else { return };
    let batch = random_batch(32, 303);
    for preset in presets::all_presets() {
        let device = preset.params.masked(NonIdealities::FULL);
        let xla_out = engine.forward(&batch, &device).unwrap();
        let native_out = NativeEngine::default().forward(&batch, &device).unwrap();
        for i in 0..batch.batch * 32 {
            let d = (xla_out.y_hw[i] - native_out.y_hw[i]).abs();
            assert!(
                d < 5e-3,
                "{}: element {i}: xla {} vs native {}",
                preset.name,
                xla_out.y_hw[i],
                native_out.y_hw[i]
            );
            let ds = (xla_out.y_sw[i] - native_out.y_sw[i]).abs();
            assert!(ds < 5e-4, "software path diverged at {i}");
        }
    }
}

#[test]
fn full_population_statistics_agree_between_engines() {
    let Some(engine) = engine_or_skip() else { return };
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let cfg = BenchmarkConfig::paper_default(device).with_population(320);

    let native = Coordinator::new(NativeEngine::default()).run(&cfg).unwrap();
    let xla = Coordinator::new(engine).run(&cfg).unwrap();

    assert_eq!(native.len(), xla.len());
    let (vn, vx) = (native.stats().variance(), xla.stats().variance());
    assert!(
        (vn / vx - 1.0).abs() < 0.02,
        "variance: native {vn} vs xla {vx}"
    );
    let (mn, mx) = (native.stats().mean(), xla.stats().mean());
    assert!((mn - mx).abs() < 5e-3, "mean: {mn} vs {mx}");
}

#[test]
fn bad_input_shapes_are_rejected_cleanly() {
    let Some(engine) = engine_or_skip() else { return };
    let rt = engine.runtime();
    // Wrong buffer length must error before reaching PJRT.
    let short = vec![0.0f32; 3];
    let err = rt
        .execute_f32("meliso_vmm", 32, &[&short, &short, &short])
        .unwrap_err();
    assert!(err.to_string().contains("expected"), "{err}");
    // Unknown program name.
    assert!(rt.execute_f32("nonexistent", 32, &[]).is_err());
}

#[test]
fn runtime_is_shareable_across_threads() {
    let Some(engine) = engine_or_skip() else { return };
    let engine = std::sync::Arc::new(engine);
    let batch = random_batch(32, 304);
    let device = presets::taox_hfox().params;
    let baseline = engine.forward(&batch, &device).unwrap();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let e = std::sync::Arc::clone(&engine);
            let b = batch.clone();
            let want = baseline.y_hw.clone();
            s.spawn(move || {
                let out = e.forward(&b, &device).unwrap();
                assert_eq!(out.y_hw, want);
            });
        }
    });
}

#[test]
fn served_replay_program_bit_equals_uncached_forward() {
    // The XLA engine serves through the replay handle: a programmed
    // model must decode bit-identically to the uncached batch path on
    // the same (w, z), chunked to the pinned artifact batches.
    let Some(engine) = engine_or_skip() else { return };
    use meliso::util::rng::Xoshiro256;
    use meliso::vmm::ProgramSpec;
    let mut rng = Xoshiro256::seed_from_u64(305);
    let mut w = vec![0.0f32; 32 * 32];
    rng.fill_uniform_f32(&mut w, -1.0, 1.0);
    let spec = ProgramSpec::from_seed(32, 32, w, 3050);
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let n = 32;
    let mut x = vec![0.0f32; n * 32];
    rng.fill_uniform_f32(&mut x, 0.0, 1.0);
    let handle = VmmEngine::program(&engine, &spec, &device).unwrap();
    let served = handle.forward(&x, n).unwrap();
    let uncached = engine.forward(&spec.to_batch(&x, n), &device).unwrap();
    // Hardware path: the replay IS the uncached path, so bitwise.
    assert_eq!(served.y_hw, uncached.y_hw);
    // Software reference: the handle computes it in rust f64, the
    // artifact in XLA f32 — same contraction, tolerance-equal.
    for i in 0..n * 32 {
        assert!((served.y_sw[i] - uncached.y_sw[i]).abs() < 5e-4, "element {i}");
    }
}

#[test]
fn default_dir_env_override_works() {
    let Some(_) = engine_or_skip() else { return };
    // XlaRuntime::default_dir honors MELISO_ARTIFACTS (used by CI).
    let dir = XlaRuntime::default_dir();
    assert!(dir.join("manifest.json").exists());
}
