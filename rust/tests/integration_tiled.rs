//! Integration: the parallel engine path and the tiled engine through
//! the coordinator — determinism guards for the engine-level fan-out
//! refactor plus the arbitrary-geometry population contract.

use meliso::coordinator::{BenchmarkConfig, Coordinator};
use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::experiments::{registry, Ctx};
use meliso::util::pool::Parallelism;
use meliso::vmm::{NativeEngine, TiledEngine};

/// The refactor's determinism guard: engine-level `Fixed(1)` and
/// `Auto` produce **bit-identical** population statistics through the
/// new parallel engine path.
#[test]
fn native_engine_fixed1_and_auto_bit_identical() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let cfg = BenchmarkConfig::paper_default(device).with_population(96);

    let serial = Coordinator::new(NativeEngine::with_parallelism(Parallelism::Fixed(1)))
        .run(&cfg)
        .unwrap();
    let auto = Coordinator::new(NativeEngine::with_parallelism(Parallelism::Auto))
        .run(&cfg)
        .unwrap();

    assert_eq!(serial.errors(), auto.errors());
    assert_eq!(serial.stats().count(), auto.stats().count());
    assert_eq!(serial.stats().mean(), auto.stats().mean());
    assert_eq!(serial.stats().variance(), auto.stats().variance());
}

#[test]
fn tiled_engine_fixed1_and_auto_bit_identical() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let mut cfg = BenchmarkConfig::paper_default(device).with_population(12);
    cfg.workload.rows = 96;
    cfg.workload.cols = 96;
    cfg.calibration_samples = 8;

    let serial = Coordinator::new(
        TiledEngine::default().with_parallelism(Parallelism::Fixed(1)),
    )
    .run(&cfg)
    .unwrap();
    let auto = Coordinator::new(TiledEngine::default().with_parallelism(Parallelism::Auto))
        .run(&cfg)
        .unwrap();

    assert_eq!(serial.errors(), auto.errors());
}

/// At the paper geometry the tiled engine degenerates to one tile and
/// must reproduce the native engine's population exactly.
#[test]
fn tiled_at_paper_geometry_matches_native_engine() {
    let device = presets::taox_hfox().params.masked(NonIdealities::FULL);
    let cfg = BenchmarkConfig::paper_default(device).with_population(48);

    let native = Coordinator::new(NativeEngine::default()).run(&cfg).unwrap();
    let tiled = Coordinator::new(TiledEngine::default()).run(&cfg).unwrap();

    assert_eq!(native.errors(), tiled.errors());
}

/// Acceptance: a >= 128x128 population completes through the
/// coordinator with sane error statistics.
#[test]
fn tiled_population_at_128_completes_through_coordinator() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let mut cfg = BenchmarkConfig::paper_default(device).with_population(16);
    cfg.workload.rows = 128;
    cfg.workload.cols = 128;
    cfg.calibration_samples = 8;

    let coord = Coordinator::new(TiledEngine::default());
    let (pop, tel) = coord.run_with_telemetry(&cfg).unwrap();

    assert_eq!(pop.len(), 16 * 128);
    assert_eq!(tel.samples, 16);
    assert!(tel.engine_threads >= 1);
    let var = pop.stats().variance();
    assert!(var.is_finite() && var > 0.0, "var={var}");

    // Error accumulates with depth: the 128-row population is wider
    // than the paper-geometry one under the same device.
    let cfg32 = BenchmarkConfig::paper_default(device).with_population(16);
    let pop32 = coord.run(&cfg32).unwrap();
    assert!(var > pop32.stats().variance(), "128: {var} 32: {}", pop32.stats().variance());
}

/// The size-sweep experiment reports error stats for every geometry
/// (the reporting half of the acceptance criterion).
#[test]
fn size_sweep_experiment_reports_all_geometries() {
    let dir = std::env::temp_dir().join("meliso_it_size_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = Ctx::native(12, &dir);
    let s = registry::run_by_id("size-sweep", &ctx).unwrap();
    let series = s.get("series").unwrap().as_arr().unwrap();
    assert_eq!(series.len(), 5);
    for row in series {
        let v = row.get("variance").unwrap().as_f64().unwrap();
        assert!(v.is_finite() && v > 0.0);
    }
    assert!(dir.join("size-sweep/summary.json").exists());
    let _ = std::fs::remove_dir_all(dir);
}
