//! Internal-link checker for the operator-facing documents.
//!
//! Scans `README.md`, `DESIGN.md`, and `OPERATIONS.md` for markdown
//! links `[text](target)`, skipping external schemes and fenced code
//! blocks, and asserts that every relative file target exists and
//! every `#anchor` fragment names a real heading in the target file
//! (GitHub slugging: lowercase, punctuation stripped, spaces to
//! hyphens, duplicate slugs suffixed `-1`, `-2`, ...).  A renamed
//! heading or a typoed anchor fails CI here instead of shipping a
//! dead link.

use std::collections::HashMap;
use std::path::PathBuf;

/// The documents under contract, relative to the crate root.
const DOCS: [&str; 3] = ["README.md", "DESIGN.md", "OPERATIONS.md"];

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// GitHub's heading-to-anchor slug: lowercase; keep alphanumerics,
/// hyphens, and underscores; spaces become hyphens; everything else
/// (punctuation, backticks, `§`, em-dashes) is dropped.
fn slug(heading: &str) -> String {
    let mut out = String::new();
    for ch in heading.trim().to_lowercase().chars() {
        if ch.is_alphanumeric() || ch == '-' || ch == '_' {
            out.push(ch);
        } else if ch == ' ' {
            out.push('-');
        }
    }
    out
}

/// All heading anchors of a markdown file, with GitHub's duplicate
/// numbering (`slug`, `slug-1`, `slug-2`, ...), ignoring headings
/// inside fenced code blocks.
fn anchors(text: &str) -> Vec<String> {
    let mut seen: HashMap<String, usize> = HashMap::new();
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let trimmed = line.trim_start();
        let level = trimmed.chars().take_while(|&c| c == '#').count();
        if level == 0 || level > 6 || !trimmed[level..].starts_with(' ') {
            continue;
        }
        let base = slug(&trimmed[level + 1..]);
        let n = seen.entry(base.clone()).or_insert(0);
        let numbered = if *n == 0 {
            base.clone()
        } else {
            format!("{base}-{n}")
        };
        out.push(numbered);
        *n += 1;
    }
    out
}

/// Extract `(line_number, target)` pairs for every markdown link in
/// the text, skipping fenced code blocks.  A link is a `](` with a
/// matching `[` earlier on the same line and a closing `)` after.
fn links(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for (lineno, line) in text.lines().enumerate() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let mut rest = line;
        let mut offset = 0;
        while let Some(pos) = rest.find("](") {
            // Require a matching '[' before the ']' on this line —
            // otherwise it's stray punctuation, not a link.
            if rest[..pos].rfind('[').is_some() {
                if let Some(end) = rest[pos + 2..].find(')') {
                    out.push((lineno + 1, rest[pos + 2..pos + 2 + end].to_string()));
                }
            }
            offset += pos + 2;
            rest = &line[offset..];
        }
    }
    out
}

#[test]
fn every_internal_doc_link_resolves() {
    let root = crate_root();
    let mut checked = 0usize;
    let mut failures: Vec<String> = Vec::new();

    for doc in DOCS {
        let path = root.join(doc);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
        for (lineno, target) in links(&text) {
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
            {
                continue;
            }
            checked += 1;
            let at = format!("{doc}:{lineno} -> ({target})");
            let (file_part, fragment) = match target.split_once('#') {
                Some((f, a)) => (f, Some(a)),
                None => (target.as_str(), None),
            };
            // Resolve the file part (empty = same document).
            let target_path = if file_part.is_empty() {
                path.clone()
            } else {
                root.join(file_part)
            };
            if !target_path.exists() {
                failures.push(format!("{at}: file does not exist"));
                continue;
            }
            if let Some(anchor) = fragment {
                if !file_part.is_empty() && !file_part.ends_with(".md") {
                    continue; // anchors only checked in markdown targets
                }
                let target_text = std::fs::read_to_string(&target_path)
                    .unwrap_or_else(|e| panic!("cannot read {}: {e}", target_path.display()));
                let known = anchors(&target_text);
                if !known.iter().any(|a| a == anchor) {
                    failures.push(format!(
                        "{at}: no heading slugs to '{anchor}' (known: {})",
                        known.join(", ")
                    ));
                }
            }
        }
    }

    assert!(
        checked >= 5,
        "link scanner found only {checked} internal links — scanner broken?"
    );
    assert!(failures.is_empty(), "broken doc links:\n{}", failures.join("\n"));
}

#[test]
fn slugging_matches_github_rules() {
    assert_eq!(slug("Reading an overload sweep"), "reading-an-overload-sweep");
    assert_eq!(
        slug("§18 Admission control: deadlines, fairness lanes, and load shedding"),
        "18-admission-control-deadlines-fairness-lanes-and-load-shedding"
    );
    assert_eq!(slug("BENCH.json and BENCH.melb"), "benchjson-and-benchmelb");
    assert_eq!(slug("[overload]"), "overload");
    assert_eq!(slug("`code` span"), "code-span");
}

#[test]
fn anchor_extraction_numbers_duplicates_and_skips_fences() {
    let text = "# Top\n```\n# not a heading\n```\n## Dup\n## Dup\n";
    assert_eq!(anchors(text), vec!["top", "dup", "dup-1"]);
}

#[test]
fn link_extraction_skips_fences_and_stray_brackets() {
    let text = "see [a](x.md#y) and `[0, 1]` (zero)\n```\n[b](c.md)\n```\n";
    assert_eq!(links(text), vec![(1, "x.md#y".to_string())]);
}
