//! Integration: the sharded multi-crossbar engine through the
//! coordinator, the registry, and the inference pipeline — the
//! acceptance guards of the shard subsystem:
//!
//! * a `1x1` shard grid is bit-identical to the native engine,
//! * an injected single-shard gross fault is detected and corrected by
//!   the checksum reduction,
//! * engine-level `Fixed(1)` and `Auto` parallelism are bit-identical
//!   (including under fault injection).

use meliso::coordinator::{BenchmarkConfig, Coordinator};
use meliso::device::params::{DeviceParams, NonIdealities};
use meliso::device::presets;
use meliso::experiments::{registry, Ctx};
use meliso::pipeline::{Activation, NetworkSpec, PipelineOptions, PipelineRunner};
use meliso::shard::FaultSpec;
use meliso::util::pool::Parallelism;
use meliso::util::rng::Xoshiro256;
use meliso::vmm::{
    DynEngine, NativeEngine, ShardedEngine, VmmBatch, VmmEngine, VmmOutput,
};

fn random_batch(b: usize, r: usize, c: usize, seed: u64) -> VmmBatch {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut vb = VmmBatch::zeros(b, r, c);
    rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
    rng.fill_uniform_f32(&mut vb.x, 0.0, 1.0);
    rng.fill_normal_f32(&mut vb.z);
    vb
}

/// Acceptance: at a `1x1` grid the sharded engine degenerates to one
/// programming cycle over the full matrix and must reproduce the
/// native engine **bit-identically** — through the coordinator, with
/// the checksum columns present (they are transparent when no
/// correction fires; the high threshold guarantees that here).
#[test]
fn sharded_1x1_bit_identical_to_native_through_coordinator() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let cfg = BenchmarkConfig::paper_default(device).with_population(48);

    let native = Coordinator::new(NativeEngine::default()).run(&cfg).unwrap();
    let sharded = Coordinator::new(ShardedEngine::new(1, 1).with_threshold(64.0))
        .run(&cfg)
        .unwrap();
    assert_eq!(native.errors(), sharded.errors());

    // And with the checksum machinery disabled entirely.
    let bare = Coordinator::new(ShardedEngine::new(1, 1).with_checksum(false))
        .run(&cfg)
        .unwrap();
    assert_eq!(native.errors(), bare.errors());
}

/// Acceptance: an injected single-shard gross fault (stuck-at-rail bit
/// line) is detected by the sum check, located by the binary locator
/// columns, and corrected before accumulation.  On a quiet device the
/// corrected population is indistinguishable from fault-free scale,
/// while the uncorrected one carries the raw fault.
#[test]
fn injected_single_shard_fault_is_detected_and_corrected() {
    let device = DeviceParams::ideal();
    let batch = random_batch(12, 64, 64, 41);
    let fault = FaultSpec { rate: 1.0, level: 1.0, seed: 13 };

    let corrected_engine = ShardedEngine::new(2, 2)
        .with_threshold(0.05)
        .with_fault(fault);
    let corrected = corrected_engine.forward(&batch, &device).unwrap();
    let broken = ShardedEngine::new(2, 2)
        .with_checksum(false)
        .with_fault(fault)
        .forward(&batch, &device)
        .unwrap();

    let max_abs = |out: &VmmOutput| out.errors().iter().fold(0.0f64, |m, e| m.max(e.abs()));
    // Without correction the stuck lines are gross outliers…
    assert!(max_abs(&broken) > 4.0, "injected fault too small: {}", max_abs(&broken));
    // …with correction every output is back at benchmark error scale.
    assert!(max_abs(&corrected) < 1.0, "residual too large: {}", max_abs(&corrected));

    // The telemetry agrees: every injected fault was corrected.
    let counts = corrected_engine.counts();
    assert_eq!(counts.injected, 12 * 4);
    assert_eq!(counts.detected, counts.injected);
    assert_eq!(counts.corrected, counts.injected);
    assert_eq!(counts.uncorrectable, 0);
}

/// Determinism guard: engine-level `Fixed(1)` and `Auto` produce
/// bit-identical populations through the coordinator — including with
/// checksum correction active and faults being injected (fault draws
/// are pure functions of `(seed, sample, shard)`).
#[test]
fn sharded_fixed1_and_auto_bit_identical() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let mut cfg = BenchmarkConfig::paper_default(device).with_population(16);
    cfg.workload.rows = 64;
    cfg.workload.cols = 64;
    cfg.calibration_samples = 8;

    let engine = |par| {
        ShardedEngine::new(2, 2)
            .with_parallelism(par)
            .with_fault(FaultSpec { rate: 0.3, level: 1.0, seed: 5 })
    };
    let serial = Coordinator::new(engine(Parallelism::Fixed(1))).run(&cfg).unwrap();
    let auto = Coordinator::new(engine(Parallelism::Auto)).run(&cfg).unwrap();
    assert_eq!(serial.errors(), auto.errors());
    assert_eq!(serial.stats().mean(), auto.stats().mean());
    assert_eq!(serial.stats().variance(), auto.stats().variance());
}

/// The shard-sweep experiment runs through the registry and reports
/// every cell (the reporting half of the acceptance criterion).
#[test]
fn shard_sweep_experiment_runs_through_registry() {
    let dir = std::env::temp_dir().join("meliso_it_shard_sweep");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = Ctx::native(8, &dir);
    let s = registry::run_by_id("shard-sweep", &ctx).unwrap();
    let rows = s.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2 * 3 * 3); // devices x grids x legs
    for row in rows {
        let v = row.get("variance").unwrap().as_f64().unwrap();
        assert!(v.is_finite() && v > 0.0);
    }
    assert!(dir.join("shard-sweep/series.csv").exists());
    assert!(dir.join("shard-sweep/summary.json").exists());
    let _ = std::fs::remove_dir_all(dir);
}

/// Pipeline support via `DynEngine`: a layered network driven by the
/// sharded engine (1x1 grid, no corrections firing) reproduces the
/// native engine's full layer trace bitwise.
#[test]
fn pipeline_on_sharded_engine_matches_native_trace() {
    let device = presets::epiram().params.masked(NonIdealities::FULL);
    let net = NetworkSpec::uniform(3, 32, Activation::Relu, 7).with_population(12);
    let opts = PipelineOptions { chunk: 4, parallelism: Parallelism::Fixed(2), ..PipelineOptions::default() };

    let native = PipelineRunner::new(DynEngine::new(NativeEngine::default()))
        .run(&net, &device, &opts)
        .unwrap();
    let sharded = PipelineRunner::new(DynEngine::new(
        ShardedEngine::new(1, 1).with_threshold(64.0),
    ))
    .run(&net, &device, &opts)
    .unwrap();

    assert_eq!(native.final_hw, sharded.final_hw);
    assert_eq!(native.final_sw, sharded.final_sw);
    for (a, b) in native.layers.iter().zip(&sharded.layers) {
        assert_eq!(a.accumulated.errors(), b.accumulated.errors(), "layer {}", a.index);
        assert_eq!(a.injected.errors(), b.injected.errors(), "layer {}", a.index);
    }

    // A real shard grid also runs end-to-end through the pipeline.
    let gridded = PipelineRunner::new(DynEngine::new(ShardedEngine::new(2, 2)))
        .run(&net, &device, &opts)
        .unwrap();
    assert_eq!(gridded.final_hw.len(), native.final_hw.len());
    assert!(gridded.end_to_end().errors().iter().all(|e| e.is_finite()));
}
