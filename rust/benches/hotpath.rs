//! Bench: the hot paths of the stack, layer by layer — the §Perf
//! instrumentation (EXPERIMENTS.md records these before/after).
//!
//!  * workload generation (host, L3)
//!  * native crossbar engine (L3 baseline physics)
//!  * software reference VMM
//!  * XLA engine single batch (L2+L1 through PJRT), if artifacts exist
//!  * streaming statistics reduction
//!  * end-to-end coordinator run (native + xla)

use meliso::coordinator::{BenchmarkConfig, Coordinator, WorkloadSpec};
use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::stats::moments::Moments;
use meliso::util::bench::{bench, black_box, BenchOpts};
use meliso::vmm::{NativeEngine, VmmEngine, XlaEngine};

fn main() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let spec = WorkloadSpec::paper_default(1);
    let b256 = spec.chunk(0, 256);

    // L3: workload generation (w, x and 3 noise planes per sample).
    bench(
        "workload gen: 256 x (32x32 + noise)",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(spec.chunk(0, 256));
        },
    );

    // L3: native physics engine.
    bench(
        "native engine: forward 256 x 32x32",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(NativeEngine.forward(&b256, &device).unwrap());
        },
    );

    // Software reference.
    bench(
        "software vmm: 256 x 32x32 (f64 acc)",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(meliso::vmm::software_vmm_batch(&b256));
        },
    );

    // L2+L1 through PJRT.
    match XlaEngine::from_default_dir() {
        Ok(engine) => {
            engine.runtime().warmup().unwrap();
            bench(
                "xla engine: forward 256 x 32x32 (meliso_fwd)",
                BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
                || {
                    black_box(engine.forward(&b256, &device).unwrap());
                },
            );
            // Kernel-only artifact.
            let gp = vec![0.5f32; 256 * 32 * 32];
            let gn = vec![0.25f32; 256 * 32 * 32];
            let v = vec![0.1f32; 256 * 32];
            bench(
                "xla kernel: raw crossbar read 256 x 32x32",
                BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
                || {
                    black_box(engine.raw_vmm(&gp, &gn, &v, 256).unwrap());
                },
            );
            // End-to-end coordinator on the XLA engine.
            let cfg =
                BenchmarkConfig::paper_default(device).with_population(1024);
            let coord = Coordinator::new(engine);
            bench(
                "coordinator e2e: 1024 VMMs (xla engine)",
                BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(1024.0) },
                || {
                    black_box(coord.run(&cfg).unwrap());
                },
            );
        }
        Err(e) => eprintln!("(xla benches skipped: {e})"),
    }

    // Stats reduction over a protocol-size error vector.
    let errs: Vec<f64> = (0..32_000).map(|i| (i as f64 * 0.37).sin()).collect();
    bench(
        "stats: streaming 4-moment reduce of 32000",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(32_000.0) },
        || {
            black_box(Moments::from_slice(&errs));
        },
    );

    // End-to-end coordinator on the native engine (parallel).
    let cfg = BenchmarkConfig::paper_default(device).with_population(1024);
    let coord = Coordinator::new(NativeEngine);
    bench(
        "coordinator e2e: 1024 VMMs (native engine)",
        BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(1024.0) },
        || {
            black_box(coord.run(&cfg).unwrap());
        },
    );
}
