//! Bench: the hot paths of the stack, layer by layer — the §Perf
//! instrumentation (EXPERIMENTS.md records these before/after).
//!
//!  * workload generation (host, L3)
//!  * native crossbar engine, sequential baseline vs parallel fan
//!  * tiled crossbar engine at 128x128 and 256x256
//!  * layered inference pipeline, depth 4/8, plain vs mitigated
//!  * software reference VMM
//!  * XLA engine single batch (L2+L1 through PJRT), if artifacts exist
//!  * streaming statistics reduction
//!  * end-to-end coordinator run (native + tiled + xla)

use meliso::coordinator::{BenchmarkConfig, Coordinator, WorkloadSpec};
use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::mitigation::{MitigatedEngine, MitigationConfig};
use meliso::pipeline::{Activation, NetworkSpec, PipelineOptions, PipelineRunner};
use meliso::stats::moments::Moments;
use meliso::util::bench::{bench, black_box, BenchOpts};
use meliso::vmm::{DynEngine, NativeEngine, TiledEngine, VmmEngine, XlaEngine};

fn main() {
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let spec = WorkloadSpec::paper_default(1);
    let b256 = spec.chunk(0, 256);

    // L3: workload generation (w, x and 3 noise planes per sample).
    bench(
        "workload gen: 256 x (32x32 + noise)",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(spec.chunk(0, 256));
        },
    );

    // L3: native physics engine — the sequential post-fix baseline…
    let seq = bench(
        "native engine (sequential): forward 256 x 32x32",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(
                NativeEngine::sequential().forward(&b256, &device).unwrap(),
            );
        },
    );
    // …vs the pool-fanned engine (per-worker scratch, shared table).
    let par = bench(
        "native engine (parallel): forward 256 x 32x32",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(NativeEngine::default().forward(&b256, &device).unwrap());
        },
    );
    println!(
        "      native parallel speedup: {:.2}x samples/sec over sequential",
        par.items_per_sec(256.0) / seq.items_per_sec(256.0)
    );

    // Mitigation pipeline: throughput cost of each strategy (and the
    // combined pipeline) over the parallel native engine — the price
    // column of the mitigation-sweep experiment.
    for spec in ["diff", "slice:2", "avg:4", "cal", "diff,slice:2,avg:4,cal"] {
        let eng = MitigatedEngine::new(
            NativeEngine::default(),
            MitigationConfig::parse(spec).unwrap(),
        );
        let res = bench(
            &format!("mitigated native ({spec}): forward 256 x 32x32"),
            BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(256.0) },
            || {
                black_box(eng.forward(&b256, &device).unwrap());
            },
        );
        println!(
            "      mitigation cost ({spec}): {:.2}x parallel-native throughput",
            res.items_per_sec(256.0) / par.items_per_sec(256.0)
        );
    }

    // Tiled engine: arbitrary-size populations over 32x32 tile grids.
    let tiled = TiledEngine::default();
    for size in [128usize, 256] {
        let mut tspec = WorkloadSpec::paper_default(2);
        tspec.rows = size;
        tspec.cols = size;
        let samples = (16 * 128 * 128 / (size * size)).max(4);
        let tb = tspec.chunk(0, samples);
        bench(
            &format!("tiled engine: forward {samples} x {size}x{size}"),
            BenchOpts {
                samples: 5,
                warmup: 1,
                items_per_iter: Some(samples as f64),
            },
            || {
                black_box(tiled.forward(&tb, &device).unwrap());
            },
        );
    }

    // Layered inference pipeline: deep VMM chains through the parallel
    // native engine, plain vs per-layer mitigation — the cost of the
    // `pipeline` experiment's cells (samples x depth VMMs per run).
    let runner = PipelineRunner::new(DynEngine::new(NativeEngine::default()));
    let opts = PipelineOptions::default();
    for depth in [4usize, 8] {
        for mit in ["none", "diff,avg:2"] {
            let mut net = NetworkSpec::uniform(depth, 32, Activation::Relu, 3)
                .with_population(32);
            if mit != "none" {
                net = net.with_mitigation(MitigationConfig::parse(mit).unwrap());
            }
            bench(
                &format!("pipeline depth-{depth} ({mit}): 32 samples x 32x32"),
                BenchOpts {
                    samples: 3,
                    warmup: 1,
                    items_per_iter: Some((32 * depth) as f64),
                },
                || {
                    black_box(runner.run(&net, &device, &opts).unwrap());
                },
            );
        }
    }

    // Software reference.
    bench(
        "software vmm: 256 x 32x32 (f64 acc)",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
        || {
            black_box(meliso::vmm::software_vmm_batch(&b256));
        },
    );

    // L2+L1 through PJRT.
    match XlaEngine::from_default_dir() {
        Ok(engine) => {
            engine.runtime().warmup().unwrap();
            bench(
                "xla engine: forward 256 x 32x32 (meliso_fwd)",
                BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
                || {
                    black_box(engine.forward(&b256, &device).unwrap());
                },
            );
            // Kernel-only artifact.
            let gp = vec![0.5f32; 256 * 32 * 32];
            let gn = vec![0.25f32; 256 * 32 * 32];
            let v = vec![0.1f32; 256 * 32];
            bench(
                "xla kernel: raw crossbar read 256 x 32x32",
                BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(256.0) },
                || {
                    black_box(engine.raw_vmm(&gp, &gn, &v, 256).unwrap());
                },
            );
            // End-to-end coordinator on the XLA engine.
            let cfg =
                BenchmarkConfig::paper_default(device).with_population(1024);
            let coord = Coordinator::new(engine);
            bench(
                "coordinator e2e: 1024 VMMs (xla engine)",
                BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(1024.0) },
                || {
                    black_box(coord.run(&cfg).unwrap());
                },
            );
        }
        Err(e) => eprintln!("(xla benches skipped: {e})"),
    }

    // Stats reduction over a protocol-size error vector.
    let errs: Vec<f64> = (0..32_000).map(|i| (i as f64 * 0.37).sin()).collect();
    bench(
        "stats: streaming 4-moment reduce of 32000",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(32_000.0) },
        || {
            black_box(Moments::from_slice(&errs));
        },
    );

    // End-to-end coordinator on the native engine (parallel).
    let cfg = BenchmarkConfig::paper_default(device).with_population(1024);
    let coord = Coordinator::new(NativeEngine::default());
    bench(
        "coordinator e2e: 1024 VMMs (native engine)",
        BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(1024.0) },
        || {
            black_box(coord.run(&cfg).unwrap());
        },
    );

    // End-to-end coordinator on the tiled engine at 128x128.
    let mut cfg128 = BenchmarkConfig::paper_default(device).with_population(64);
    cfg128.workload.rows = 128;
    cfg128.workload.cols = 128;
    cfg128.calibration_samples = 16;
    let coord = Coordinator::new(TiledEngine::default());
    bench(
        "coordinator e2e: 64 VMMs at 128x128 (tiled engine)",
        BenchOpts { samples: 3, warmup: 1, items_per_iter: Some(64.0) },
        || {
            black_box(coord.run(&cfg128).unwrap());
        },
    );
}
