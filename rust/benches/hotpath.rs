//! Bench: the hot paths of the stack, layer by layer — the §Perf
//! instrumentation.  The suite itself lives in `meliso::perf` (shared
//! with the `meliso bench` subcommand, which runs it in quick mode and
//! writes machine-readable `BENCH.json`); this target runs it in full
//! mode:
//!
//!  * workload generation (host, L3)
//!  * native crossbar engine, sequential baseline vs parallel fan
//!  * error-mitigation pipeline cost per strategy
//!  * tiled crossbar engine at 128x128 and 256x256
//!  * sharded multi-crossbar engine (1x1/2x2/4x4 grids, checksum
//!    reduction, fault-injection campaign)
//!  * layered inference pipeline, depth 4/8, plain vs mitigated
//!  * software reference VMM
//!  * XLA engine single batch (L2+L1 through PJRT), if artifacts exist
//!  * streaming statistics reduction
//!  * end-to-end coordinator runs (native + tiled + sharded + xla)
//!
//! Set `MELISO_BENCH_OUT=<dir>` to also write `<dir>/BENCH.json`.

use meliso::perf::{run_suite, SuiteOpts};
use meliso::util::bench::write_bench_json;

fn main() {
    let filter = std::env::var("MELISO_BENCH_FILTER").ok();
    let results = run_suite(&SuiteOpts { quick: false, filter: filter.clone() });
    if results.is_empty() {
        // Same guard as the `meliso bench` CLI: an empty BENCH.json
        // reads as "no regressions" downstream.
        eprintln!(
            "error: MELISO_BENCH_FILTER '{}' matched no benchmarks",
            filter.as_deref().unwrap_or("")
        );
        std::process::exit(1);
    }
    if let Ok(dir) = std::env::var("MELISO_BENCH_OUT") {
        let path = std::path::Path::new(&dir).join("BENCH.json");
        match write_bench_json(&results, &path) {
            Ok(()) => println!("wrote {} results to {}", results.len(), path.display()),
            Err(e) => eprintln!("error: could not write {}: {e}", path.display()),
        }
    }
}
