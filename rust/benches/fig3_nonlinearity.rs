//! Bench: regenerate the paper's fig3 artifact end-to-end and time it.
//! The experiment itself prints the series/rows the paper reports;
//! run `meliso run fig3` for the full-population version.

use meliso::experiments::{registry, Ctx};
use meliso::util::bench::{bench, BenchOpts};

fn main() {
    let dir = std::env::temp_dir().join("meliso_bench_fig3");
    let ctx = Ctx::native(48, &dir);
    bench(
        "fig3 (population 48, native engine)",
        BenchOpts { samples: 5, warmup: 1, items_per_iter: None },
        || {
            registry::run_by_id("fig3", &ctx).unwrap();
        },
    );
    // Echo the headline series once, non-quiet, full default layout.
    let mut loud = Ctx::native(48, &dir);
    loud.quiet = false;
    registry::run_by_id("fig3", &loud).unwrap();
    let _ = std::fs::remove_dir_all(dir);
}
