//! Bench: regenerate Fig. 4 (a, b and the c variance comparison) and
//! time the sweeps.  `meliso run fig4a|fig4b|fig4c` gives the
//! full-population version.

use meliso::experiments::{registry, Ctx};
use meliso::util::bench::{bench, BenchOpts};

fn main() {
    let dir = std::env::temp_dir().join("meliso_bench_fig4");
    let ctx = Ctx::native(48, &dir);
    for id in ["fig4a", "fig4b", "fig4c"] {
        bench(
            &format!("{id} (population 48, native engine)"),
            BenchOpts { samples: 3, warmup: 1, items_per_iter: None },
            || {
                registry::run_by_id(id, &ctx).unwrap();
            },
        );
    }
    let mut loud = Ctx::native(48, &dir);
    loud.quiet = false;
    registry::run_by_id("fig4c", &loud).unwrap();
    let _ = std::fs::remove_dir_all(dir);
}
