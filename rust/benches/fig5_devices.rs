//! Bench: regenerate Fig. 5 (both panels) and time the device
//! comparison.  `meliso run fig5a|fig5b` gives the full-population
//! version.

use meliso::experiments::{registry, Ctx};
use meliso::util::bench::{bench, BenchOpts};

fn main() {
    let dir = std::env::temp_dir().join("meliso_bench_fig5");
    let ctx = Ctx::native(64, &dir);
    for id in ["fig5a", "fig5b"] {
        bench(
            &format!("{id} (population 64, native engine)"),
            BenchOpts { samples: 3, warmup: 1, items_per_iter: None },
            || {
                registry::run_by_id(id, &ctx).unwrap();
            },
        );
    }
    let mut loud = Ctx::native(64, &dir);
    loud.quiet = false;
    registry::run_by_id("fig5b", &loud).unwrap();
    let _ = std::fs::remove_dir_all(dir);
}
