//! Bench: regenerate Table II (population run + all distribution fits)
//! and time both the benchmark half and the fitting half separately.

use meliso::coordinator::{BenchmarkConfig, Coordinator};
use meliso::device::params::NonIdealities;
use meliso::device::presets;
use meliso::experiments::{registry, Ctx};
use meliso::util::bench::{bench, black_box, BenchOpts};
use meliso::vmm::NativeEngine;

fn main() {
    let dir = std::env::temp_dir().join("meliso_bench_table2");

    // Full Table II regeneration at reduced population.
    let ctx = Ctx::native(64, &dir);
    bench(
        "table2 (population 64, 8 configs x 5 fits)",
        BenchOpts { samples: 3, warmup: 1, items_per_iter: None },
        || {
            registry::run_by_id("table2", &ctx).unwrap();
        },
    );

    // Isolated fitting cost on a protocol-size error population.
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let cfg = BenchmarkConfig::paper_default(device).with_population(1000);
    let pop = Coordinator::new(NativeEngine::default()).run(&cfg).unwrap();
    bench(
        "fit_all on 32000-sample population",
        BenchOpts { samples: 3, warmup: 1, items_per_iter: None },
        || {
            black_box(pop.fit_all().unwrap());
        },
    );

    let mut loud = Ctx::native(64, &dir);
    loud.quiet = false;
    registry::run_by_id("table2", &loud).unwrap();
    let _ = std::fs::remove_dir_all(dir);
}
