//! Bench: regenerate the paper's fig2b artifact end-to-end and time it.
//! The experiment itself prints the series/rows the paper reports;
//! run `meliso run fig2b` for the full-population version.

use meliso::experiments::{registry, Ctx};
use meliso::util::bench::{bench, BenchOpts};

fn main() {
    let dir = std::env::temp_dir().join("meliso_bench_fig2b");
    let ctx = Ctx::native(48, &dir);
    bench(
        "fig2b (population 48, native engine)",
        BenchOpts { samples: 5, warmup: 1, items_per_iter: None },
        || {
            registry::run_by_id("fig2b", &ctx).unwrap();
        },
    );
    // Echo the headline series once, non-quiet, full default layout.
    let mut loud = Ctx::native(48, &dir);
    loud.quiet = false;
    registry::run_by_id("fig2b", &loud).unwrap();
    let _ = std::fs::remove_dir_all(dir);
}
