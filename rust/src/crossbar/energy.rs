//! Read-energy model for a crossbar VMM — the Table I `R_ON` column
//! feeding the "energy-efficient operations" claim of the paper's
//! introduction, and the §IV outlook's energy benchmarking metric.
//!
//! Per read pulse, each cell dissipates `V² G t_read`; the array energy
//! is the sum over both differential devices.  Conductances are the
//! normalized values scaled by `G_ON = 1/R_ON`.

use crate::device::presets::DevicePreset;

/// Energy model constants (typical read conditions from the RRAM
/// VMM literature, e.g. ISAAC / Amirsoleimani et al. 2020).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Read voltage in volts.
    pub v_read: f64,
    /// Read pulse width in seconds.
    pub t_read: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self { v_read: 0.2, t_read: 10e-9 }
    }
}

impl EnergyModel {
    /// Energy (J) of one VMM on a `rows x cols` array for a device
    /// preset, assuming uniformly distributed programmed conductances
    /// (expected normalized conductance per device ≈ mean of the pair
    /// states ≈ `(1 + 1/MW) / 2 · 1/2` for our differential encoding).
    pub fn vmm_energy(&self, preset: &DevicePreset, rows: usize, cols: usize) -> f64 {
        let g_on = 1.0 / preset.r_on_ohms;
        let g_min = g_on / preset.params.memory_window;
        // Differential pair: the driven device averages half scale, the
        // reset device sits at Gmin.
        let g_cell = 0.5 * (g_on + g_min) * 0.5 + g_min;
        let cells = (rows * cols) as f64;
        self.v_read * self.v_read * g_cell * self.t_read * cells
    }

    /// Energy per MAC (J) — the figure of merit papers quote.
    pub fn energy_per_mac(&self, preset: &DevicePreset, rows: usize, cols: usize) -> f64 {
        self.vmm_energy(preset, rows, cols) / (rows * cols) as f64
    }

    /// Equivalent digital data-movement energy for the same VMM
    /// (DRAM fetch at ~20 pJ/byte, 4 bytes per operand) — the Von
    /// Neumann comparison point from the paper's introduction.
    pub fn digital_movement_energy(&self, rows: usize, cols: usize) -> f64 {
        let bytes = (rows * cols + rows + cols) as f64 * 4.0;
        bytes * 20e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    #[test]
    fn energy_positive_and_scales_with_array() {
        let m = EnergyModel::default();
        let d = presets::epiram();
        let e32 = m.vmm_energy(&d, 32, 32);
        let e64 = m.vmm_energy(&d, 64, 64);
        assert!(e32 > 0.0);
        assert!((e64 / e32 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn high_r_on_means_low_energy() {
        let m = EnergyModel::default();
        // Ag:a-Si has R_ON = 26 MΩ, AlOx/HfO2 16.9 kΩ: the silver
        // device reads far cheaper.
        let e_ag = m.energy_per_mac(&presets::ag_si(), 32, 32);
        let e_al = m.energy_per_mac(&presets::alox_hfo2(), 32, 32);
        assert!(e_ag < e_al / 100.0);
    }

    #[test]
    fn in_memory_beats_data_movement() {
        // The paper's motivating claim: in-memory VMM avoids the
        // dominant data-movement energy.  Holds for every Table I
        // device except (marginally) the lowest-R_ON ones.
        let m = EnergyModel::default();
        for d in presets::all_presets() {
            let analog = m.vmm_energy(&d, 32, 32);
            let digital = m.digital_movement_energy(32, 32);
            if d.r_on_ohms > 50e3 {
                assert!(analog < digital, "{}", d.name);
            }
        }
    }

    #[test]
    fn per_mac_consistency() {
        let m = EnergyModel::default();
        let d = presets::taox_hfox();
        let total = m.vmm_energy(&d, 32, 32);
        let per = m.energy_per_mac(&d, 32, 32);
        assert!((per * 1024.0 - total).abs() < 1e-18);
    }
}
