//! A single RRAM crossbar array with differential conductance pairs.
//!
//! Semantics mirror the L2 JAX pipeline exactly (`program_crossbar` +
//! `baseline_mismatch_current` + the L1 crossbar read in
//! `python/compile/model.py`); noise enters as explicit standard-normal
//! draws so the native and XLA engines are comparable sample-by-sample.

use crate::device::params::DeviceParams;
use crate::device::pulse::{mismatch_transform, nl_to_curvature, pulse_curve};
use crate::util::rng::Xoshiro256;

use super::kernel;

/// Per-cell noise draws for programming one array: three channels, as
/// in the artifact's `z` input (`z0` C2C+, `z1` C2C-, `z2` mismatch).
#[derive(Debug, Clone)]
pub struct ProgramNoise {
    pub z0: Vec<f32>,
    pub z1: Vec<f32>,
    pub z2: Vec<f32>,
}

impl ProgramNoise {
    /// Zero noise (deterministic programming).
    pub fn zeros(cells: usize) -> Self {
        Self {
            z0: vec![0.0; cells],
            z1: vec![0.0; cells],
            z2: vec![0.0; cells],
        }
    }

    /// Sample from the given RNG in channel order — identical to the
    /// coordinator's artifact-input packing.
    pub fn sample(rng: &mut Xoshiro256, cells: usize) -> Self {
        let mut n = Self {
            z0: vec![0.0; cells],
            z1: vec![0.0; cells],
            z2: vec![0.0; cells],
        };
        rng.fill_normal_f32(&mut n.z0);
        rng.fill_normal_f32(&mut n.z1);
        rng.fill_normal_f32(&mut n.z2);
        n
    }
}

/// Precomputed per-device pulse-curve state, shared across every array
/// programmed under the same `(params, verify)` pair.
///
/// Perf: pulse counts are integers in `[0, S-1]`, so the curve values
/// and `sqrt(s)` live on an S-point grid — build it once per device and
/// reuse it for every sample/tile of a population instead of paying
/// 4 exp() + 2 sqrt() per cell per array.  Direct evaluation remains
/// for very large S (the "ideal" 65536-state device) where the table
/// would cost more than it saves.
#[derive(Debug, Clone)]
pub struct PulseTable {
    kappa_p: f64,
    kappa_d: f64,
    verify: bool,
    /// `(curve_ltp, curve_ltd, sqrt(s))` on the state grid, when tabled.
    grid: Option<(Vec<f64>, Vec<f64>, Vec<f64>)>,
}

impl PulseTable {
    const TABLE_LIMIT: usize = 4096;

    /// Build the table for a device (open-loop when `verify == false`).
    pub fn new(params: &DeviceParams, verify: bool) -> Self {
        let kappa_p = nl_to_curvature(params.nu_ltp);
        let kappa_d = nl_to_curvature(params.nu_ltd);
        let n = params.states - 1.0;
        let grid = if !verify && (params.states as usize) <= Self::TABLE_LIMIT {
            let states = params.states as usize;
            let mut cp = Vec::with_capacity(states);
            let mut cd = Vec::with_capacity(states);
            let mut sq = Vec::with_capacity(states);
            for s in 0..states {
                let t = s as f64 / n;
                cp.push(pulse_curve(t, kappa_p));
                cd.push(pulse_curve(t, kappa_d));
                sq.push((s as f64).sqrt());
            }
            Some((cp, cd, sq))
        } else {
            None
        };
        Self { kappa_p, kappa_d, verify, grid }
    }
}

/// A programmed crossbar array holding normalized differential
/// conductances plus the per-cell mismatch residue.
///
/// Reads go through one fused **column-major** plane
/// (`g_diff + mismatch`, laid out `plane[j*rows + i]`) built at
/// program time, so the hot read loop in [`kernel`] streams
/// unit-stride columns; the row-major planes are kept for inspection,
/// the artifact cross-check, and the programming-side tests.
#[derive(Debug, Clone)]
pub struct CrossbarArray {
    rows: usize,
    cols: usize,
    /// `gp - gn` per cell, row-major (the effective signed weight).
    g_diff: Vec<f32>,
    /// Per-cell mismatch current coefficient (already scaled by `m`).
    mismatch: Vec<f32>,
    /// Fused read plane `g_diff + mismatch`, **column-major**.
    plane: Vec<f32>,
    /// Normalized positive/negative conductances (kept for inspection
    /// and the program-only artifact cross-check).
    gp: Vec<f32>,
    gn: Vec<f32>,
}

impl CrossbarArray {
    /// Program target weights `w` (row-major `rows x cols`, in
    /// `[-1, 1]`) into the array under `params`, consuming the given
    /// noise draws (open-loop, write-verify off — the paper's
    /// benchmark protocol).
    pub fn program(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        noise: &ProgramNoise,
    ) -> Self {
        Self::program_with(rows, cols, w, params, noise, false)
    }

    /// Program with closed-loop write–verify: each cell is iteratively
    /// read back and corrected, so the NL deviation is nulled and the
    /// accumulated C2C walk collapses to a single residual pulse of
    /// disturbance.  The paper (§III) calls this "essential to mitigate
    /// [NL] effects ... in real-world applications"; the in-memory
    /// solvers use it.  Read-path mismatch is unaffected.
    pub fn program_verified(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        noise: &ProgramNoise,
    ) -> Self {
        Self::program_with(rows, cols, w, params, noise, true)
    }

    fn program_with(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        noise: &ProgramNoise,
        verify: bool,
    ) -> Self {
        let table = PulseTable::new(params, verify);
        let mut arr = Self::zeroed(rows, cols);
        arr.reprogram(w, params, noise, &table);
        arr
    }

    /// Allocate an unprogrammed (all-zero) array of the given geometry
    /// — the reusable scratch the parallel engines program in place.
    pub fn zeroed(rows: usize, cols: usize) -> Self {
        let cells = rows * cols;
        Self {
            rows,
            cols,
            g_diff: vec![0.0; cells],
            mismatch: vec![0.0; cells],
            plane: vec![0.0; cells],
            gp: vec![0.0; cells],
            gn: vec![0.0; cells],
        }
    }

    /// Program target weights into this array **in place**, reusing its
    /// buffers and a shared per-device [`PulseTable`].  Numerically
    /// identical to [`CrossbarArray::program`] /
    /// [`CrossbarArray::program_verified`] with the matching table.
    pub fn reprogram(
        &mut self,
        w: &[f32],
        params: &DeviceParams,
        noise: &ProgramNoise,
        table: &PulseTable,
    ) {
        self.reprogram_active(w, params, noise, table, self.rows * self.cols)
    }

    /// Like [`CrossbarArray::reprogram`], but normalizes the per-cycle
    /// severity draw over `active_cells` real device cells.  Tiled edge
    /// arrays pass the unpadded count: their padded lines carry zero
    /// noise, and dividing by the full cell count would dilute the
    /// lognormal cycle severity toward its deterministic limit.
    pub fn reprogram_active(
        &mut self,
        w: &[f32],
        params: &DeviceParams,
        noise: &ProgramNoise,
        table: &PulseTable,
        active_cells: usize,
    ) {
        let cells = self.rows * self.cols;
        assert_eq!(w.len(), cells, "weight buffer size mismatch");
        assert_eq!(noise.z0.len(), cells, "z0 noise plane size mismatch");
        assert_eq!(noise.z1.len(), cells, "z1 noise plane size mismatch");
        assert_eq!(noise.z2.len(), cells, "z2 noise plane size mismatch");

        let n = params.states - 1.0;
        // Linear-in-sigma C2C law, scale fitted once (DESIGN.md §7).
        let acc = params.sigma_c2c * params.k_c2c;
        let m = params.mismatch_scale();

        // Per-array cycle severity: lognormal draw shared by all cells
        // of this programming cycle (mirrors model.SEVERITY_SIGMA).
        const SEVERITY_SIGMA: f64 = 0.6;
        let zeta = noise.z0.iter().map(|&z| z as f64).sum::<f64>()
            / (active_cells.max(1) as f64).sqrt();
        let sev = (SEVERITY_SIGMA * zeta - 0.5 * SEVERITY_SIGMA * SEVERITY_SIGMA).exp();
        let sa = sev * acc;

        // The mode branch (verify / tabled / direct) is hoisted out of
        // the per-cell loop: each mode gets its own branch-free pass
        // over the cells.  Per-cell arithmetic — complementary pulse
        // targets `(1±w)/2` with f32 rounding (mirroring the artifact,
        // which computes in f32), open-loop NL deviation plus
        // severity-scaled pulse-domain C2C noise, clamp to the
        // conductance window — is unchanged bit-for-bit.
        if table.verify {
            // Write-verify nulls the NL deviation and leaves one pulse
            // of residual C2C disturbance.
            for (i, &wv) in w.iter().enumerate() {
                let wi = wv as f64;
                let s_pos = (((1.0 + wi) * 0.5 * n) as f32).round() as f64;
                let s_neg = (((1.0 - wi) * 0.5 * n) as f32).round() as f64;
                let g_pos =
                    (s_pos / n + params.sigma_c2c * noise.z0[i] as f64).clamp(0.0, 1.0);
                let g_neg =
                    (s_neg / n + params.sigma_c2c * noise.z1[i] as f64).clamp(0.0, 1.0);
                self.gp[i] = g_pos as f32;
                self.gn[i] = g_neg as f32;
                self.g_diff[i] = (g_pos - g_neg) as f32;
            }
        } else if let Some((cp, cd, sq)) = &table.grid {
            // Batched table path: pulse counts are integers on the
            // device grid, so curve values and sqrt(s) are lookups.
            for (i, &wv) in w.iter().enumerate() {
                let wi = wv as f64;
                let ip = (((1.0 + wi) * 0.5 * n) as f32).round() as usize;
                let id = (((1.0 - wi) * 0.5 * n) as f32).round() as usize;
                let g_pos = (cp[ip] + sa * sq[ip] * noise.z0[i] as f64).clamp(0.0, 1.0);
                let g_neg = (cd[id] + sa * sq[id] * noise.z1[i] as f64).clamp(0.0, 1.0);
                self.gp[i] = g_pos as f32;
                self.gn[i] = g_neg as f32;
                self.g_diff[i] = (g_pos - g_neg) as f32;
            }
        } else {
            // Direct evaluation for very large state counts.
            for (i, &wv) in w.iter().enumerate() {
                let wi = wv as f64;
                let s_pos = (((1.0 + wi) * 0.5 * n) as f32).round() as f64;
                let s_neg = (((1.0 - wi) * 0.5 * n) as f32).round() as f64;
                let g_pos = (pulse_curve(s_pos / n, table.kappa_p)
                    + sa * s_pos.sqrt() * noise.z0[i] as f64)
                    .clamp(0.0, 1.0);
                let g_neg = (pulse_curve(s_neg / n, table.kappa_d)
                    + sa * s_neg.sqrt() * noise.z1[i] as f64)
                    .clamp(0.0, 1.0);
                self.gp[i] = g_pos as f32;
                self.gn[i] = g_neg as f32;
                self.g_diff[i] = (g_pos - g_neg) as f32;
            }
        }

        // Mismatch residue plane (read-path baseline wander).
        for (mm, z) in self.mismatch.iter_mut().zip(&noise.z2) {
            *mm = (m * mismatch_transform(*z as f64)) as f32;
        }

        // Build the fused column-major read plane once per cycle.
        kernel::fuse_plane(&self.g_diff, &self.mismatch, self.rows, self.cols, &mut self.plane);
    }

    /// Force every cell of column `j` to a stuck differential level —
    /// gross-fault injection for the sharded engine's checksum studies.
    /// `level = ±1` models a rail-stuck bit line, `0.0` a dead (open)
    /// line.  The column's mismatch residue is cleared: a gross defect
    /// dominates the per-cell baseline wander.
    pub fn force_column(&mut self, j: usize, level: f32) {
        assert!(j < self.cols, "column {j} out of range");
        let level = level.clamp(-1.0, 1.0);
        for i in 0..self.rows {
            let idx = i * self.cols + j;
            self.gp[idx] = (1.0 + level) * 0.5;
            self.gn[idx] = (1.0 - level) * 0.5;
            self.g_diff[idx] = level;
            self.mismatch[idx] = 0.0;
        }
        // The stuck column is contiguous in the column-major read
        // plane; `g_diff + mismatch = level + 0.0` exactly.
        self.plane[j * self.rows..(j + 1) * self.rows].fill(level);
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Normalized positive conductances (row-major).
    pub fn gp(&self) -> &[f32] {
        &self.gp
    }

    /// Normalized negative conductances (row-major).
    pub fn gn(&self) -> &[f32] {
        &self.gn
    }

    /// Effective programmed weight of cell `(i, j)` (differential,
    /// without mismatch).
    pub fn weight(&self, i: usize, j: usize) -> f32 {
        self.g_diff[i * self.cols + j]
    }

    /// Fused column-major read plane (`g_diff + mismatch`, laid out
    /// `plane[j*rows + i]`) — the buffer [`kernel::read_columnar`]
    /// consumes.
    pub fn plane(&self) -> &[f32] {
        &self.plane
    }

    /// Analog read: `y[j] = sum_i x[i] * (g_diff + mismatch)[i,j]`,
    /// already decoded to weight units (the differential read cancels
    /// `Gmin` and the decode divides by the range — see DESIGN.md §4).
    ///
    /// Geometry is a `debug_assert!` here: this is the innermost hot
    /// loop, and the engines perform one typed
    /// [`crate::error::Error::Geometry`] check per batch at their
    /// entry points instead of two asserts per tile read.
    pub fn read(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        kernel::read_columnar(&self.plane, self.rows, self.cols, x, y);
    }

    /// Convenience allocating read.
    pub fn read_vec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.cols];
        self.read(x, &mut y);
        y
    }
}

/// Reusable per-worker programming scratch shared by the batch
/// engines: one array, its noise planes, and weight/input gather
/// staging for engines that program sub-blocks of a logical matrix.
/// One instance per pool worker replaces the engines' former ad-hoc
/// scratch structs — zero steady-state allocation on the hot path.
#[derive(Debug)]
pub struct ProgramScratch {
    /// The reusable physical array, programmed in place per job.
    pub arr: CrossbarArray,
    /// Per-cell noise planes staged for [`CrossbarArray::reprogram`].
    pub noise: ProgramNoise,
    /// Weight gather staging (`rows * cols`), for region/tile gathers.
    pub w: Vec<f32>,
    /// Input gather staging (`rows`), zero-padded for partial regions.
    pub x: Vec<f32>,
}

impl ProgramScratch {
    /// Scratch for a `rows x cols` physical array.
    pub fn new(rows: usize, cols: usize) -> Self {
        let cells = rows * cols;
        Self {
            arr: CrossbarArray::zeroed(rows, cols),
            noise: ProgramNoise::zeros(cells),
            w: vec![0.0; cells],
            x: vec![0.0; rows],
        }
    }

    /// Copy three full-size logical noise planes into the scratch
    /// (the whole-matrix engines' staging step).
    pub fn load_noise(&mut self, z: [&[f32]; 3]) {
        self.noise.z0.copy_from_slice(z[0]);
        self.noise.z1.copy_from_slice(z[1]);
        self.noise.z2.copy_from_slice(z[2]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::params::DeviceParams;
    use crate::util::rng::Xoshiro256;

    fn rand_w(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        let mut w = vec![0.0f32; n];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        w
    }

    #[test]
    fn ideal_program_recovers_weights() {
        let mut rng = Xoshiro256::seed_from_u64(101);
        let w = rand_w(&mut rng, 32 * 32);
        let arr = CrossbarArray::program(
            32,
            32,
            &w,
            &DeviceParams::ideal(),
            &ProgramNoise::zeros(32 * 32),
        );
        for (i, &wi) in w.iter().enumerate() {
            assert!(
                (arr.g_diff[i] - wi).abs() < 2e-4,
                "cell {i}: {} vs {wi}",
                arr.g_diff[i]
            );
        }
    }

    #[test]
    fn ideal_read_matches_software_dot() {
        let mut rng = Xoshiro256::seed_from_u64(102);
        let w = rand_w(&mut rng, 32 * 32);
        let mut x = vec![0.0f32; 32];
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let arr = CrossbarArray::program(
            32,
            32,
            &w,
            &DeviceParams::ideal(),
            &ProgramNoise::zeros(32 * 32),
        );
        let y = arr.read_vec(&x);
        for j in 0..32 {
            let want: f32 = (0..32).map(|i| x[i] * w[i * 32 + j]).sum();
            assert!((y[j] - want).abs() < 5e-3, "col {j}: {} vs {want}", y[j]);
        }
    }

    #[test]
    fn complementary_pair_targets_without_noise() {
        let params = DeviceParams::ideal().with_weight_bits(6);
        let w = vec![0.75f32, -0.75, 0.0, 1.0];
        let arr = CrossbarArray::program(2, 2, &w, &params, &ProgramNoise::zeros(4));
        // w = 0.75: gp -> (1+w)/2 = 0.875, gn -> 0.125.
        assert!((arr.gp()[0] - 0.875).abs() < 0.02);
        assert!((arr.gn()[0] - 0.125).abs() < 0.02);
        // Mirror for w = -0.75.
        assert!((arr.gp()[1] - 0.125).abs() < 0.02);
        assert!((arr.gn()[1] - 0.875).abs() < 0.02);
        // Zero weight: both at the midpoint; full scale: gp=1, gn=0.
        assert!((arr.gp()[2] - 0.5).abs() < 0.02);
        assert!((arr.gn()[2] - 0.5).abs() < 0.02);
        assert!((arr.gp()[3] - 1.0).abs() < 1e-6);
        assert_eq!(arr.gn()[3], 0.0);
    }

    #[test]
    fn conductances_always_in_window() {
        let mut rng = Xoshiro256::seed_from_u64(103);
        let params = DeviceParams::ideal()
            .with_weight_bits(5)
            .with_nonlinearity(2.4, -4.88)
            .with_c2c(0.05);
        for trial in 0..10 {
            let w = rand_w(&mut rng, 16 * 16);
            let noise = ProgramNoise::sample(&mut rng, 16 * 16);
            let arr = CrossbarArray::program(16, 16, &w, &params, &noise);
            for i in 0..16 * 16 {
                assert!((0.0..=1.0).contains(&arr.gp()[i]), "trial {trial}");
                assert!((0.0..=1.0).contains(&arr.gn()[i]), "trial {trial}");
            }
        }
    }

    #[test]
    fn c2c_noise_perturbs_programming() {
        let mut rng = Xoshiro256::seed_from_u64(104);
        let params = DeviceParams::ideal().with_weight_bits(7).with_c2c(0.03);
        let w = rand_w(&mut rng, 8 * 8);
        let clean =
            CrossbarArray::program(8, 8, &w, &params, &ProgramNoise::zeros(8 * 8));
        let noise = ProgramNoise::sample(&mut rng, 8 * 8);
        let noisy = CrossbarArray::program(8, 8, &w, &params, &noise);
        let diff: f32 = (0..64)
            .map(|i| (clean.g_diff[i] - noisy.g_diff[i]).abs())
            .sum();
        assert!(diff > 0.01, "c2c must move conductances");
    }

    #[test]
    fn both_devices_accumulate_c2c_noise() {
        // The complementary scheme programs both devices (~n/2 pulses
        // each), so even zero weights carry C2C noise — the mechanism
        // behind the strong Fig. 4/5 degradation.
        let params = DeviceParams::ideal().with_weight_bits(7).with_c2c(0.05);
        let mut rng = Xoshiro256::seed_from_u64(105);
        let noise = ProgramNoise::sample(&mut rng, 4);
        let arr = CrossbarArray::program(2, 2, &[0.0; 4], &params, &noise);
        let moved = (0..4).filter(|&i| arr.g_diff[i] != 0.0).count();
        assert!(moved >= 3, "zero weights must still be noisy: {moved}/4");
    }

    #[test]
    fn read_is_linear_in_x() {
        let mut rng = Xoshiro256::seed_from_u64(106);
        let w = rand_w(&mut rng, 8 * 8);
        let noise = ProgramNoise::sample(&mut rng, 8 * 8);
        let params = DeviceParams::ideal().with_nonlinearity(1.0, -1.0);
        let arr = CrossbarArray::program(8, 8, &w, &params, &noise);
        let mut x1 = vec![0.0f32; 8];
        let mut x2 = vec![0.0f32; 8];
        rng.fill_uniform_f32(&mut x1, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x2, -1.0, 1.0);
        let xsum: Vec<f32> = x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let y1 = arr.read_vec(&x1);
        let y2 = arr.read_vec(&x2);
        let ysum = arr.read_vec(&xsum);
        for j in 0..8 {
            assert!((ysum[j] - y1[j] - y2[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn reprogram_reuses_buffers_and_matches_fresh_program() {
        let mut rng = Xoshiro256::seed_from_u64(107);
        let params = DeviceParams::ideal()
            .with_weight_bits(7)
            .with_nonlinearity(2.4, -4.88)
            .with_c2c(0.035);
        let table = PulseTable::new(&params, false);
        let mut scratch = CrossbarArray::zeroed(16, 16);
        for trial in 0..4 {
            let w = rand_w(&mut rng, 256);
            let noise = ProgramNoise::sample(&mut rng, 256);
            scratch.reprogram(&w, &params, &noise, &table);
            let fresh = CrossbarArray::program(16, 16, &w, &params, &noise);
            assert_eq!(scratch.gp(), fresh.gp(), "trial {trial}");
            assert_eq!(scratch.gn(), fresh.gn(), "trial {trial}");
            assert_eq!(scratch.g_diff, fresh.g_diff);
            assert_eq!(scratch.mismatch, fresh.mismatch);
        }
    }

    #[test]
    fn verified_table_matches_program_verified() {
        let mut rng = Xoshiro256::seed_from_u64(108);
        let params = DeviceParams::ideal().with_weight_bits(6).with_c2c(0.02);
        let w = rand_w(&mut rng, 64);
        let noise = ProgramNoise::sample(&mut rng, 64);
        let table = PulseTable::new(&params, true);
        let mut scratch = CrossbarArray::zeroed(8, 8);
        scratch.reprogram(&w, &params, &noise, &table);
        let fresh = CrossbarArray::program_verified(8, 8, &w, &params, &noise);
        assert_eq!(scratch.gp(), fresh.gp());
        assert_eq!(scratch.gn(), fresh.gn());
    }

    #[test]
    fn force_column_sticks_reads_at_level() {
        let mut rng = Xoshiro256::seed_from_u64(109);
        let w = rand_w(&mut rng, 8 * 8);
        let noise = ProgramNoise::sample(&mut rng, 8 * 8);
        let params = DeviceParams::ideal().with_c2c(0.02);
        let mut arr = CrossbarArray::program(8, 8, &w, &params, &noise);
        let before = arr.clone();
        arr.force_column(3, 1.0);
        let mut x = vec![0.0f32; 8];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let y = arr.read_vec(&x);
        let want: f32 = x.iter().sum();
        assert!((y[3] - want).abs() < 1e-5, "{} vs {want}", y[3]);
        // Other columns are untouched.
        let y_before = before.read_vec(&x);
        for j in [0usize, 1, 2, 4, 5, 6, 7] {
            assert_eq!(y[j], y_before[j], "col {j}");
            assert_eq!(arr.weight(2, j), before.weight(2, j));
        }
    }

    #[test]
    fn fused_plane_tracks_programmed_conductances() {
        let mut rng = Xoshiro256::seed_from_u64(110);
        let params = DeviceParams::ideal().with_weight_bits(6).with_c2c(0.03);
        let w = rand_w(&mut rng, 12 * 7);
        let noise = ProgramNoise::sample(&mut rng, 12 * 7);
        let arr = CrossbarArray::program(12, 7, &w, &params, &noise);
        for i in 0..12 {
            for j in 0..7 {
                let want = arr.g_diff[i * 7 + j] + arr.mismatch[i * 7 + j];
                assert_eq!(arr.plane()[j * 12 + i], want, "cell ({i},{j})");
            }
        }
        // The read is exactly the kernel reference over the plane.
        let mut x = vec![0.0f32; 12];
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let mut want = vec![0.0f32; 7];
        super::kernel::read_reference(arr.plane(), 12, 7, &x, &mut want);
        assert_eq!(arr.read_vec(&x), want);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn wrong_buffer_size_panics() {
        CrossbarArray::program(
            4,
            4,
            &[0.0; 15],
            &DeviceParams::ideal(),
            &ProgramNoise::zeros(16),
        );
    }
}
