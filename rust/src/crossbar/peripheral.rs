//! Peripheral circuit models: DAC quantization on the word-line drive
//! and ADC quantization on the bit-line readout.
//!
//! The paper's protocol uses ideal peripherals (the error analysis
//! isolates device physics), so both default to **off**; the ablation
//! bench (`meliso run ablation-adc`) switches them on to show where
//! peripheral precision starts to dominate device error — the
//! NeuroSim+ heritage the paper builds on.

/// DAC/ADC configuration.  `None` bits = ideal (infinite precision).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Peripherals {
    pub dac_bits: Option<u32>,
    pub adc_bits: Option<u32>,
}

impl Peripherals {
    pub const IDEAL: Peripherals = Peripherals { dac_bits: None, adc_bits: None };

    pub fn with_dac(mut self, bits: u32) -> Self {
        self.dac_bits = Some(bits);
        self
    }

    pub fn with_adc(mut self, bits: u32) -> Self {
        self.adc_bits = Some(bits);
        self
    }

    /// Quantize an input voltage in `[-1, 1]` through the DAC.
    pub fn dac(&self, x: f32) -> f32 {
        match self.dac_bits {
            None => x,
            Some(bits) => quantize_symmetric(x, bits, 1.0),
        }
    }

    /// Quantize a bit-line readout through the ADC with full-scale
    /// range `fs` (outputs clamp at the rails, as real ADCs do).
    pub fn adc(&self, y: f32, fs: f32) -> f32 {
        match self.adc_bits {
            None => y,
            Some(bits) => quantize_symmetric(y, bits, fs),
        }
    }

    /// Apply the DAC to a whole drive vector.
    pub fn dac_vec(&self, x: &mut [f32]) {
        if self.dac_bits.is_some() {
            for v in x.iter_mut() {
                *v = self.dac(*v);
            }
        }
    }

    /// Apply the ADC to a whole readout vector.
    pub fn adc_vec(&self, y: &mut [f32], fs: f32) {
        if self.adc_bits.is_some() {
            for v in y.iter_mut() {
                *v = self.adc(*v, fs);
            }
        }
    }
}

/// Symmetric uniform quantizer with **exactly `2^bits` codes**: the
/// two's-complement mid-tread grid `k * step` for
/// `k in [-2^(bits-1), 2^(bits-1) - 1]`, step `2*fs / 2^bits`.  Zero is
/// a code, the bottom rail `-fs` is a code, and the top code is
/// `fs - step` — an N-bit converter cannot represent both rails.
/// (The previous mid-rise variant emitted `2^bits + 1` levels: its
/// positive clamp at `fs - step/2` still rounded up to `+fs`.)
fn quantize_symmetric(x: f32, bits: u32, fs: f32) -> f32 {
    if bits == 0 {
        return 0.0;
    }
    let half_codes = (1u64 << (bits - 1)) as f32;
    let step = fs / half_codes;
    let code = (x / step).round().clamp(-half_codes, half_codes - 1.0);
    code * step
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_identity() {
        let p = Peripherals::IDEAL;
        assert_eq!(p.dac(0.3333), 0.3333);
        assert_eq!(p.adc(-7.77, 32.0), -7.77);
    }

    #[test]
    fn dac_quantizes_to_grid() {
        let p = Peripherals::default().with_dac(3); // 8 levels, step 0.25
        let q = p.dac(0.3);
        assert!((q - 0.25).abs() < 1e-6, "q={q}");
        let q = p.dac(-0.9999);
        assert!(q >= -1.0);
    }

    #[test]
    fn adc_clamps_at_rails() {
        let p = Peripherals::default().with_adc(4);
        assert!(p.adc(100.0, 8.0) <= 8.0);
        assert!(p.adc(-100.0, 8.0) >= -8.0);
    }

    #[test]
    fn more_bits_less_error() {
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 / 999.0) * 2.0 - 1.0).collect();
        let err = |bits: u32| -> f32 {
            let p = Peripherals::default().with_dac(bits);
            xs.iter().map(|&x| (p.dac(x) - x).abs()).sum::<f32>() / xs.len() as f32
        };
        assert!(err(2) > err(4));
        assert!(err(4) > err(8));
        assert!(err(8) < 0.005);
    }

    #[test]
    fn quantizer_emits_exactly_two_pow_bits_codes() {
        // The bug this guards against: the old mid-rise grid emitted
        // 2^bits + 1 levels because both rails were representable.
        for bits in [1u32, 2, 3, 5] {
            let p = Peripherals::default().with_dac(bits);
            let mut codes: Vec<i64> = (0..=20_000)
                .map(|i| {
                    let x = (i as f32 / 10_000.0) - 1.0; // [-1, 1]
                    (p.dac(x) * 1e6).round() as i64
                })
                .collect();
            codes.sort_unstable();
            codes.dedup();
            assert_eq!(codes.len(), 1usize << bits, "bits={bits}");
        }
        // Top code is fs - step, bottom code is -fs.
        let p = Peripherals::default().with_adc(4);
        let fs = 8.0f32;
        let step = 2.0 * fs / 16.0;
        assert_eq!(p.adc(fs, fs), fs - step);
        assert_eq!(p.adc(1e9, fs), fs - step);
        assert_eq!(p.adc(-fs, fs), -fs);
    }

    #[test]
    fn quantizer_is_idempotent() {
        let p = Peripherals::default().with_adc(5);
        for x in [-3.0f32, -0.2, 0.0, 1.7] {
            let once = p.adc(x, 4.0);
            let twice = p.adc(once, 4.0);
            assert_eq!(once, twice);
        }
    }

    #[test]
    fn vec_helpers_apply_elementwise() {
        let p = Peripherals::default().with_dac(2).with_adc(2);
        let mut x = vec![0.3f32, -0.8];
        p.dac_vec(&mut x);
        assert_eq!(x[0], p.dac(0.3));
        let mut y = vec![1.3f32, -2.9];
        p.adc_vec(&mut y, 4.0);
        assert_eq!(y[1], p.adc(-2.9, 4.0));
    }
}
