//! Tiling: map a weight matrix larger than one physical array onto a
//! grid of 32x32 crossbars, with per-tile programming and summed
//! partial currents.  This is what lets the in-memory linear solvers
//! (`solver`) run systems bigger than the paper's 32x32 protocol.

use crate::device::params::DeviceParams;
use crate::util::rng::Xoshiro256;

use super::array::{CrossbarArray, ProgramNoise, PulseTable};

/// A logical matrix mapped onto a grid of physical crossbar tiles.
#[derive(Debug)]
pub struct TiledCrossbar {
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    grid_r: usize,
    grid_c: usize,
    tiles: Vec<CrossbarArray>,
}

impl TiledCrossbar {
    /// Program an arbitrary `rows x cols` weight matrix (row-major,
    /// values in `[-1, 1]`) onto `tile_rows x tile_cols` arrays.
    /// Partial tiles are zero-padded (zero weights cost zero pulses,
    /// matching real deployments that ground unused lines).
    pub fn program(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
    ) -> Self {
        Self::program_with(rows, cols, w, params, tile_rows, tile_cols, rng, false)
    }

    /// Tiled programming with closed-loop write–verify (see
    /// [`CrossbarArray::program_verified`]).
    #[allow(clippy::too_many_arguments)]
    pub fn program_verified(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
    ) -> Self {
        Self::program_with(rows, cols, w, params, tile_rows, tile_cols, rng, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn program_with(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
        verify: bool,
    ) -> Self {
        assert_eq!(w.len(), rows * cols);
        assert!(tile_rows > 0 && tile_cols > 0);
        let grid_r = rows.div_ceil(tile_rows);
        let grid_c = cols.div_ceil(tile_cols);
        let mut tiles = Vec::with_capacity(grid_r * grid_c);
        let cells = tile_rows * tile_cols;
        // One pulse table for the whole grid (device is shared).
        let table = PulseTable::new(params, verify);
        let mut tw = vec![0.0f32; cells];

        for tr in 0..grid_r {
            for tc in 0..grid_c {
                gather_tile(w, rows, cols, tile_rows, tile_cols, tr, tc, &mut tw);
                let noise = ProgramNoise::sample(rng, cells);
                let mut arr = CrossbarArray::zeroed(tile_rows, tile_cols);
                arr.reprogram(&tw, params, &noise, &table);
                tiles.push(arr);
            }
        }
        Self { rows, cols, tile_rows, tile_cols, grid_r, grid_c, tiles }
    }

    /// Program with **explicit** per-cell noise planes over the logical
    /// `rows x cols` geometry (`z0` C2C+, `z1` C2C-, `z2` mismatch, all
    /// row-major `rows * cols`), instead of drawing from an RNG.  This
    /// is the engine-batch contract: each tile's physics is a function
    /// of its slice of the logical noise; padded cells get zero noise
    /// (grounded lines) and are excluded from the per-cycle severity
    /// normalization.  With `rows == tile_rows` and `cols == tile_cols`
    /// the result is bit-identical to a single
    /// [`CrossbarArray::reprogram`] on the same inputs.
    #[allow(clippy::too_many_arguments)]
    pub fn program_with_noise(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        z: [&[f32]; 3],
        table: &PulseTable,
    ) -> Self {
        assert_eq!(w.len(), rows * cols);
        assert!(tile_rows > 0 && tile_cols > 0);
        for plane in &z {
            assert_eq!(plane.len(), rows * cols, "noise plane size mismatch");
        }
        let grid_r = rows.div_ceil(tile_rows);
        let grid_c = cols.div_ceil(tile_cols);
        let mut tiles = Vec::with_capacity(grid_r * grid_c);
        let mut scratch = TileScratch::new(tile_rows, tile_cols);

        for tr in 0..grid_r {
            for tc in 0..grid_c {
                scratch.program_tile(rows, cols, w, params, z, table, tr, tc);
                tiles.push(scratch.arr.clone());
            }
        }
        Self { rows, cols, tile_rows, tile_cols, grid_r, grid_c, tiles }
    }

    /// Streaming tiled VMM `y = x^T W` with explicit noise planes:
    /// program each tile into the reusable `scratch` array, read its
    /// partial product, and accumulate — same tile order and arithmetic
    /// as [`TiledCrossbar::program_with_noise`] followed by
    /// [`TiledCrossbar::read`], without materializing the grid.  This
    /// is the engines' hot path: zero steady-state allocation.
    #[allow(clippy::too_many_arguments)]
    pub fn vmm_with_noise(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        z: [&[f32]; 3],
        table: &PulseTable,
        x: &[f32],
        y: &mut [f32],
        scratch: &mut TileScratch,
    ) {
        assert_eq!(w.len(), rows * cols);
        assert_eq!(x.len(), rows);
        assert_eq!(y.len(), cols);
        for plane in &z {
            assert_eq!(plane.len(), rows * cols, "noise plane size mismatch");
        }
        let (tile_rows, tile_cols) = (scratch.tile_rows, scratch.tile_cols);
        let grid_r = rows.div_ceil(tile_rows);
        let grid_c = cols.div_ceil(tile_cols);
        y.fill(0.0);
        for tr in 0..grid_r {
            let r0 = tr * tile_rows;
            let rlen = tile_rows.min(rows - r0);
            scratch.tx.fill(0.0);
            scratch.tx[..rlen].copy_from_slice(&x[r0..r0 + rlen]);
            for tc in 0..grid_c {
                scratch.program_tile(rows, cols, w, params, z, table, tr, tc);
                scratch.arr.read(&scratch.tx, &mut scratch.ty);
                let c0 = tc * tile_cols;
                let clen = tile_cols.min(cols - c0);
                for j in 0..clen {
                    y[c0 + j] += scratch.ty[j];
                }
            }
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Physical tile row count (per-worker read scratch is sized off
    /// this).
    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    /// Physical tile column count.
    pub fn tile_cols(&self) -> usize {
        self.tile_cols
    }

    /// Full VMM `y = x^T W` by summing partial currents across the
    /// tile grid (bit-line current summation across tile rows).
    ///
    /// Convenience wrapper that allocates its staging buffers once per
    /// call; hot loops (the serving read path) use
    /// [`TiledCrossbar::read_with`] with per-worker scratch instead.
    pub fn read(&self, x: &[f32], y: &mut [f32]) {
        let mut tx = vec![0.0f32; self.tile_rows];
        let mut ty = vec![0.0f32; self.tile_cols];
        self.read_with(x, y, &mut tx, &mut ty);
    }

    /// Allocation-free tiled read into caller-owned staging buffers
    /// (`tx` of length [`TiledCrossbar::tile_rows`], `ty` of length
    /// [`TiledCrossbar::tile_cols`]).  Geometry is `debug_assert!`-ed:
    /// callers validate once per batch at their entry points.
    pub fn read_with(&self, x: &[f32], y: &mut [f32], tx: &mut [f32], ty: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        debug_assert_eq!(tx.len(), self.tile_rows);
        debug_assert_eq!(ty.len(), self.tile_cols);
        y.fill(0.0);
        for tr in 0..self.grid_r {
            let r0 = tr * self.tile_rows;
            let rlen = self.tile_rows.min(self.rows - r0);
            // Zero-padded input slice for this tile row.
            tx.fill(0.0);
            tx[..rlen].copy_from_slice(&x[r0..r0 + rlen]);
            for tc in 0..self.grid_c {
                let tile = &self.tiles[tr * self.grid_c + tc];
                tile.read(tx, ty);
                let c0 = tc * self.tile_cols;
                let clen = self.tile_cols.min(self.cols - c0);
                for j in 0..clen {
                    y[c0 + j] += ty[j];
                }
            }
        }
    }

    pub fn read_vec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.cols];
        self.read(x, &mut y);
        y
    }
}

/// Reusable per-worker buffers for tiled programming and streaming
/// VMMs: one physical array, its noise planes, and the gather/read
/// staging vectors.  Engines keep one per pool worker.
#[derive(Debug)]
pub struct TileScratch {
    tile_rows: usize,
    tile_cols: usize,
    arr: CrossbarArray,
    noise: ProgramNoise,
    tw: Vec<f32>,
    tx: Vec<f32>,
    ty: Vec<f32>,
}

impl TileScratch {
    pub fn new(tile_rows: usize, tile_cols: usize) -> Self {
        assert!(tile_rows > 0 && tile_cols > 0);
        let cells = tile_rows * tile_cols;
        Self {
            tile_rows,
            tile_cols,
            arr: CrossbarArray::zeroed(tile_rows, tile_cols),
            noise: ProgramNoise::zeros(cells),
            tw: vec![0.0; cells],
            tx: vec![0.0; tile_rows],
            ty: vec![0.0; tile_cols],
        }
    }

    /// Gather tile `(tr, tc)` of the logical weight/noise planes and
    /// program it into the scratch array, normalizing the cycle
    /// severity over the tile's real (unpadded) cells.
    #[allow(clippy::too_many_arguments)]
    fn program_tile(
        &mut self,
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        z: [&[f32]; 3],
        table: &PulseTable,
        tr: usize,
        tc: usize,
    ) {
        let (tile_rows, tile_cols) = (self.tile_rows, self.tile_cols);
        gather_tile(w, rows, cols, tile_rows, tile_cols, tr, tc, &mut self.tw);
        gather_tile(z[0], rows, cols, tile_rows, tile_cols, tr, tc, &mut self.noise.z0);
        gather_tile(z[1], rows, cols, tile_rows, tile_cols, tr, tc, &mut self.noise.z1);
        gather_tile(z[2], rows, cols, tile_rows, tile_cols, tr, tc, &mut self.noise.z2);
        let rlen = tile_rows.min(rows - tr * tile_rows);
        let clen = tile_cols.min(cols - tc * tile_cols);
        self.arr
            .reprogram_active(&self.tw, params, &self.noise, table, rlen * clen);
    }
}

/// Copy tile `(tr, tc)` of a logical `rows x cols` plane into a
/// `tile_rows x tile_cols` buffer, zero-filling padded cells.
#[allow(clippy::too_many_arguments)]
fn gather_tile(
    src: &[f32],
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    tr: usize,
    tc: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), tile_rows * tile_cols);
    out.fill(0.0);
    for i in 0..tile_rows {
        let gi = tr * tile_rows + i;
        if gi >= rows {
            break;
        }
        for j in 0..tile_cols {
            let gj = tc * tile_cols + j;
            if gj >= cols {
                break;
            }
            out[i * tile_cols + j] = src[gi * cols + gj];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::params::DeviceParams;

    fn software_vmm(rows: usize, cols: usize, w: &[f32], x: &[f32]) -> Vec<f32> {
        (0..cols)
            .map(|j| (0..rows).map(|i| x[i] * w[i * cols + j]).sum())
            .collect()
    }

    #[test]
    fn exact_tiling_matches_software() {
        let mut rng = Xoshiro256::seed_from_u64(111);
        let (rows, cols) = (64, 96);
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(
            rows,
            cols,
            &w,
            &DeviceParams::ideal(),
            32,
            32,
            &mut rng,
        );
        assert_eq!(t.tile_count(), 2 * 3);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 0.02, "col {j}: {} vs {}", y[j], want[j]);
        }
    }

    #[test]
    fn ragged_tiling_matches_software() {
        let mut rng = Xoshiro256::seed_from_u64(112);
        let (rows, cols) = (50, 41); // not multiples of 32
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(
            rows,
            cols,
            &w,
            &DeviceParams::ideal(),
            32,
            32,
            &mut rng,
        );
        assert_eq!(t.tile_count(), 2 * 2);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 0.02);
        }
    }

    #[test]
    fn single_tile_degenerates_to_array() {
        let mut rng = Xoshiro256::seed_from_u64(113);
        let mut w = vec![0.0f32; 16 * 16];
        let mut x = vec![0.0f32; 16];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(
            16,
            16,
            &w,
            &DeviceParams::ideal(),
            32,
            32,
            &mut rng,
        );
        assert_eq!(t.tile_count(), 1);
        let y = t.read_vec(&x);
        let want = software_vmm(16, 16, &w, &x);
        for j in 0..16 {
            assert!((y[j] - want[j]).abs() < 0.01);
        }
    }

    #[test]
    fn explicit_noise_single_tile_matches_plain_array() {
        let mut rng = Xoshiro256::seed_from_u64(115);
        let params = crate::device::presets::ag_si().params;
        let cells = 32 * 32;
        let mut w = vec![0.0f32; cells];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let noise = ProgramNoise::sample(&mut rng, cells);
        let table = PulseTable::new(&params, false);
        let t = TiledCrossbar::program_with_noise(
            32,
            32,
            &w,
            &params,
            32,
            32,
            [&noise.z0, &noise.z1, &noise.z2],
            &table,
        );
        assert_eq!(t.tile_count(), 1);
        let arr = CrossbarArray::program(32, 32, &w, &params, &noise);
        let mut x = vec![0.0f32; 32];
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        assert_eq!(t.read_vec(&x), arr.read_vec(&x));
    }

    #[test]
    fn explicit_noise_tiling_still_approximates_software() {
        let mut rng = Xoshiro256::seed_from_u64(116);
        let params = crate::device::presets::epiram().params;
        let (rows, cols) = (80, 48); // ragged 3x2 grid
        let n = rows * cols;
        let mut w = vec![0.0f32; n];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let mut z = vec![0.0f32; 3 * n];
        rng.fill_normal_f32(&mut z);
        let table = PulseTable::new(&params, false);
        let t = TiledCrossbar::program_with_noise(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            [&z[..n], &z[n..2 * n], &z[2 * n..]],
            &table,
        );
        assert_eq!(t.tile_count(), 3 * 2);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 10.0, "col {j}: {} vs {}", y[j], want[j]);
        }
    }

    #[test]
    fn streaming_vmm_matches_materialized_grid() {
        let mut rng = Xoshiro256::seed_from_u64(117);
        let params = crate::device::presets::ag_si().params;
        let (rows, cols) = (80, 48); // ragged grid incl. padded tiles
        let n = rows * cols;
        let mut w = vec![0.0f32; n];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let mut z = vec![0.0f32; 3 * n];
        rng.fill_normal_f32(&mut z);
        let planes = [&z[..n], &z[n..2 * n], &z[2 * n..]];
        let table = PulseTable::new(&params, false);

        let grid =
            TiledCrossbar::program_with_noise(rows, cols, &w, &params, 32, 32, planes, &table);
        let want = grid.read_vec(&x);

        let mut scratch = TileScratch::new(32, 32);
        let mut y = vec![0.0f32; cols];
        TiledCrossbar::vmm_with_noise(
            rows, cols, &w, &params, planes, &table, &x, &mut y, &mut scratch,
        );
        assert_eq!(y, want);

        // Scratch reuse across calls must not leak state.
        let mut y2 = vec![0.0f32; cols];
        TiledCrossbar::vmm_with_noise(
            rows, cols, &w, &params, planes, &table, &x, &mut y2, &mut scratch,
        );
        assert_eq!(y2, want);
    }

    #[test]
    fn noisy_device_still_approximates() {
        let mut rng = Xoshiro256::seed_from_u64(114);
        let params = crate::device::presets::epiram().params;
        let (rows, cols) = (64, 64);
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(rows, cols, &w, &params, 32, 32, &mut rng);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        // EpiRAM-class device on a 64-row sum: per-output error std is
        // ~2 (accumulated C2C over both tiles); 4-sigma bound.
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 8.0, "col {j}: {} vs {}", y[j], want[j]);
        }
    }
}
