//! Tiling: map a weight matrix larger than one physical array onto a
//! grid of 32x32 crossbars, with per-tile programming and summed
//! partial currents.  This is what lets the in-memory linear solvers
//! (`solver`) run systems bigger than the paper's 32x32 protocol.

use crate::device::params::DeviceParams;
use crate::util::rng::Xoshiro256;

use super::array::{CrossbarArray, ProgramNoise};

/// A logical matrix mapped onto a grid of physical crossbar tiles.
#[derive(Debug)]
pub struct TiledCrossbar {
    rows: usize,
    cols: usize,
    tile_rows: usize,
    tile_cols: usize,
    grid_r: usize,
    grid_c: usize,
    tiles: Vec<CrossbarArray>,
}

impl TiledCrossbar {
    /// Program an arbitrary `rows x cols` weight matrix (row-major,
    /// values in `[-1, 1]`) onto `tile_rows x tile_cols` arrays.
    /// Partial tiles are zero-padded (zero weights cost zero pulses,
    /// matching real deployments that ground unused lines).
    pub fn program(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
    ) -> Self {
        Self::program_with(rows, cols, w, params, tile_rows, tile_cols, rng, false)
    }

    /// Tiled programming with closed-loop write–verify (see
    /// [`CrossbarArray::program_verified`]).
    #[allow(clippy::too_many_arguments)]
    pub fn program_verified(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
    ) -> Self {
        Self::program_with(rows, cols, w, params, tile_rows, tile_cols, rng, true)
    }

    #[allow(clippy::too_many_arguments)]
    fn program_with(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
        verify: bool,
    ) -> Self {
        assert_eq!(w.len(), rows * cols);
        assert!(tile_rows > 0 && tile_cols > 0);
        let grid_r = rows.div_ceil(tile_rows);
        let grid_c = cols.div_ceil(tile_cols);
        let mut tiles = Vec::with_capacity(grid_r * grid_c);
        let cells = tile_rows * tile_cols;

        for tr in 0..grid_r {
            for tc in 0..grid_c {
                let mut tw = vec![0.0f32; cells];
                for i in 0..tile_rows {
                    let gi = tr * tile_rows + i;
                    if gi >= rows {
                        break;
                    }
                    for j in 0..tile_cols {
                        let gj = tc * tile_cols + j;
                        if gj >= cols {
                            break;
                        }
                        tw[i * tile_cols + j] = w[gi * cols + gj];
                    }
                }
                let noise = ProgramNoise::sample(rng, cells);
                tiles.push(if verify {
                    CrossbarArray::program_verified(tile_rows, tile_cols, &tw, params, &noise)
                } else {
                    CrossbarArray::program(tile_rows, tile_cols, &tw, params, &noise)
                });
            }
        }
        Self { rows, cols, tile_rows, tile_cols, grid_r, grid_c, tiles }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }

    /// Full VMM `y = x^T W` by summing partial currents across the
    /// tile grid (bit-line current summation across tile rows).
    pub fn read(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        let mut ty = vec![0.0f32; self.tile_cols];
        for tr in 0..self.grid_r {
            let r0 = tr * self.tile_rows;
            let rlen = self.tile_rows.min(self.rows - r0);
            // Zero-padded input slice for this tile row.
            let mut tx = vec![0.0f32; self.tile_rows];
            tx[..rlen].copy_from_slice(&x[r0..r0 + rlen]);
            for tc in 0..self.grid_c {
                let tile = &self.tiles[tr * self.grid_c + tc];
                tile.read(&tx, &mut ty);
                let c0 = tc * self.tile_cols;
                let clen = self.tile_cols.min(self.cols - c0);
                for j in 0..clen {
                    y[c0 + j] += ty[j];
                }
            }
        }
    }

    pub fn read_vec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.cols];
        self.read(x, &mut y);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::params::DeviceParams;

    fn software_vmm(rows: usize, cols: usize, w: &[f32], x: &[f32]) -> Vec<f32> {
        (0..cols)
            .map(|j| (0..rows).map(|i| x[i] * w[i * cols + j]).sum())
            .collect()
    }

    #[test]
    fn exact_tiling_matches_software() {
        let mut rng = Xoshiro256::seed_from_u64(111);
        let (rows, cols) = (64, 96);
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(
            rows,
            cols,
            &w,
            &DeviceParams::ideal(),
            32,
            32,
            &mut rng,
        );
        assert_eq!(t.tile_count(), 2 * 3);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 0.02, "col {j}: {} vs {}", y[j], want[j]);
        }
    }

    #[test]
    fn ragged_tiling_matches_software() {
        let mut rng = Xoshiro256::seed_from_u64(112);
        let (rows, cols) = (50, 41); // not multiples of 32
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(
            rows,
            cols,
            &w,
            &DeviceParams::ideal(),
            32,
            32,
            &mut rng,
        );
        assert_eq!(t.tile_count(), 2 * 2);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 0.02);
        }
    }

    #[test]
    fn single_tile_degenerates_to_array() {
        let mut rng = Xoshiro256::seed_from_u64(113);
        let mut w = vec![0.0f32; 16 * 16];
        let mut x = vec![0.0f32; 16];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(
            16,
            16,
            &w,
            &DeviceParams::ideal(),
            32,
            32,
            &mut rng,
        );
        assert_eq!(t.tile_count(), 1);
        let y = t.read_vec(&x);
        let want = software_vmm(16, 16, &w, &x);
        for j in 0..16 {
            assert!((y[j] - want[j]).abs() < 0.01);
        }
    }

    #[test]
    fn noisy_device_still_approximates() {
        let mut rng = Xoshiro256::seed_from_u64(114);
        let params = crate::device::presets::epiram().params;
        let (rows, cols) = (64, 64);
        let mut w = vec![0.0f32; rows * cols];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, -1.0, 1.0);
        let t = TiledCrossbar::program(rows, cols, &w, &params, 32, 32, &mut rng);
        let y = t.read_vec(&x);
        let want = software_vmm(rows, cols, &w, &x);
        // EpiRAM-class device on a 64-row sum: per-output error std is
        // ~2 (accumulated C2C over both tiles); 4-sigma bound.
        for j in 0..cols {
            assert!((y[j] - want[j]).abs() < 8.0, "col {j}: {} vs {}", y[j], want[j]);
        }
    }
}
