//! Crossbar-array substrate: differential-pair programming of a
//! weight matrix into conductances, analog read, tiling of matrices
//! larger than one physical array, peripheral (DAC/ADC) quantization,
//! and a read-energy model.

pub mod array;
pub mod energy;
pub mod kernel;
pub mod peripheral;
pub mod tile;

pub use array::{CrossbarArray, ProgramNoise, ProgramScratch, PulseTable};
pub use energy::EnergyModel;
pub use peripheral::Peripherals;
pub use tile::TiledCrossbar;
