//! Columnar VMM inner loops: the single read kernel every engine
//! routes through, plus its retained scalar reference.
//!
//! ## Layout
//!
//! [`super::array::CrossbarArray`] keeps one fused read plane
//! `g_diff + mismatch` in **column-major** order
//! (`plane[j * rows + i]`), built once at program time.  A read is
//! then `cols` independent dot products over contiguous columns —
//! half the memory traffic of the old row-major
//! `g_diff`/`mismatch` pair, with unit-stride streaming access.
//!
//! ## Accumulation-order contract
//!
//! The dot product is lane-blocked with a fixed lane width
//! ([`LANES`]): rows are consumed in chunks of `LANES` with one f32
//! partial accumulator per lane, the lane accumulators are combined
//! by a fixed pairwise tree, and the non-multiple tail is accumulated
//! left-to-right and added last:
//!
//! ```text
//! a[l] = sum_k x[k*LANES + l] * col[k*LANES + l]      (per lane)
//! y    = ((a0+a1) + (a2+a3)) + ((a4+a5) + (a6+a7)) + tail
//! ```
//!
//! Every engine, tile, shard, and thread count performs exactly this
//! operation order, so the bit-identity invariants (`Fixed(1) ==
//! Auto`, cached == uncached, sharded 1x1 == native) hold by
//! construction.  Zero inputs are **not** skipped: an `x[i] == 0` row
//! contributes `0.0 * g`, which never changes a finite f32 sum (it
//! can only flip the sign of a zero, and `-0.0 == 0.0`).  The
//! independent per-lane accumulators are what lets the compiler keep
//! the loop in SIMD registers without reassociating f32 math.
//!
//! [`dot_reference`]/[`read_reference`] are the naive indexed
//! transcription of this contract; `prop_kernel_matches_reference`
//! (in `rust/tests/proptests.rs`) holds the optimized kernel to exact
//! bit-equality against them over random geometries, including ragged
//! non-lane-multiple row counts.

/// Fixed kernel lane width (f32 lanes per accumulator block).
///
/// Part of the numeric contract: changing it changes every simulated
/// read, so it is a constant, not a tuning knob.
pub const LANES: usize = 8;

/// Fixed pairwise reduction of the lane accumulators plus the tail.
#[inline]
fn reduce(acc: [f32; LANES], tail: f32) -> f32 {
    let s01 = acc[0] + acc[1];
    let s23 = acc[2] + acc[3];
    let s45 = acc[4] + acc[5];
    let s67 = acc[6] + acc[7];
    let lo = s01 + s23;
    let hi = s45 + s67;
    lo + hi + tail
}

/// Lane-blocked dot product of `x` against one contiguous column.
///
/// Branch-free inner loop (no zero-skip, no bounds checks after the
/// slice split); the accumulation order is the module contract.
#[inline]
pub fn dot(x: &[f32], col: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), col.len());
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut cc = col.chunks_exact(LANES);
    for (xs, cs) in xc.by_ref().zip(cc.by_ref()) {
        for (a, (&xv, &cv)) in acc.iter_mut().zip(xs.iter().zip(cs)) {
            *a += xv * cv;
        }
    }
    let mut tail = 0.0f32;
    for (&xv, &cv) in xc.remainder().iter().zip(cc.remainder()) {
        tail += xv * cv;
    }
    reduce(acc, tail)
}

/// Full columnar read: `y[j] = dot(x, plane[:, j])` for every column
/// of a column-major `rows x cols` plane.  This is the sole read
/// implementation behind [`super::array::CrossbarArray::read`].
#[inline]
pub fn read_columnar(plane: &[f32], rows: usize, cols: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(plane.len(), rows * cols);
    debug_assert_eq!(x.len(), rows);
    debug_assert_eq!(y.len(), cols);
    for (j, yj) in y.iter_mut().enumerate() {
        *yj = dot(x, &plane[j * rows..(j + 1) * rows]);
    }
}

/// Fuse the row-major differential and mismatch planes into the
/// column-major read plane:
/// `plane[j*rows + i] = g_diff[i*cols + j] + mismatch[i*cols + j]`.
///
/// Runs once per programming cycle; the per-cell f32 add here is the
/// same add the old read path performed on every read.
pub fn fuse_plane(g_diff: &[f32], mismatch: &[f32], rows: usize, cols: usize, plane: &mut [f32]) {
    debug_assert_eq!(g_diff.len(), rows * cols);
    debug_assert_eq!(mismatch.len(), rows * cols);
    debug_assert_eq!(plane.len(), rows * cols);
    for i in 0..rows {
        let row_d = &g_diff[i * cols..(i + 1) * cols];
        let row_m = &mismatch[i * cols..(i + 1) * cols];
        for (j, (&d, &mm)) in row_d.iter().zip(row_m).enumerate() {
            plane[j * rows + i] = d + mm;
        }
    }
}

/// Naive indexed transcription of the lane-accumulation contract —
/// the executable spec [`dot`] must match **bit-for-bit**.  Kept
/// scalar and index-based on purpose; do not "optimize" it.
#[allow(clippy::needless_range_loop)]
pub fn dot_reference(x: &[f32], col: &[f32]) -> f32 {
    assert_eq!(x.len(), col.len());
    let n = x.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for k in 0..chunks {
        for l in 0..LANES {
            acc[l] += x[k * LANES + l] * col[k * LANES + l];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * LANES..n {
        tail += x[i] * col[i];
    }
    reduce(acc, tail)
}

/// Matrix-level scalar reference mirroring [`read_columnar`].
pub fn read_reference(plane: &[f32], rows: usize, cols: usize, x: &[f32], y: &mut [f32]) {
    assert_eq!(plane.len(), rows * cols);
    assert_eq!(x.len(), rows);
    assert_eq!(y.len(), cols);
    for (j, yj) in y.iter_mut().enumerate() {
        *yj = dot_reference(x, &plane[j * rows..(j + 1) * rows]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rand_vec(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_uniform_f32(&mut v, -1.0, 1.0);
        v
    }

    #[test]
    fn dot_matches_reference_across_lengths() {
        let mut rng = Xoshiro256::seed_from_u64(301);
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 32, 100, 257] {
            let x = rand_vec(&mut rng, n);
            let c = rand_vec(&mut rng, n);
            let got = dot(&x, &c);
            let want = dot_reference(&x, &c);
            assert_eq!(got.to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn empty_and_zero_inputs() {
        assert_eq!(dot(&[], &[]), 0.0);
        let x = vec![0.0f32; 13];
        let c = vec![-0.5f32; 13];
        // Zero drive reads exactly zero (zero rows are not skipped,
        // but 0.0 * g only ever produces a signed zero).
        assert_eq!(dot(&x, &c), 0.0);
    }

    #[test]
    fn zero_rows_do_not_perturb_the_sum() {
        // Padding a vector with zero-drive rows must not change the
        // value: the tiled engine relies on this for padded tiles.
        let mut rng = Xoshiro256::seed_from_u64(302);
        let x = rand_vec(&mut rng, 24);
        let c = rand_vec(&mut rng, 24);
        let mut xp = x.clone();
        let mut cp = c.clone();
        xp.extend_from_slice(&[0.0; 16]);
        cp.extend_from_slice(&rand_vec(&mut rng, 16));
        assert_eq!(dot(&xp, &cp), dot(&x, &c));
    }

    #[test]
    fn read_columnar_matches_reference_ragged() {
        let mut rng = Xoshiro256::seed_from_u64(303);
        for (rows, cols) in [(5usize, 3usize), (8, 8), (33, 9), (50, 41)] {
            let plane = rand_vec(&mut rng, rows * cols);
            let x = rand_vec(&mut rng, rows);
            let mut y = vec![0.0f32; cols];
            let mut yr = vec![0.0f32; cols];
            read_columnar(&plane, rows, cols, &x, &mut y);
            read_reference(&plane, rows, cols, &x, &mut yr);
            assert_eq!(y, yr, "{rows}x{cols}");
        }
    }

    #[test]
    fn fuse_plane_transposes_and_adds() {
        let (rows, cols) = (3usize, 4usize);
        let g: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let m: Vec<f32> = (0..12).map(|v| 0.5 * v as f32).collect();
        let mut plane = vec![0.0f32; 12];
        fuse_plane(&g, &m, rows, cols, &mut plane);
        for i in 0..rows {
            for j in 0..cols {
                assert_eq!(plane[j * rows + i], g[i * cols + j] + m[i * cols + j]);
            }
        }
    }
}
