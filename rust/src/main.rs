//! `meliso` — the MELISO-RS benchmark coordinator binary.
//!
//! See `meliso help` or README.md for usage; `DESIGN.md` maps every
//! subcommand to the paper artifact it regenerates.

use meliso::cli::{dispatch, Args};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match Args::parse(argv).and_then(|args| dispatch(&args)) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}
