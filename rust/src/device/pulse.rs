//! Pulse-programming physics: the LTP/LTD conductance curve and the
//! mismatch noise transform.  **Must stay in lock-step with
//! `python/compile/model.py`** — the integration suite cross-checks.

/// Normalized conductance after a fraction `t ∈ [0,1]` of the pulse
/// train with non-linearity `nu`:
/// `g(t) = (1 - exp(-nu t)) / (1 - exp(-nu))`, linear as `nu -> 0`.
///
/// Concave (fast early potentiation) for `nu > 0`, convex for
/// `nu < 0`.  Open-loop programming targets the linear curve, so
/// `g(t) - t` is the encoding error caused by switching write–verify
/// off (the Fig. 3 mechanism).
#[inline]
pub fn pulse_curve(t: f64, nu: f64) -> f64 {
    const EPS: f64 = 1e-6;
    if nu.abs() < EPS {
        t
    } else {
        (1.0 - (-nu * t).exp()) / (1.0 - (-nu).exp())
    }
}

/// Map the paper's NL *label* to the pulse-curve curvature `kappa`:
/// `sign(NL) (e^{0.35 |NL|} - 1)`.  NeuroSim resolves its NL metric to
/// the exponential curve parameter through a nonlinear lookup; this
/// closed form reproduces the Fig. 3 "exponential dependency" while
/// keeping mid-range conductances off the window rails.
#[inline]
pub fn nl_to_curvature(nu: f64) -> f64 {
    const NL_GAMMA: f64 = 0.35;
    nu.signum() * ((NL_GAMMA * nu.abs()).exp_m1())
}

/// dg/dt of the pulse curve: `nu e^{-nu t} / (1 - e^{-nu})`, linear
/// limit 1.  C2C disturbance is a pulse-domain effect; mapping it
/// through the local slope amplifies noise on strongly non-linear
/// devices and makes it state-dependent (the Fig. 4b amplification and
/// the Table II skew/kurtosis).
#[inline]
pub fn pulse_curve_slope(t: f64, nu: f64) -> f64 {
    const EPS: f64 = 1e-6;
    if nu.abs() < EPS {
        1.0
    } else {
        nu * (-nu * t).exp() / (1.0 - (-nu).exp())
    }
}

/// Heavy-tailed, positively-skewed, zero-mean mismatch noise transform
/// applied to a standard normal draw (DESIGN.md §4):
/// `sinh(a z)/a + b (z² - 1)` with `a = 0.7`, `b = 0.15`.
#[inline]
pub fn mismatch_transform(z: f64) -> f64 {
    const A: f64 = 0.7;
    const B: f64 = 0.15;
    (A * z).sinh() / A + B * (z * z - 1.0)
}

/// Maximum absolute deviation of the pulse curve from linear — a cheap
/// analytic proxy for the non-linearity encoding error magnitude, used
/// by reports and the roofline estimate.
pub fn max_curve_deviation(nu: f64) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..=100 {
        let t = i as f64 / 100.0;
        worst = worst.max((pulse_curve(t, nu) - t).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_limit() {
        for i in 0..=10 {
            let t = i as f64 / 10.0;
            assert!((pulse_curve(t, 0.0) - t).abs() < 1e-12);
            assert!((pulse_curve(t, 1e-9) - t).abs() < 1e-6);
        }
    }

    #[test]
    fn endpoints_pinned() {
        for nu in [-4.88, -0.5, 0.3, 2.4, 5.0] {
            assert!(pulse_curve(0.0, nu).abs() < 1e-12);
            assert!((pulse_curve(1.0, nu) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn curvature_signs() {
        assert!(pulse_curve(0.5, 2.4) > 0.5); // concave LTP
        assert!(pulse_curve(0.5, -4.88) < 0.5); // convex LTD
    }

    #[test]
    fn monotone_in_t() {
        for nu in [-5.0, -1.0, 0.0, 1.0, 5.0] {
            let mut prev = -1.0;
            for i in 0..=50 {
                let g = pulse_curve(i as f64 / 50.0, nu);
                assert!(g > prev - 1e-12);
                prev = g;
            }
        }
    }

    #[test]
    fn deviation_grows_with_nu() {
        let devs: Vec<f64> = [0.0, 1.0, 2.4, 5.0]
            .iter()
            .map(|&nu| max_curve_deviation(nu))
            .collect();
        for w in devs.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(devs[0] < 1e-9);
    }

    #[test]
    fn mismatch_transform_shape() {
        // Odd-ish with positive skew correction: h(0) = -b.
        assert!((mismatch_transform(0.0) + 0.15).abs() < 1e-12);
        // Symmetric part dominates the tails; the skew term shifts the
        // negative tail up by 0.15 (z^2 - 1).
        assert!(mismatch_transform(4.0) > 13.0);
        assert!(mismatch_transform(-4.0) < -9.0);
        // Grows faster than linear in the tails.
        assert!(mismatch_transform(6.0) / 6.0 > mismatch_transform(2.0) / 2.0);
    }

    #[test]
    fn matches_python_constants() {
        // Spot values computed from the python reference
        // (sinh(0.7*1.5)/0.7 + 0.15*(1.5^2-1)).
        let z = 1.5f64;
        let want = (0.7f64 * z).sinh() / 0.7 + 0.15 * (z * z - 1.0);
        assert!((mismatch_transform(z) - want).abs() < 1e-15);
    }
}
