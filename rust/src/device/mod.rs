//! RRAM device models: conductance-state machines, pulse programming
//! with LTP/LTD non-linearity, cycle-to-cycle variation, memory-window
//! limited baseline mismatch — plus the Table I state-of-the-art
//! presets.
//!
//! The math here is the **same math** as the L2 JAX model
//! (`python/compile/model.py`); the two are kept in lock-step and
//! cross-checked by `rust/tests/integration_xla.rs`.  Any change to one
//! side must be mirrored on the other.

pub mod params;
pub mod presets;
pub mod pulse;

pub use params::{DeviceParams, NonIdealities};
pub use presets::{all_presets, DevicePreset};
pub use pulse::{mismatch_transform, pulse_curve};
