//! Table I: state-of-the-art device metrics.
//!
//! | Device        | CS  | Non-linearity | R_ON    | MW   | C2C (%) |
//! |---------------|-----|---------------|---------|------|---------|
//! | Ag:a-Si       | 97  | 2.4 / -4.88   | 26 MΩ   | 12.5 | 3.5     |
//! | TaOx/HfOx     | 128 | 0.04 / -0.63  | 100 kΩ  | 10   | 3.7     |
//! | AlOx/HfO2     | 40  | 1.94 / -0.61  | 16.9 kΩ | 4.43 | 5       |
//! | EpiRAM        | 64  | 0.5 / -0.5    | 81 kΩ   | 50.2 | 2       |
//!
//! Sources: Ag:a-Si (Jo et al., Nano Lett. 2010), TaOx/HfOx (Wu et al.,
//! VLSI 2018), AlOx/HfO2 (Woo et al., EDL 2016), EpiRAM (Choi et al.,
//! Nat. Mater. 2018) — as tabulated by the paper / NeuroSim+ V3.0.

use super::params::{DeviceParams, DEFAULT_K_BASE, DEFAULT_K_C2C, DEFAULT_S_EXP};

/// A named Table I device.
#[derive(Debug, Clone)]
pub struct DevicePreset {
    /// Canonical display name (as printed in the paper's tables).
    pub name: &'static str,
    /// CLI-friendly identifier.
    pub id: &'static str,
    /// ON-state resistance in ohms (used by the energy model).
    pub r_on_ohms: f64,
    /// Full device parameterization (non-idealities *included*; use
    /// [`DeviceParams::masked`] to switch them off per experiment).
    pub params: DeviceParams,
}

fn preset(
    name: &'static str,
    id: &'static str,
    cs: f64,
    nu_ltp: f64,
    nu_ltd: f64,
    r_on_ohms: f64,
    mw: f64,
    c2c_pct: f64,
) -> DevicePreset {
    DevicePreset {
        name,
        id,
        r_on_ohms,
        params: DeviceParams {
            states: cs,
            memory_window: mw,
            nu_ltp,
            nu_ltd,
            sigma_c2c: c2c_pct / 100.0,
            k_c2c: DEFAULT_K_C2C,
            k_base: DEFAULT_K_BASE,
            s_exp: DEFAULT_S_EXP,
        },
    }
}

/// Ag:a-Si (Jo et al. 2010) — the paper's model system.
pub fn ag_si() -> DevicePreset {
    preset("Ag:a-Si", "ag-si", 97.0, 2.4, -4.88, 26e6, 12.5, 3.5)
}

/// TaOx/HfOx (Wu et al. 2018).
pub fn taox_hfox() -> DevicePreset {
    preset("TaOx/HfOx", "taox-hfox", 128.0, 0.04, -0.63, 100e3, 10.0, 3.7)
}

/// AlOx/HfO2 (Woo et al. 2016).
pub fn alox_hfo2() -> DevicePreset {
    preset("AlOx/HfO2", "alox-hfo2", 40.0, 1.94, -0.61, 16.9e3, 4.43, 5.0)
}

/// EpiRAM (Choi et al. 2018) — best metrics across the board.
pub fn epiram() -> DevicePreset {
    preset("EpiRAM", "epiram", 64.0, 0.5, -0.5, 81e3, 50.2, 2.0)
}

/// The paper's modified Ag:a-Si used in Figs. 2–4: memory window raised
/// to 100 (the paper's modification i) so window effects don't mask the
/// swept variable.  Non-linearity and C2C carry the Table I values and
/// are masked per experiment (modification ii).
pub fn ag_si_modified() -> DevicePreset {
    let mut d = ag_si();
    d.name = "Ag:a-Si (MW=100)";
    d.id = "ag-si-mod";
    d.params.memory_window = 100.0;
    d
}

/// All four Table I devices, in the paper's column order.
pub fn all_presets() -> Vec<DevicePreset> {
    vec![ag_si(), taox_hfox(), alox_hfo2(), epiram()]
}

/// Look up a preset by CLI id (case-insensitive).
pub fn by_id(id: &str) -> Option<DevicePreset> {
    let id = id.to_ascii_lowercase();
    [ag_si(), taox_hfox(), alox_hfo2(), epiram(), ag_si_modified()]
        .into_iter()
        .find(|d| d.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::params::NonIdealities;

    #[test]
    fn table1_values_exact() {
        let ag = ag_si();
        assert_eq!(ag.params.states, 97.0);
        assert_eq!(ag.params.nu_ltp, 2.4);
        assert_eq!(ag.params.nu_ltd, -4.88);
        assert_eq!(ag.params.memory_window, 12.5);
        assert!((ag.params.sigma_c2c - 0.035).abs() < 1e-12);
        assert_eq!(ag.r_on_ohms, 26e6);

        let ta = taox_hfox();
        assert_eq!(ta.params.states, 128.0);
        assert_eq!(ta.params.memory_window, 10.0);

        let al = alox_hfo2();
        assert_eq!(al.params.states, 40.0);
        assert_eq!(al.params.memory_window, 4.43);
        assert!((al.params.sigma_c2c - 0.05).abs() < 1e-12);

        let epi = epiram();
        assert_eq!(epi.params.states, 64.0);
        assert_eq!(epi.params.memory_window, 50.2);
        assert!((epi.params.sigma_c2c - 0.02).abs() < 1e-12);
    }

    #[test]
    fn all_presets_are_valid() {
        for d in all_presets() {
            assert!(d.params.validate().is_ok(), "{}", d.name);
        }
    }

    #[test]
    fn modified_ag_si_has_window_100() {
        let d = ag_si_modified();
        assert_eq!(d.params.memory_window, 100.0);
        // Non-linearity still present until masked.
        assert_eq!(d.params.nu_ltp, 2.4);
        let ideal = d.params.masked(NonIdealities::IDEAL);
        assert_eq!(ideal.nu_ltp, 0.0);
        assert_eq!(ideal.sigma_c2c, 0.0);
    }

    #[test]
    fn lookup_by_id() {
        assert_eq!(by_id("epiram").unwrap().name, "EpiRAM");
        assert_eq!(by_id("AG-SI").unwrap().name, "Ag:a-Si");
        assert!(by_id("nope").is_none());
    }

    #[test]
    fn epiram_has_best_metrics() {
        // The paper's explanation of Fig. 5: EpiRAM wins on window,
        // cumulative non-linearity, and C2C.
        let epi = epiram().params;
        for other in [ag_si().params, taox_hfox().params, alox_hfo2().params] {
            assert!(epi.sigma_c2c <= other.sigma_c2c);
            assert!(epi.memory_window > other.memory_window);
        }
        // Lowest cumulative non-linearity vs the high-NL devices
        // (TaOx/HfOx has a lower sum but a 5x smaller window).
        for other in [ag_si().params, alox_hfo2().params] {
            assert!(
                epi.nu_ltp.abs() + epi.nu_ltd.abs()
                    <= other.nu_ltp.abs() + other.nu_ltd.abs()
            );
        }
    }
}
