//! Device parameter vectors — the runtime-scalar contract shared with
//! the AOT artifacts.

/// Calibration defaults (DESIGN.md §7): fitted once on the Ag:a-Si
/// Table II magnitudes, then held fixed across all devices and sweeps.

pub const DEFAULT_K_C2C: f64 = 2.0;
pub const DEFAULT_K_BASE: f64 = 3.3;
pub const DEFAULT_S_EXP: f64 = 1.5;

/// Reference state count at which the state-resolution factor is 1
/// (mirrors `model.S_REF`).
pub const S_REF: f64 = 64.0;
/// Cap on the state-resolution factor (mirrors `model.MISMATCH_RES_CAP`).
pub const MISMATCH_RES_CAP: f64 = 8.0;

/// Which non-idealities are active — the paper's experiments toggle
/// non-linearity and C2C independently (Figs. 2–5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct NonIdealities {
    pub nonlinearity: bool,
    pub c2c: bool,
}

impl NonIdealities {
    pub const IDEAL: NonIdealities = NonIdealities { nonlinearity: false, c2c: false };
    pub const FULL: NonIdealities = NonIdealities { nonlinearity: true, c2c: true };

    pub fn label(&self) -> &'static str {
        match (self.nonlinearity, self.c2c) {
            (false, false) => "ideal",
            (true, true) => "nonideal",
            (true, false) => "nl-only",
            (false, true) => "c2c-only",
        }
    }
}

/// The full device parameterization of one benchmark configuration.
///
/// Field order and meaning mirror `params[0..8]` of the L2 model — see
/// `python/compile/model.py` module docstring.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceParams {
    /// Number of conductance states (Table I "CS").
    pub states: f64,
    /// Memory window `Gmax / Gmin` (Table I "MW").
    pub memory_window: f64,
    /// LTP weight-update non-linearity (positive-target device).
    pub nu_ltp: f64,
    /// LTD weight-update non-linearity (negative-target device).
    pub nu_ltd: f64,
    /// Cycle-to-cycle sigma, fraction of the conductance range / pulse.
    pub sigma_c2c: f64,
    /// Calibration: accumulated-C2C scale.
    pub k_c2c: f64,
    /// Calibration: baseline-mismatch scale.
    pub k_base: f64,
    /// Calibration: state-resolution exponent.
    pub s_exp: f64,
}

impl DeviceParams {
    /// An idealized device: effectively-continuous states, huge window,
    /// no non-idealities.  `y_hw == y_sw` up to f32 rounding.
    pub fn ideal() -> Self {
        Self {
            states: 65_536.0,
            memory_window: 1e6,
            nu_ltp: 0.0,
            nu_ltd: 0.0,
            sigma_c2c: 0.0,
            k_c2c: DEFAULT_K_C2C,
            k_base: DEFAULT_K_BASE,
            s_exp: DEFAULT_S_EXP,
        }
    }

    /// Weight bits `log2(states)`.
    pub fn weight_bits(&self) -> f64 {
        self.states.log2()
    }

    /// Set states from a bit count (Fig. 2a sweeps bits directly).
    pub fn with_weight_bits(mut self, bits: u32) -> Self {
        self.states = (1u64 << bits) as f64;
        self
    }

    pub fn with_memory_window(mut self, mw: f64) -> Self {
        self.memory_window = mw;
        self
    }

    pub fn with_nonlinearity(mut self, nu_ltp: f64, nu_ltd: f64) -> Self {
        self.nu_ltp = nu_ltp;
        self.nu_ltd = nu_ltd;
        self
    }

    pub fn with_c2c(mut self, sigma: f64) -> Self {
        self.sigma_c2c = sigma;
        self
    }

    /// Apply a non-ideality mask: switched-off terms are zeroed, which
    /// is exactly the paper's "without non-linearity and C-to-C"
    /// protocol.
    pub fn masked(mut self, mask: NonIdealities) -> Self {
        if !mask.nonlinearity {
            self.nu_ltp = 0.0;
            self.nu_ltd = 0.0;
        }
        if !mask.c2c {
            self.sigma_c2c = 0.0;
        }
        self
    }

    /// Normalized minimum conductance `Gmin/Gmax = 1/MW`.
    pub fn g_min(&self) -> f64 {
        1.0 / self.memory_window
    }

    /// Baseline-to-range ratio `r = Gmin / (Gmax - Gmin) = 1/(MW-1)`.
    pub fn baseline_ratio(&self) -> f64 {
        1.0 / (self.memory_window - 1.0)
    }

    /// Per-cell mismatch scale `m = k_base * r * min((S_REF/S)^s_exp, cap)`.
    pub fn mismatch_scale(&self) -> f64 {
        let res = (S_REF / self.states)
            .powf(self.s_exp)
            .min(MISMATCH_RES_CAP);
        self.k_base * self.baseline_ratio() * res
    }

    /// Pack into the artifact's `params` input layout (f32 8-vector).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        vec![
            self.states as f32,
            self.memory_window as f32,
            self.nu_ltp as f32,
            self.nu_ltd as f32,
            self.sigma_c2c as f32,
            self.k_c2c as f32,
            self.k_base as f32,
            self.s_exp as f32,
        ]
    }

    /// Validate physical plausibility; returns a description of the
    /// first violation.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.states >= 2.0) {
            return Err(format!("states must be >= 2, got {}", self.states));
        }
        if !(self.memory_window > 1.0) {
            return Err(format!(
                "memory window must exceed 1 (Gmax > Gmin), got {}",
                self.memory_window
            ));
        }
        if self.sigma_c2c < 0.0 {
            return Err(format!("sigma_c2c must be >= 0, got {}", self.sigma_c2c));
        }
        if self.nu_ltp.abs() > 20.0 || self.nu_ltd.abs() > 20.0 {
            return Err("non-linearity out of the supported [-20, 20] range".into());
        }
        if self.k_c2c < 0.0 || self.k_base < 0.0 {
            return Err("calibration scales must be >= 0".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_is_valid_and_clean() {
        let p = DeviceParams::ideal();
        assert!(p.validate().is_ok());
        assert_eq!(p.sigma_c2c, 0.0);
        assert_eq!(p.nu_ltp, 0.0);
    }

    #[test]
    fn weight_bits_roundtrip() {
        let p = DeviceParams::ideal().with_weight_bits(6);
        assert_eq!(p.states, 64.0);
        assert!((p.weight_bits() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn masked_zeroes_only_disabled_terms() {
        let p = DeviceParams::ideal()
            .with_nonlinearity(2.4, -4.88)
            .with_c2c(0.035);
        let ideal = p.masked(NonIdealities::IDEAL);
        assert_eq!(ideal.nu_ltp, 0.0);
        assert_eq!(ideal.sigma_c2c, 0.0);
        assert_eq!(ideal.states, p.states);
        let nl = p.masked(NonIdealities { nonlinearity: true, c2c: false });
        assert_eq!(nl.nu_ltp, 2.4);
        assert_eq!(nl.sigma_c2c, 0.0);
        let full = p.masked(NonIdealities::FULL);
        assert_eq!(full, p);
    }

    #[test]
    fn geometry_ratios() {
        let p = DeviceParams::ideal().with_memory_window(12.5);
        assert!((p.g_min() - 0.08).abs() < 1e-12);
        assert!((p.baseline_ratio() - 1.0 / 11.5).abs() < 1e-12);
    }

    #[test]
    fn mismatch_scale_monotonicity() {
        let base = DeviceParams::ideal();
        // Larger window -> smaller mismatch.
        let a = base.with_memory_window(4.43).mismatch_scale();
        let b = base.with_memory_window(50.2).mismatch_scale();
        assert!(a > b);
        // More states -> smaller mismatch (until the cap).
        let c = base.with_memory_window(10.0).with_weight_bits(5).mismatch_scale();
        let d = base.with_memory_window(10.0).with_weight_bits(8).mismatch_scale();
        assert!(c > d);
    }

    #[test]
    fn mismatch_res_factor_capped() {
        let tiny = DeviceParams::ideal()
            .with_memory_window(10.0)
            .with_weight_bits(1); // 2 states: raw factor (64/2)^1.5 = 181
        let capped = tiny.mismatch_scale();
        let expected = DEFAULT_K_BASE * (1.0 / 9.0) * MISMATCH_RES_CAP;
        assert!((capped - expected).abs() < 1e-12);
    }

    #[test]
    fn f32_vec_layout() {
        let p = DeviceParams::ideal().with_nonlinearity(2.4, -4.88).with_c2c(0.02);
        let v = p.to_f32_vec();
        assert_eq!(v.len(), 8);
        assert_eq!(v[2], 2.4f32);
        assert_eq!(v[3], -4.88f32);
        assert_eq!(v[4], 0.02f32);
    }

    #[test]
    fn validation_catches_nonsense() {
        let mut p = DeviceParams::ideal();
        p.states = 1.0;
        assert!(p.validate().is_err());
        let mut p = DeviceParams::ideal();
        p.memory_window = 0.9;
        assert!(p.validate().is_err());
        let mut p = DeviceParams::ideal();
        p.sigma_c2c = -0.1;
        assert!(p.validate().is_err());
        let mut p = DeviceParams::ideal();
        p.nu_ltp = 25.0;
        assert!(p.validate().is_err());
    }
}
