//! Deterministic gross-fault injection for the sharded engine.
//!
//! The fault model is the one the checksum reduction targets: a whole
//! bit line of one shard goes gross — stuck at a differential rail
//! (`level = ±1`) or dead (`level = 0`, an open line reading zero
//! current).  Faults are drawn per `(sample, shard)` cell from a
//! dedicated seed, so whether a given cell faults — and which column —
//! is a pure function of `(seed, sample, shard)`: independent of the
//! thread count, chunk sizes, and scheduling order, which keeps the
//! engine's bit-determinism contract intact under injection.

use crate::util::rng::Xoshiro256;

/// Gross-fault injection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Probability that a given `(sample, shard)` programming cycle
    /// suffers one faulty bit line.
    pub rate: f64,
    /// Stuck differential conductance level in `[-1, 1]`: `1.0` is a
    /// rail-stuck line (every cell reads as a full-scale `+1` weight),
    /// `0.0` a dead line.
    pub level: f32,
    /// Root seed of the fault stream (independent of the workload
    /// seed, as real defects are independent of the data).
    pub seed: u64,
}

impl FaultSpec {
    /// A rail-stuck-line policy at the given rate.
    pub fn stuck_at_on(rate: f64, seed: u64) -> Self {
        Self { rate, level: 1.0, seed }
    }

    /// Decide whether shard `shard` of sample `sample` faults, and if
    /// so which of its `clen` data columns.  Deterministic in
    /// `(seed, sample, shard)`.
    pub fn draw(&self, sample: usize, shard: usize, clen: usize) -> Option<usize> {
        if self.rate <= 0.0 || clen == 0 {
            return None;
        }
        let mut rng = Xoshiro256::seed_from_u64(self.seed)
            .child(sample as u64)
            .child(shard as u64);
        if rng.uniform() < self.rate {
            Some(rng.below(clen as u64) as usize)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draw_is_deterministic() {
        let f = FaultSpec::stuck_at_on(0.5, 42);
        for sample in 0..20 {
            for shard in 0..4 {
                assert_eq!(f.draw(sample, shard, 8), f.draw(sample, shard, 8));
            }
        }
    }

    #[test]
    fn rate_zero_never_fires_rate_one_always() {
        let off = FaultSpec::stuck_at_on(0.0, 7);
        let on = FaultSpec::stuck_at_on(1.0, 7);
        for sample in 0..50 {
            assert_eq!(off.draw(sample, 0, 8), None);
            let col = on.draw(sample, 0, 8).expect("rate 1.0 must fire");
            assert!(col < 8);
        }
    }

    #[test]
    fn rate_is_approximately_honored() {
        let f = FaultSpec::stuck_at_on(0.25, 99);
        let n = 4000;
        let hits = (0..n).filter(|&s| f.draw(s, 0, 16).is_some()).count();
        let p = hits as f64 / n as f64;
        assert!((p - 0.25).abs() < 0.03, "p={p}");
    }

    #[test]
    fn cells_are_independent() {
        let f = FaultSpec::stuck_at_on(0.5, 5);
        // Different shards of the same sample must not share the draw.
        let a: Vec<_> = (0..64).map(|s| f.draw(s, 0, 8)).collect();
        let b: Vec<_> = (0..64).map(|s| f.draw(s, 1, 8)).collect();
        assert_ne!(a, b);
        // Different seeds reshuffle everything.
        let g = FaultSpec::stuck_at_on(0.5, 6);
        let c: Vec<_> = (0..64).map(|s| g.draw(s, 0, 8)).collect();
        assert_ne!(a, c);
    }
}
