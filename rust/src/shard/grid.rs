//! Shard grid geometry: partition one logical `rows x cols` weight
//! matrix into an `R x C` grid of independently programmed crossbar
//! shards with near-equal block sizes.
//!
//! Unlike the tiled engine (fixed *physical* tile size, grid derived
//! from the workload), the shard grid fixes the *grid* and derives the
//! block sizes — the deployment question is "how many crossbars do I
//! spread this matrix over", not "how big is one crossbar".  Blocks
//! follow the same near-equal split as
//! [`crate::util::pool::partition_blocks`]: `base = n / parts` with the
//! first `n % parts` blocks one element longer, in index order.

use crate::error::{Error, Result};

/// One shard's rectangle of the logical matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRegion {
    /// First logical row covered by this shard.
    pub r0: usize,
    /// Rows covered.
    pub rlen: usize,
    /// First logical column covered by this shard.
    pub c0: usize,
    /// Columns covered.
    pub clen: usize,
}

/// A validated `R x C` partition of a `rows x cols` matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardGrid {
    rows: usize,
    cols: usize,
    grid_r: usize,
    grid_c: usize,
    row_blocks: Vec<(usize, usize)>,
    col_blocks: Vec<(usize, usize)>,
}

impl ShardGrid {
    /// Partition `rows x cols` into `grid_r x grid_c` shards.  Every
    /// shard must cover at least one row and one column, so the grid
    /// may not exceed the matrix in either dimension.
    pub fn new(rows: usize, cols: usize, grid_r: usize, grid_c: usize) -> Result<Self> {
        if grid_r == 0 || grid_c == 0 {
            return Err(Error::Config("shard grid must be positive".into()));
        }
        if grid_r > rows || grid_c > cols {
            return Err(Error::Config(format!(
                "shard grid {grid_r}x{grid_c} exceeds the {rows}x{cols} workload \
                 (every shard needs at least one row and one column)"
            )));
        }
        Ok(Self {
            rows,
            cols,
            grid_r,
            grid_c,
            row_blocks: blocks(rows, grid_r),
            col_blocks: blocks(cols, grid_c),
        })
    }

    /// Total shards in the grid.
    pub fn count(&self) -> usize {
        self.grid_r * self.grid_c
    }

    /// Grid shape `(R, C)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.grid_r, self.grid_c)
    }

    /// Region of shard `index` (row-major over the grid:
    /// `index = sr * C + sc`).
    pub fn region(&self, index: usize) -> ShardRegion {
        let (sr, sc) = (index / self.grid_c, index % self.grid_c);
        let (r0, rlen) = self.row_blocks[sr];
        let (c0, clen) = self.col_blocks[sc];
        ShardRegion { r0, rlen, c0, clen }
    }

    /// Largest shard row count (scratch sizing).
    pub fn max_rlen(&self) -> usize {
        self.row_blocks.iter().map(|&(_, l)| l).max().unwrap_or(0)
    }

    /// Largest shard column count (scratch sizing).
    pub fn max_clen(&self) -> usize {
        self.col_blocks.iter().map(|&(_, l)| l).max().unwrap_or(0)
    }
}

/// Near-equal `(start, len)` blocks covering `0..n` in order.
fn blocks(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, len));
        start += len;
    }
    out
}

/// Parse an `RxC` grid spec (e.g. `"2x4"`), as used by `--shards` and
/// the `[shard] grid` TOML key.
///
/// Failures are actionable, not bare parse errors: every message
/// states the expected `RxC` shape, quotes the offending input, and
/// names which half is wrong (mirroring the `EngineKind::ALL`
/// unknown-engine message, which lists every valid name).
pub fn parse_grid(s: &str) -> Result<(usize, usize)> {
    let bad = |what: &str| {
        Error::Config(format!(
            "shard grid must be 'RxC' with positive integers, e.g. '2x4' — \
             got '{s}' ({what})"
        ))
    };
    let spec = s.trim().to_ascii_lowercase();
    let (r, c) = spec
        .split_once('x')
        .ok_or_else(|| bad("missing the 'x' separator"))?;
    let parse_half = |half: &str, name: &str| -> Result<usize> {
        let n: usize = half
            .trim()
            .parse()
            .map_err(|_| bad(&format!("{name} '{}' is not an integer", half.trim())))?;
        if n == 0 {
            return Err(bad(&format!("{name} must be >= 1")));
        }
        Ok(n)
    };
    Ok((parse_half(r, "rows")?, parse_half(c, "columns")?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_matrix_in_order() {
        let g = ShardGrid::new(70, 33, 3, 2).unwrap();
        assert_eq!(g.count(), 6);
        assert_eq!(g.shape(), (3, 2));
        // Row blocks: 24, 23, 23; col blocks: 17, 16.
        let mut next_row = vec![0usize; 2];
        for sr in 0..3 {
            for sc in 0..2 {
                let reg = g.region(sr * 2 + sc);
                assert_eq!(reg.r0, next_row[sc], "shard {sr}x{sc}");
                assert!(reg.rlen > 0 && reg.clen > 0);
                next_row[sc] = reg.r0 + reg.rlen;
            }
        }
        assert_eq!(next_row, vec![70, 70]);
        let cols: usize = (0..2).map(|sc| g.region(sc).clen).sum();
        assert_eq!(cols, 33);
        assert_eq!(g.max_rlen(), 24);
        assert_eq!(g.max_clen(), 17);
    }

    #[test]
    fn unit_grid_is_the_whole_matrix() {
        let g = ShardGrid::new(32, 32, 1, 1).unwrap();
        assert_eq!(g.count(), 1);
        assert_eq!(g.region(0), ShardRegion { r0: 0, rlen: 32, c0: 0, clen: 32 });
    }

    #[test]
    fn degenerate_grids_rejected() {
        assert!(ShardGrid::new(32, 32, 0, 2).is_err());
        assert!(ShardGrid::new(32, 32, 2, 0).is_err());
        assert!(ShardGrid::new(8, 8, 9, 1).is_err());
        assert!(ShardGrid::new(8, 8, 1, 9).is_err());
        // One shard per cell is the finest legal partition.
        assert!(ShardGrid::new(8, 8, 8, 8).is_ok());
    }

    #[test]
    fn parse_grid_specs() {
        assert_eq!(parse_grid("2x4").unwrap(), (2, 4));
        assert_eq!(parse_grid(" 1X1 ").unwrap(), (1, 1));
        assert!(parse_grid("2").is_err());
        assert!(parse_grid("0x2").is_err());
        assert!(parse_grid("2x").is_err());
        assert!(parse_grid("ax2").is_err());
        assert!(parse_grid("2x3x4").is_err());
    }

    #[test]
    fn parse_grid_errors_name_format_input_and_cause() {
        // A malformed spec must report the expected RxC format and the
        // offending input — never a bare integer-parse error.
        for (input, cause) in [
            ("4", "separator"),
            ("x4", "not an integer"),
            ("4x", "not an integer"),
            ("axb", "not an integer"),
            ("0x2", ">= 1"),
            ("2x0", ">= 1"),
            ("2x3x4", "not an integer"),
        ] {
            let msg = parse_grid(input).unwrap_err().to_string();
            assert!(msg.contains("RxC"), "input {input:?}: {msg}");
            assert!(msg.contains(input), "input {input:?}: {msg}");
            assert!(msg.contains(cause), "input {input:?}: {msg}");
        }
        // Which half is wrong is named.
        assert!(parse_grid("ax2").unwrap_err().to_string().contains("rows"));
        assert!(parse_grid("2xb").unwrap_err().to_string().contains("columns"));
    }
}
