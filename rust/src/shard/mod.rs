//! Sharded multi-crossbar execution support: grid partitioning,
//! ABFT-style checksum coding, and deterministic gross-fault injection.
//!
//! This is the geometry/coding layer under
//! [`crate::vmm::ShardedEngine`], which partitions one large VMM across
//! a grid of independently programmed crossbar shards and reduces the
//! partial sums with per-shard checksum verification — the
//! scalable/distributed direction of arXiv:2508.13298, where the error
//! correction is integrated into the partitioning rather than bolted
//! onto single devices (contrast [`crate::mitigation`], whose
//! strategies act per device pair/cell and cannot express a
//! shard-granular gross fault).
//!
//! * [`grid`] — [`ShardGrid`]: near-equal `R x C` block partition of a
//!   `rows x cols` matrix, plus the `"RxC"` spec parser behind
//!   `--shards` and the `[shard]` TOML section.
//! * [`checksum`] — [`ChecksumCode`]: sum + binary-locator checksum
//!   columns appended to each shard at program time; verification
//!   locates and reconstructs a single gross per-shard fault at
//!   reduction time.
//! * [`fault`] — [`FaultSpec`]: seeded stuck/dead bit-line injection,
//!   a pure function of `(seed, sample, shard)` so determinism
//!   guarantees survive fault campaigns.

#![warn(missing_docs)]

pub mod checksum;
pub mod fault;
pub mod grid;

pub use checksum::{extra_cols, locator_count, ChecksumCode, Verdict};
pub use fault::FaultSpec;
pub use grid::{parse_grid, ShardGrid, ShardRegion};
