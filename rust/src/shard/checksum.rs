//! ABFT-style checksum coding for shard partial sums.
//!
//! Each shard's weight block is augmented with extra crossbar columns
//! at program time (algorithm-based fault tolerance in the
//! Huang–Abraham tradition, following the integrated-error-correction
//! direction of arXiv:2508.13298):
//!
//! * one **sum column** holding the scaled row sums
//!   `sum_j W[i, j] / clen`, so its analog read estimates
//!   `sum_j y[j]` of the shard's partial outputs, and
//! * `ceil(log2(clen))` **binary locator columns**, column `b` holding
//!   the scaled partial row sums over the data columns whose index has
//!   bit `b` set.
//!
//! At reduction time the decoded checksum reads are compared against
//! the matching sums of the data outputs.  A single gross fault of
//! magnitude `e` at data column `j*` shifts the sum check by `-e` and
//! locator check `b` by `-e` exactly when bit `b` of `j*` is set — so
//! the per-bit ratios `delta_b / delta_1` read out the faulty column
//! index in binary, and adding `delta_1` back to that column
//! reconstructs it from the checksum.  Binary-coded locators are used
//! instead of the classical single weighted column because the weighted
//! column's `j * W` entries must be rescaled by `O(clen^2)` to fit the
//! conductance window, which amplifies quantization error past the
//! point of reliable localization; each binary locator rescales by at
//! most `clen / 2`.
//!
//! The ratio decode demands every bit be *clearly* 0 or 1 (within
//! [`RATIO_MARGIN`] of the ideal).  Anything else — two simultaneous
//! faults, a fault on a checksum line itself, or a detection fired by
//! accumulated analog noise rather than a gross fault — decodes
//! inconsistently and is reported as [`Verdict::Detected`] without
//! touching the data.  The margin is a guard, not a proof: on very
//! noisy devices a noise-fired detection can occasionally land every
//! ratio inside the windows (most often decoding column 0) and be
//! applied as a bogus correction of roughly noise-floor magnitude —
//! the false-fire legs of the `shard-sweep` experiment measure this
//! rate, and the detection threshold is the knob that bounds it.

/// Half-width of the accepted ratio windows around 0 and 1.
pub const RATIO_MARGIN: f64 = 0.4;

/// Locator columns needed to address `clen` data columns.
pub fn locator_count(clen: usize) -> usize {
    if clen <= 1 {
        0
    } else {
        (usize::BITS - (clen - 1).leading_zeros()) as usize
    }
}

/// Total checksum columns (sum + locators) for `clen` data columns.
pub fn extra_cols(clen: usize) -> usize {
    1 + locator_count(clen)
}

/// Outcome of verifying one shard's partial outputs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Verdict {
    /// Checks passed; partials flow to accumulation untouched.
    Clean,
    /// A single-column gross fault was located; adding `delta` to data
    /// column `col` reconstructs it from the checksum.
    Fault { col: usize, delta: f64 },
    /// The sum check fired but the locator pattern is inconsistent —
    /// detected, not correctable; data is left untouched.
    Detected,
}

/// Checksum encoder/verifier for one shard column count.
#[derive(Debug, Clone, PartialEq)]
pub struct ChecksumCode {
    clen: usize,
    locators: usize,
    /// Descale factor of the sum column (`clen`: row sums of up to
    /// `clen` unit weights are compressed into the `[-1, 1]` window).
    sum_scale: f64,
    /// Descale factor per locator column (the size of its column set).
    loc_scale: Vec<f64>,
}

impl ChecksumCode {
    /// Code for shards of `clen` data columns (panics on zero): one
    /// sum column plus [`locator_count`] binary-locator columns.
    pub fn new(clen: usize) -> Self {
        assert!(clen > 0, "checksum code needs at least one data column");
        let locators = locator_count(clen);
        let loc_scale = (0..locators)
            .map(|b| (0..clen).filter(|j| (j >> b) & 1 == 1).count() as f64)
            .collect();
        Self { clen, locators, sum_scale: clen as f64, loc_scale }
    }

    /// Checksum columns this code appends.
    pub fn extra(&self) -> usize {
        1 + self.locators
    }

    /// Encode one weight row: fill `cs_row` (length [`Self::extra`])
    /// with the scaled sum and locator targets for `w_row` (length
    /// `clen`, entries in `[-1, 1]`).  Every target lands in `[-1, 1]`
    /// by construction.
    pub fn encode_row(&self, w_row: &[f32], cs_row: &mut [f32]) {
        debug_assert_eq!(w_row.len(), self.clen);
        debug_assert_eq!(cs_row.len(), self.extra());
        let sum: f64 = w_row.iter().map(|&w| w as f64).sum();
        cs_row[0] = (sum / self.sum_scale) as f32;
        for b in 0..self.locators {
            let sb: f64 = w_row
                .iter()
                .enumerate()
                .filter(|(j, _)| (j >> b) & 1 == 1)
                .map(|(_, &w)| w as f64)
                .sum();
            cs_row[1 + b] = (sb / self.loc_scale[b]) as f32;
        }
    }

    /// Verify one shard's raw partial outputs (`y_data`, length `clen`)
    /// against its checksum column reads (`y_cs`, length
    /// [`Self::extra`]).  `threshold` is the absolute sum-check
    /// discrepancy above which a fault is declared — it must sit above
    /// the shard's accumulated analog noise floor and below the gross
    /// fault magnitudes of interest (see the module docs of
    /// [`crate::vmm::sharded`] for the scaling used by the engine).
    pub fn verify(&self, y_data: &[f32], y_cs: &[f32], threshold: f64) -> Verdict {
        debug_assert_eq!(y_data.len(), self.clen);
        debug_assert_eq!(y_cs.len(), self.extra());
        let s: f64 = y_data.iter().map(|&v| v as f64).sum();
        let c1 = y_cs[0] as f64 * self.sum_scale;
        let d1 = c1 - s;
        if d1.abs() <= threshold {
            return Verdict::Clean;
        }
        // With no locators (clen == 1) the loop is empty and the fault
        // can only be at column 0.
        let mut col = 0usize;
        for b in 0..self.locators {
            let sb: f64 = y_data
                .iter()
                .enumerate()
                .filter(|(j, _)| (j >> b) & 1 == 1)
                .map(|(_, &v)| v as f64)
                .sum();
            let cb = y_cs[1 + b] as f64 * self.loc_scale[b];
            let ratio = (cb - sb) / d1;
            if (ratio - 1.0).abs() < RATIO_MARGIN {
                col |= 1 << b;
            } else if ratio.abs() >= RATIO_MARGIN {
                return Verdict::Detected;
            }
        }
        if col >= self.clen {
            return Verdict::Detected;
        }
        Verdict::Fault { col, delta: d1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    /// Exact synthetic shard: `y_data` and `y_cs` computed from the
    /// same `(W, x)` in f64, so the only check discrepancy is f32
    /// rounding of the encoded targets.
    fn exact_shard(rows: usize, clen: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let code = ChecksumCode::new(clen);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut w = vec![0.0f32; rows * clen];
        let mut x = vec![0.0f32; rows];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let mut y = vec![0.0f32; clen];
        for j in 0..clen {
            y[j] = (0..rows).map(|i| x[i] as f64 * w[i * clen + j] as f64).sum::<f64>() as f32;
        }
        let mut cs_w = vec![0.0f32; rows * code.extra()];
        for i in 0..rows {
            code.encode_row(
                &w[i * clen..(i + 1) * clen],
                &mut cs_w[i * code.extra()..(i + 1) * code.extra()],
            );
        }
        let mut y_cs = vec![0.0f32; code.extra()];
        for (k, yc) in y_cs.iter_mut().enumerate() {
            *yc = (0..rows)
                .map(|i| x[i] as f64 * cs_w[i * code.extra() + k] as f64)
                .sum::<f64>() as f32;
        }
        (y, y_cs)
    }

    #[test]
    fn locator_counts() {
        assert_eq!(locator_count(1), 0);
        assert_eq!(locator_count(2), 1);
        assert_eq!(locator_count(5), 3);
        assert_eq!(locator_count(32), 5);
        assert_eq!(locator_count(33), 6);
        assert_eq!(extra_cols(32), 6);
        assert_eq!(extra_cols(1), 1);
    }

    #[test]
    fn encoded_targets_stay_in_window() {
        let code = ChecksumCode::new(13);
        let w_row = vec![1.0f32; 13];
        let mut cs = vec![0.0f32; code.extra()];
        code.encode_row(&w_row, &mut cs);
        assert!(cs.iter().all(|v| (-1.0..=1.0).contains(v)), "{cs:?}");
        assert!((cs[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn clean_shard_verifies_clean() {
        for clen in [1usize, 2, 13, 32] {
            let code = ChecksumCode::new(clen);
            let (y, y_cs) = exact_shard(32, clen, 500 + clen as u64);
            // f32 encode rounding only: a loose absolute threshold.
            assert_eq!(code.verify(&y, &y_cs, 0.01), Verdict::Clean, "clen={clen}");
        }
    }

    // Single-fault correction and double-fault refusal are covered by
    // the randomized property suites in `rust/tests/proptests.rs`
    // (`prop_checksum_single_fault_*`, `prop_checksum_double_fault_*`),
    // which subsume the fixed-case asserts that used to live here.

    #[test]
    fn single_column_shard_needs_no_locators() {
        let code = ChecksumCode::new(1);
        let (mut y, y_cs) = exact_shard(16, 1, 77);
        y[0] -= 4.0;
        match code.verify(&y, &y_cs, 0.5) {
            Verdict::Fault { col, delta } => {
                assert_eq!(col, 0);
                assert!((delta - 4.0).abs() < 0.05);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn checksum_line_fault_on_nonzero_column_refused() {
        let code = ChecksumCode::new(16);
        let (y, mut y_cs) = exact_shard(32, 16, 4321);
        // A fault on a locator line fires that single ratio without a
        // matching sum-check shift large enough to explain it: here we
        // corrupt the sum line itself, which decodes every locator
        // ratio to ~0 — column 0.  Column 0's reconstruction would then
        // subtract the whole (bogus) delta from a healthy column; the
        // decode accepts this as col 0 only when the ratios are
        // *consistently* zero, which is exactly the ambiguous case the
        // margin cannot distinguish from a genuine col-0 fault — so the
        // engine documents that checksum lines are programmed verified
        // (they carry no stochastic noise).  What *is* guaranteed: the
        // verdict never names a column outside the data range.
        y_cs[0] += 1.0; // descaled: +16 on the sum check
        match code.verify(&y, &y_cs, 1.0) {
            Verdict::Fault { col, .. } => assert!(col < 16),
            Verdict::Detected => {}
            Verdict::Clean => panic!("corrupted sum line must not verify clean"),
        }
    }
}
