//! [`MitigatedMatrix`]: a logical matrix programmed through the
//! mitigation pipeline onto (tiled) crossbars — the solver-side
//! counterpart of [`super::MitigatedEngine`].
//!
//! The pipeline programs one [`TiledCrossbar`] per (differential sign ×
//! bit-slice × replica) with independent noise draws from the caller's
//! RNG, recombines reads with the pipeline's linear weights in f64, and
//! optionally inverts a per-column affine distortion estimated from
//! probe reads against the analytically known clean programming.  With
//! the identity config it programs exactly one crossbar and consumes
//! exactly the RNG stream the unmitigated
//! [`crate::solver::CrossbarOperator`] consumed before the mitigation
//! layer existed, so existing results are bit-for-bit unchanged.
//!
//! Replica semantics deliberately differ from the engine path: a
//! deployed solver replicates *spatially* — `R` redundant physical
//! arrays at `R`× area cost — so every noise channel (mismatch
//! included) is drawn independently and averaging attacks all of them.
//! [`super::MitigatedEngine`] instead models *temporal* replicas
//! (reprogramming cycles of the same arrays), where the mismatch floor
//! survives averaging.  See DESIGN.md §10.

use crate::crossbar::tile::TiledCrossbar;
use crate::device::params::DeviceParams;
use crate::util::rng::Xoshiro256;

use super::{
    clean_programmed_weight, probe_affine_fit, probe_input, slice_digits, slice_gain,
    MitigationConfig,
};

/// A mitigation-pipelined crossbar realization of a `rows x cols`
/// weight matrix (entries in `[-1, 1]`).
#[derive(Debug)]
pub struct MitigatedMatrix {
    rows: usize,
    cols: usize,
    /// `(combine weight, crossbar)` per programmed array.
    parts: Vec<(f64, TiledCrossbar)>,
    /// Per-column `(gain, offset)`; corrected read is `(y - o) / g`.
    cal: Option<Vec<(f64, f64)>>,
}

impl MitigatedMatrix {
    /// Program `w` (row-major, `[-1, 1]`) under the mitigation config.
    /// `verify` selects closed-loop write–verify programming (what the
    /// solvers deploy with).
    #[allow(clippy::too_many_arguments)]
    pub fn program(
        rows: usize,
        cols: usize,
        w: &[f32],
        params: &DeviceParams,
        tile_rows: usize,
        tile_cols: usize,
        rng: &mut Xoshiro256,
        cfg: &MitigationConfig,
        verify: bool,
    ) -> Self {
        assert_eq!(w.len(), rows * cols);
        let signs: &[f64] = if cfg.differential { &[1.0, -1.0] } else { &[1.0] };
        let pair_norm = 1.0 / signs.len() as f64;
        let gain = slice_gain(params);
        let digits = slice_digits(w, params, cfg.slices);

        let mut parts = Vec::with_capacity(cfg.array_count());
        // Clean model of the recombined realization (for calibration):
        // replicas share targets, so each (sign, slice) contributes
        // once with the replica normalization already folded in.
        let mut clean = if cfg.calibrate {
            vec![0.0f64; rows * cols]
        } else {
            Vec::new()
        };
        let mut target = vec![0.0f32; rows * cols];
        for &sign in signs {
            for (slice, plane) in digits.iter().enumerate() {
                for (t, &d) in target.iter_mut().zip(plane.iter()) {
                    *t = if sign >= 0.0 { d } else { -d };
                }
                let weight = sign * pair_norm * gain.powi(-(slice as i32));
                if cfg.calibrate {
                    for (acc, &t) in clean.iter_mut().zip(target.iter()) {
                        // sign folds into the realization of ±d; weight
                        // carries the sign back out, so accumulate the
                        // signed product.
                        *acc += weight * clean_programmed_weight(t, params, verify);
                    }
                }
                for _rep in 0..cfg.replicas {
                    let xbar = if verify {
                        TiledCrossbar::program_verified(
                            rows,
                            cols,
                            &target,
                            params,
                            tile_rows,
                            tile_cols,
                            rng,
                        )
                    } else {
                        TiledCrossbar::program(
                            rows,
                            cols,
                            &target,
                            params,
                            tile_rows,
                            tile_cols,
                            rng,
                        )
                    };
                    parts.push((weight / cfg.replicas as f64, xbar));
                }
            }
        }

        let mut m = Self { rows, cols, parts, cal: None };
        if cfg.calibrate {
            m.cal = Some(m.fit_calibration(&clean, cfg.probes));
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Physical crossbars in the pipeline.
    pub fn array_count(&self) -> usize {
        self.parts.len()
    }

    /// Recombined (uncalibrated) pipeline read.
    fn read_raw(&self, x: &[f32], y64: &mut [f64], scratch: &mut ReadScratch) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y64.len(), self.cols);
        scratch.prepare(self);
        y64.fill(0.0);
        for (weight, xbar) in &self.parts {
            xbar.read_with(x, &mut scratch.y32, &mut scratch.tx, &mut scratch.ty);
            for (acc, &v) in y64.iter_mut().zip(scratch.y32.iter()) {
                *acc += weight * v as f64;
            }
        }
    }

    /// Full mitigated read `y = x^T W` in weight units, staging
    /// through caller-owned scratch — the hot path for callers that
    /// read in a loop (solver iterations, probe sweeps).
    pub fn read_scratch(&self, x: &[f32], y: &mut [f32], scratch: &mut ReadScratch) {
        debug_assert_eq!(y.len(), self.cols);
        let mut y64 = std::mem::take(&mut scratch.y64);
        y64.resize(self.cols, 0.0);
        self.read_raw(x, &mut y64, scratch);
        if let Some(cal) = &self.cal {
            for (v, &(g, o)) in y64.iter_mut().zip(cal.iter()) {
                *v = (*v - o) / g;
            }
        }
        for (out, &v) in y.iter_mut().zip(y64.iter()) {
            *out = v as f32;
        }
        scratch.y64 = y64;
    }

    /// Full mitigated read `y = x^T W` in weight units (allocating
    /// convenience wrapper over [`MitigatedMatrix::read_scratch`]).
    pub fn read(&self, x: &[f32], y: &mut [f32]) {
        let mut scratch = ReadScratch::default();
        self.read_scratch(x, y, &mut scratch);
    }

    /// Convenience allocating read.
    pub fn read_vec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.cols];
        self.read(x, &mut y);
        y
    }

    /// Probe the programmed pipeline against the analytically clean
    /// recombined matrix and fit per-column affine distortion.
    fn fit_calibration(&self, clean: &[f64], probes: usize) -> Vec<(f64, f64)> {
        let (rows, cols) = (self.rows, self.cols);
        let mut yn = vec![vec![0.0f64; probes]; cols];
        let mut yc = vec![vec![0.0f64; probes]; cols];
        let mut x = vec![0.0f32; rows];
        let mut y64 = vec![0.0f64; cols];
        let mut scratch = ReadScratch::default();
        for k in 0..probes {
            for (i, xi) in x.iter_mut().enumerate() {
                *xi = probe_input(k, i, rows);
            }
            self.read_raw(&x, &mut y64, &mut scratch);
            for j in 0..cols {
                yn[j][k] = y64[j];
                let mut e = 0.0f64;
                for i in 0..rows {
                    e += x[i] as f64 * clean[i * cols + j];
                }
                yc[j][k] = e;
            }
        }
        (0..cols)
            .map(|j| probe_affine_fit(&yc[j], &yn[j]))
            .collect()
    }
}

/// Reusable staging buffers for [`MitigatedMatrix`] reads: the f32
/// partial-read plane, the tiled read's tile staging, and the f64
/// recombination accumulator.  `resize` is a no-op once warmed, so a
/// caller looping over reads (solver iterations, probe fits) pays zero
/// steady-state allocation.
#[derive(Debug, Default)]
pub struct ReadScratch {
    y32: Vec<f32>,
    tx: Vec<f32>,
    ty: Vec<f32>,
    y64: Vec<f64>,
}

impl ReadScratch {
    fn prepare(&mut self, m: &MitigatedMatrix) {
        self.y32.resize(m.cols, 0.0);
        if let Some((_, xbar)) = m.parts.first() {
            self.tx.resize(xbar.tile_rows(), 0.0);
            self.ty.resize(xbar.tile_cols(), 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    fn rand_w(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut w = vec![0.0f32; n];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        w
    }

    fn software_vmm(rows: usize, cols: usize, w: &[f32], x: &[f32]) -> Vec<f64> {
        (0..cols)
            .map(|j| {
                (0..rows)
                    .map(|i| x[i] as f64 * w[i * cols + j] as f64)
                    .sum()
            })
            .collect()
    }

    fn read_error_rms(m: &MitigatedMatrix, w: &[f32], seed: u64) -> f64 {
        let (rows, cols) = (m.rows(), m.cols());
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut x = vec![0.0f32; rows];
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for _ in 0..8 {
            rng.fill_uniform_f32(&mut x, 0.0, 1.0);
            let y = m.read_vec(&x);
            let want = software_vmm(rows, cols, w, &x);
            for j in 0..cols {
                let e = y[j] as f64 - want[j];
                sum += e * e;
                n += 1;
            }
        }
        (sum / n as f64).sqrt()
    }

    #[test]
    fn noop_matches_single_tiled_crossbar() {
        let (rows, cols) = (48, 40);
        let w = rand_w(rows * cols, 401);
        let params = presets::epiram().params;
        let m = MitigatedMatrix::program(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            &mut Xoshiro256::seed_from_u64(402),
            &MitigationConfig::NONE,
            true,
        );
        assert_eq!(m.array_count(), 1);
        let plain = TiledCrossbar::program_verified(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            &mut Xoshiro256::seed_from_u64(402),
        );
        let mut x = vec![0.0f32; rows];
        Xoshiro256::seed_from_u64(403).fill_uniform_f32(&mut x, -1.0, 1.0);
        assert_eq!(m.read_vec(&x), plain.read_vec(&x));
    }

    #[test]
    fn replica_averaging_tightens_reads() {
        let (rows, cols) = (32, 32);
        let w = rand_w(rows * cols, 404);
        let params = presets::epiram().params;
        let mut rng = Xoshiro256::seed_from_u64(405);
        let base = MitigatedMatrix::program(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            &mut rng,
            &MitigationConfig::NONE,
            true,
        );
        let avg = MitigatedMatrix::program(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            &mut rng,
            &MitigationConfig::parse("avg:4").unwrap(),
            true,
        );
        assert_eq!(avg.array_count(), 4);
        let e_base = read_error_rms(&base, &w, 406);
        let e_avg = read_error_rms(&avg, &w, 406);
        assert!(e_avg < e_base, "base {e_base} vs avg {e_avg}");
    }

    #[test]
    fn combined_pipeline_tightens_reads_further() {
        let (rows, cols) = (32, 32);
        let w = rand_w(rows * cols, 407);
        let params = presets::ag_si().params;
        let mut rng = Xoshiro256::seed_from_u64(408);
        let base = MitigatedMatrix::program(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            &mut rng,
            &MitigationConfig::NONE,
            true,
        );
        let full = MitigatedMatrix::program(
            rows,
            cols,
            &w,
            &params,
            32,
            32,
            &mut rng,
            &MitigationConfig::parse("diff,slice:2,avg:2,cal").unwrap(),
            true,
        );
        assert_eq!(full.array_count(), 8);
        let e_base = read_error_rms(&base, &w, 409);
        let e_full = read_error_rms(&full, &w, 409);
        assert!(e_full < e_base, "base {e_base} vs full {e_full}");
    }
}
