//! [`MitigatedEngine`]: a [`VmmEngine`] adapter that runs any inner
//! engine (native, tiled, …) through the composable mitigation
//! pipeline.
//!
//! The adapter expands each forward pass into a deterministic set of
//! *array variants* — the cartesian product of differential sign ×
//! bit-slice × replica — runs each variant through the inner engine
//! (inheriting its per-worker scratch parallelism unchanged), and
//! recombines the hardware outputs with the pipeline's linear weights
//! in f64.  Per-column affine calibration, when enabled, is estimated
//! from probe reads of the *same* combined pipeline against its
//! noise-free programming and inverted on the data reads.
//!
//! ## Determinism
//!
//! Every variant's noise is a pure per-sample function of the batch's
//! own noise planes (in-plane rotations by a variant-specific offset),
//! so results are independent of chunking and bit-identical for any
//! thread count — the same reproducibility contract the plain engines
//! honour (`rust/tests/integration_mitigation.rs` enforces it).
//! Replicas model *reprogramming cycles* of the same physical arrays:
//! they redraw the C2C planes but share the mismatch plane (mismatch is
//! a device property, which is exactly why averaging shrinks C2C by
//! ~`1/√R` but leaves the mismatch floor).
//!
//! Engines that pin batch sizes (the XLA artifact path) are not
//! supported behind calibration, which enlarges probe batches; use the
//! native or tiled engine.
//!
//! Known overhead: each inner `forward` also computes the engine's own
//! exact software reference, which the adapter discards (it computes
//! the reference once itself), and the calibration's clean reference is
//! a zero-noise *simulation* rather than the solver path's analytic
//! model — simulating keeps the noise-free pipeline an exact bitwise
//! identity, which the analytic f64 model cannot guarantee.  Removing
//! the duplicate reference would need a hardware-only method on the
//! `VmmEngine` contract; the `hotpath` bench prices the pipeline
//! end-to-end as is.

use crate::device::params::DeviceParams;
use crate::device::pulse::mismatch_transform;
use crate::error::Result;
use crate::vmm::engine::{DynEngine, VmmBatch, VmmEngine, VmmOutput};
use crate::vmm::program::{ProgramSpec, ProgrammedVmm, ReplayProgrammed};
use crate::vmm::software::software_vmm_batch;

use super::{probe_affine_fit, probe_input, slice_digits, slice_gain, MitigationConfig};

/// A mitigation pipeline wrapped around an inner compute engine.
#[derive(Debug, Clone)]
pub struct MitigatedEngine<E> {
    inner: E,
    cfg: MitigationConfig,
}

/// In-plane offsets decorrelating variant noise draws; both are odd, so
/// they are coprime with every power-of-two plane size and cycle the
/// whole plane before repeating.
const MISMATCH_STRIDE: usize = 131;
const C2C_STRIDE: usize = 257;

impl<E: VmmEngine> MitigatedEngine<E> {
    pub fn new(inner: E, cfg: MitigationConfig) -> Self {
        Self { inner, cfg }
    }

    pub fn config(&self) -> &MitigationConfig {
        &self.cfg
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Run the variant set and recombine into the mitigated hardware
    /// output (no calibration applied here).
    fn combined_forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        let cells = r * c;
        let signs: &[f64] = if cfg.differential { &[1.0, -1.0] } else { &[1.0] };
        let pair_norm = 1.0 / signs.len() as f64;
        let gain = slice_gain(params);
        let digits = slice_digits(&batch.w, params, cfg.slices);

        let mut acc = vec![0.0f64; b * c];
        let mut variant = VmmBatch::zeros(b, r, c);
        variant.x.copy_from_slice(&batch.x);

        for (si, &sign) in signs.iter().enumerate() {
            for (slice, plane) in digits.iter().enumerate() {
                // One physical array set per (sign, slice): distinct
                // devices, so all three noise planes are decorrelated.
                let array = si * cfg.slices + slice;
                if sign >= 0.0 {
                    variant.w.copy_from_slice(plane);
                } else {
                    for (dst, &d) in variant.w.iter_mut().zip(plane.iter()) {
                        *dst = -d;
                    }
                }
                for s in 0..b {
                    rotate_plane(
                        batch.z_of(s, 2),
                        array * MISMATCH_STRIDE,
                        plane_mut(&mut variant.z, s, 2, cells),
                    );
                }
                let combine = sign * pair_norm * gain.powi(-(slice as i32)) / cfg.replicas as f64;
                for rep in 0..cfg.replicas {
                    // Replicas reprogram the same arrays: fresh C2C
                    // draws, shared mismatch.
                    let cycle = (array * cfg.replicas + rep) * C2C_STRIDE;
                    for s in 0..b {
                        rotate_plane(
                            batch.z_of(s, 0),
                            cycle,
                            plane_mut(&mut variant.z, s, 0, cells),
                        );
                        rotate_plane(
                            batch.z_of(s, 1),
                            cycle,
                            plane_mut(&mut variant.z, s, 1, cells),
                        );
                    }
                    let out = self.inner.forward(&variant, params)?;
                    for (a, &y) in acc.iter_mut().zip(out.y_hw.iter()) {
                        *a += combine * y as f64;
                    }
                }
            }
        }
        Ok(acc.into_iter().map(|v| v as f32).collect())
    }

    /// Build the probe batch: `probes` reads per data sample, each with
    /// the sample's weights, a deterministic probe drive, and either
    /// the sample's noise planes (`noisy`) or zero noise (the known
    /// clean programming).
    fn probe_batch(&self, batch: &VmmBatch, noisy: bool) -> VmmBatch {
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        let p = self.cfg.probes;
        let cells = r * c;
        let mut pb = VmmBatch::zeros(b * p, r, c);
        for s in 0..b {
            for k in 0..p {
                let d = s * p + k;
                pb.w[d * cells..(d + 1) * cells].copy_from_slice(batch.w_of(s));
                for i in 0..r {
                    pb.x[d * r + i] = probe_input(k, i, r);
                }
                if noisy {
                    let src = (s * 3) * cells;
                    let dst = (d * 3) * cells;
                    pb.z[dst..dst + 3 * cells].copy_from_slice(&batch.z[src..src + 3 * cells]);
                }
            }
        }
        pb
    }

    /// Combined linear weight of the pipeline (what a constant per-cell
    /// read offset is multiplied by after recombination): zero under
    /// differential pairing, the slice-gain geometric sum otherwise.
    fn combine_weight_sum(&self, params: &DeviceParams) -> f64 {
        if self.cfg.differential {
            return 0.0;
        }
        let gain = slice_gain(params);
        (0..self.cfg.slices).map(|s| gain.powi(-(s as i32))).sum()
    }

    /// Estimate per-(sample, column) affine readout distortion from the
    /// probe reads and invert it on `y`.
    fn apply_calibration(
        &self,
        batch: &VmmBatch,
        params: &DeviceParams,
        y: &mut [f32],
    ) -> Result<()> {
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        let p = self.cfg.probes;
        let noisy = self.combined_forward(&self.probe_batch(batch, true), params)?;
        let clean = self.combined_forward(&self.probe_batch(batch, false), params)?;
        // The zero-noise probe programming still carries the
        // deterministic mismatch pedestal `m * h(0)` — the mismatch
        // transform is zero-mean in z, not zero at z = 0 — which would
        // bias the calibration target by `m * h(0) * sum(x)` per
        // column.  Subtract it analytically so the target models the
        // mismatch-free array, matching the solver path's analytic
        // clean model.  (Exactly zero on mismatch-free devices, so the
        // perfect-device identity property is preserved.)
        let mis0 = params.mismatch_scale() * mismatch_transform(0.0);
        let wsum = self.combine_weight_sum(params);
        let pedestal: Vec<f64> = (0..p)
            .map(|k| {
                let drive: f64 = (0..r).map(|i| probe_input(k, i, r) as f64).sum();
                mis0 * drive * wsum
            })
            .collect();
        let mut yc = vec![0.0f64; p];
        let mut yn = vec![0.0f64; p];
        for s in 0..b {
            for j in 0..c {
                for k in 0..p {
                    let idx = (s * p + k) * c + j;
                    yc[k] = clean[idx] as f64 - pedestal[k];
                    yn[k] = noisy[idx] as f64;
                }
                let (g, o) = probe_affine_fit(&yc, &yn);
                let idx = s * c + j;
                y[idx] = ((y[idx] as f64 - o) / g) as f32;
            }
        }
        Ok(())
    }
}

/// Copy `src` into `dst` rotated left by `offset` (mod the plane
/// length).  Offset 0 is the identity, so the base variant consumes the
/// batch's noise verbatim.
fn rotate_plane(src: &[f32], offset: usize, dst: &mut [f32]) {
    let n = src.len();
    let off = offset % n.max(1);
    dst[..n - off].copy_from_slice(&src[off..]);
    dst[n - off..].copy_from_slice(&src[..off]);
}

/// Mutable view of sample `s`, channel `ch` of a packed noise buffer.
fn plane_mut(z: &mut [f32], s: usize, ch: usize, cells: usize) -> &mut [f32] {
    let base = (s * 3 + ch) * cells;
    &mut z[base..base + cells]
}

impl<E: VmmEngine + Clone + 'static> VmmEngine for MitigatedEngine<E> {
    fn name(&self) -> &'static str {
        "mitigated"
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        if self.cfg.is_noop() {
            return self.inner.forward(batch, params);
        }
        let y_sw = software_vmm_batch(batch);
        let mut y_hw = self.combined_forward(batch, params)?;
        if self.cfg.calibrate {
            self.apply_calibration(batch, params, &mut y_hw)?;
        }
        Ok(VmmOutput { y_hw, y_sw })
    }

    fn preferred_batches(&self) -> Vec<usize> {
        self.inner.preferred_batches()
    }

    fn internal_parallelism(&self) -> usize {
        self.inner.internal_parallelism()
    }

    /// The mitigation pipeline rotates noise planes per variant, so a
    /// materialized single-array program cannot represent it; serving
    /// replays the full mitigated forward per read batch —
    /// bit-identical, unamortized (the variant arrays themselves are
    /// reprogrammed per read, exactly as the batch path does).
    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        spec.check()?;
        Ok(ProgrammedVmm::new(
            spec,
            ReplayProgrammed::new(DynEngine::new(self.clone()), spec.clone(), *params),
        ))
    }

    fn cache_config(&self) -> String {
        format!("mitigated[{}]:{}", self.cfg.label(), self.inner.cache_config())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::stats::moments::Moments;
    use crate::util::rng::Xoshiro256;
    use crate::vmm::NativeEngine;

    fn random_batch(b: usize, r: usize, c: usize, seed: u64) -> VmmBatch {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut vb = VmmBatch::zeros(b, r, c);
        rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut vb.x, 0.0, 1.0);
        rng.fill_normal_f32(&mut vb.z);
        vb
    }

    fn engine(spec: &str) -> MitigatedEngine<NativeEngine> {
        MitigatedEngine::new(
            NativeEngine::default(),
            MitigationConfig::parse(spec).unwrap(),
        )
    }

    fn err_var(spec: &str, b: &VmmBatch, params: &DeviceParams) -> f64 {
        let out = engine(spec).forward(b, params).unwrap();
        Moments::from_slice(&out.errors()).variance()
    }

    #[test]
    fn noop_config_delegates_bitwise() {
        let b = random_batch(6, 32, 32, 301);
        let params = presets::ag_si().params;
        let plain = NativeEngine::default().forward(&b, &params).unwrap();
        let wrapped = engine("none").forward(&b, &params).unwrap();
        assert_eq!(plain.y_hw, wrapped.y_hw);
        assert_eq!(plain.y_sw, wrapped.y_sw);
    }

    #[test]
    fn rotate_plane_identity_and_cycle() {
        let src = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let mut dst = vec![0.0f32; 5];
        rotate_plane(&src, 0, &mut dst);
        assert_eq!(dst, src);
        rotate_plane(&src, 2, &mut dst);
        assert_eq!(dst, vec![3.0, 4.0, 5.0, 1.0, 2.0]);
        rotate_plane(&src, 7, &mut dst);
        assert_eq!(dst, vec![3.0, 4.0, 5.0, 1.0, 2.0]);
    }

    #[test]
    fn replica_averaging_shrinks_c2c_variance() {
        // EpiRAM is C2C-dominated: averaging 4 reprogramming cycles
        // must cut the error variance well below the single-cycle run.
        let b = random_batch(48, 32, 32, 302);
        let params = presets::epiram().params;
        let v1 = err_var("none", &b, &params);
        let v4 = err_var("avg:4", &b, &params);
        assert!(v4 < v1 * 0.8, "v1={v1} v4={v4}");
    }

    #[test]
    fn differential_pair_reduces_bias() {
        // Strong-NL Ag:a-Si: the deterministic encoding bias dominates
        // the mean error; the complementary array cancels it.
        let b = random_batch(48, 32, 32, 303);
        let params = presets::ag_si().params;
        let base = engine("none").forward(&b, &params).unwrap();
        let diff = engine("diff").forward(&b, &params).unwrap();
        let mb = Moments::from_slice(&base.errors()).mean().abs();
        let md = Moments::from_slice(&diff.errors()).mean().abs();
        assert!(md < mb, "base mean {mb}, diff mean {md}");
    }

    #[test]
    fn slicing_restores_resolution_on_coarse_device() {
        // A quantization-limited device: 3-bit states, no NL, no C2C.
        let params = DeviceParams::ideal().with_weight_bits(3);
        let b = random_batch(16, 32, 32, 304);
        let v1 = err_var("none", &b, &params);
        let v2 = err_var("slice:2", &b, &params);
        assert!(v2 < v1 * 0.1, "v1={v1} v2={v2}");
    }

    #[test]
    fn calibration_never_explodes_error() {
        let b = random_batch(24, 32, 32, 305);
        let params = presets::epiram().params;
        let base = err_var("none", &b, &params);
        let cal = err_var("cal", &b, &params);
        assert!(cal.is_finite() && cal < base * 2.0, "base={base} cal={cal}");
    }

    #[test]
    fn combined_pipeline_beats_baseline() {
        let b = random_batch(48, 32, 32, 306);
        let params = presets::epiram().params;
        let base = err_var("none", &b, &params);
        let full = err_var("diff,slice:2,avg:4,cal", &b, &params);
        assert!(full < base, "base={base} full={full}");
    }

    #[test]
    fn works_through_tiled_engine_at_nonpaper_geometry() {
        let b = random_batch(4, 48, 40, 307);
        let params = presets::epiram().params;
        let eng = MitigatedEngine::new(
            crate::vmm::TiledEngine::default(),
            MitigationConfig::parse("diff,avg:2").unwrap(),
        );
        let out = eng.forward(&b, &params).unwrap();
        assert_eq!(out.y_hw.len(), 4 * 40);
        assert!(out.errors().iter().all(|e| e.is_finite()));
    }
}
