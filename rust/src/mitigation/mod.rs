//! Error-mitigation strategies for crossbar VMM — the "and mitigating"
//! half of the paper's abstract, following the integrated-correction
//! direction of arXiv:2508.13298 and the bit-sliced multi-crossbar
//! encodings of the N-ary crossbar literature.
//!
//! Four composable strategies, each a physically meaningful circuit
//! technique (DESIGN.md §10):
//!
//! * **Differential-pair encoding** (`diff`) — program a complementary
//!   array with `-W` and read `y = (y⁺ - y⁻) / 2`.  Common-mode
//!   additive programming bias (the deterministic non-linearity offset,
//!   the mean of the baseline mismatch) cancels; independent random
//!   terms average down by 2 in variance.
//! * **Bit-slicing** (`slice:K`) — split each weight across `K`
//!   crossbars with power-of-two inter-slice gains: slice 0 carries the
//!   coarse value, each further slice carries the previous slice's
//!   *quantization residual* amplified to full range.  Recombining with
//!   gains `G⁻ⁱ` multiplies the effective state count by ~`G` per
//!   slice (the N-ary multi-crossbar encoding); it attacks pulse-count
//!   quantization, not programming noise or open-loop NL distortion.
//! * **Replica averaging** (`avg:R`) — program `R` copies and average
//!   the reads; cycle-to-cycle programming noise shrinks like `1/√R`.
//! * **Affine read calibration** (`cal`) — estimate a per-column
//!   `(gain, offset)` from probe reads against the known clean
//!   (noise-free) programming of the same targets, then invert it on
//!   every read — a per-column generalization of the coordinator's
//!   offset trim.
//!
//! Strategies compose freely (`diff,slice:2,avg:4,cal`), are available
//! on the engine path ([`MitigatedEngine`] wraps any
//! [`crate::vmm::VmmEngine`]) and on the solver path
//! ([`MitigatedMatrix`] backs
//! [`crate::solver::CrossbarOperator`]), and are plumbed through the
//! CLI (`--mitigation`) and TOML (`mitigation = "..."`).

pub mod engine;
pub mod matrix;

pub use engine::MitigatedEngine;
pub use matrix::{MitigatedMatrix, ReadScratch};

use crate::device::params::DeviceParams;
use crate::device::pulse::{nl_to_curvature, pulse_curve};
use crate::error::{Error, Result};

/// Which mitigation strategies are active, and their strengths.
///
/// The default is the identity pipeline (no mitigation): every field at
/// its neutral value.  Build from a CLI/TOML spec with
/// [`MitigationConfig::parse`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MitigationConfig {
    /// Differential-pair encoding (complementary `-W` array).
    pub differential: bool,
    /// Bit-slice count (1 = off).
    pub slices: usize,
    /// Replica count for read averaging (1 = off).
    pub replicas: usize,
    /// Per-column affine read calibration.
    pub calibrate: bool,
    /// Probe reads used by the calibration fit (>= 3).
    pub probes: usize,
}

impl Default for MitigationConfig {
    fn default() -> Self {
        Self::NONE
    }
}

impl MitigationConfig {
    /// The identity pipeline: no strategy active.
    pub const NONE: MitigationConfig = MitigationConfig {
        differential: false,
        slices: 1,
        replicas: 1,
        calibrate: false,
        probes: 4,
    };

    /// Parse a comma-separated strategy spec, e.g.
    /// `"diff,slice:2,avg:4,cal"`.  `""` and `"none"` give the identity
    /// pipeline.
    pub fn parse(spec: &str) -> Result<MitigationConfig> {
        let mut cfg = MitigationConfig::NONE;
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(cfg);
        }
        for token in spec.split(',') {
            let token = token.trim();
            let (name, arg) = match token.split_once(':') {
                Some((n, a)) => (n, Some(a)),
                None => (token, None),
            };
            match name {
                "diff" => {
                    if arg.is_some() {
                        return Err(Error::Config("diff takes no argument".into()));
                    }
                    cfg.differential = true;
                }
                "slice" => {
                    let k: usize = parse_arg("slice", arg)?;
                    if !(1..=8).contains(&k) {
                        return Err(Error::Config(format!(
                            "slice:K needs K in 1..=8, got {k}"
                        )));
                    }
                    cfg.slices = k;
                }
                "avg" => {
                    let r: usize = parse_arg("avg", arg)?;
                    if !(1..=64).contains(&r) {
                        return Err(Error::Config(format!(
                            "avg:R needs R in 1..=64, got {r}"
                        )));
                    }
                    cfg.replicas = r;
                }
                "cal" => {
                    cfg.calibrate = true;
                    if let Some(a) = arg {
                        let p: usize = a.parse().map_err(|_| {
                            Error::Config(format!("cal:P: bad number '{a}'"))
                        })?;
                        if !(3..=16).contains(&p) {
                            return Err(Error::Config(format!(
                                "cal:P needs P in 3..=16, got {p}"
                            )));
                        }
                        cfg.probes = p;
                    }
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown mitigation '{other}' (diff|slice:K|avg:R|cal[:P])"
                    )));
                }
            }
        }
        Ok(cfg)
    }

    /// True when no strategy is active (the identity pipeline).
    pub fn is_noop(&self) -> bool {
        !self.differential && self.slices <= 1 && self.replicas <= 1 && !self.calibrate
    }

    /// Canonical human-readable label (`"none"`, `"diff+avg:4"`, …).
    pub fn label(&self) -> String {
        if self.is_noop() {
            return "none".into();
        }
        let mut parts = Vec::new();
        if self.differential {
            parts.push("diff".to_string());
        }
        if self.slices > 1 {
            parts.push(format!("slice:{}", self.slices));
        }
        if self.replicas > 1 {
            parts.push(format!("avg:{}", self.replicas));
        }
        if self.calibrate {
            parts.push("cal".to_string());
        }
        parts.join("+")
    }

    /// Physical crossbar arrays the pipeline programs per logical
    /// matrix (cost multiplier for programming).
    pub fn array_count(&self) -> usize {
        (if self.differential { 2 } else { 1 }) * self.slices * self.replicas
    }
}

fn parse_arg(name: &str, arg: Option<&str>) -> Result<usize> {
    let a = arg.ok_or_else(|| Error::Config(format!("{name}:N needs a value")))?;
    a.parse()
        .map_err(|_| Error::Config(format!("{name}:N: bad number '{a}'")))
}

/// Power-of-two inter-slice gain matched to the device resolution:
/// `2^floor(log2(states))`, so each further slice refines the previous
/// one's residual by roughly one full device word.
pub fn slice_gain(params: &DeviceParams) -> f64 {
    let bits = params.states.max(2.0).log2().floor() as i32;
    (2.0f64).powi(bits.clamp(1, 15))
}

/// The differential weight the device would *deterministically* realize
/// for target `v` (pulse-count quantization plus the open-loop NL
/// curve; under write–verify the NL deviation is nulled, leaving pure
/// quantization).  This is the model knowledge a closed-loop
/// program-and-verify controller has about its own write, and what the
/// bit-slice residuals are computed against.
pub fn clean_programmed_weight(v: f32, params: &DeviceParams, verify: bool) -> f64 {
    let n = params.states - 1.0;
    let wi = v as f64;
    // f32 rounding of the pulse targets mirrors `CrossbarArray`.
    let s_pos = (((1.0 + wi) * 0.5 * n) as f32).round() as f64;
    let s_neg = (((1.0 - wi) * 0.5 * n) as f32).round() as f64;
    if verify {
        return (s_pos - s_neg) / n;
    }
    let kp = nl_to_curvature(params.nu_ltp);
    let kd = nl_to_curvature(params.nu_ltd);
    let g_pos = pulse_curve(s_pos / n, kp).clamp(0.0, 1.0);
    let g_neg = pulse_curve(s_neg / n, kd).clamp(0.0, 1.0);
    g_pos - g_neg
}

/// Compute the `k` bit-slice digit planes for target weights `w`
/// (any length, cell-independent).  Slice 0 is the raw target; slice
/// `i+1` carries slice `i`'s pulse-count *quantization* residual
/// amplified by the inter-slice gain and clamped to the programmable
/// range.  Recombine reads with weights `G⁻ⁱ`.
///
/// Residuals are computed against the quantized target (classic digit
/// decomposition), not the NL-distorted open-loop realization: on a
/// strongly non-linear device an amplified model-based correction would
/// itself be distorted at full scale, so slicing deliberately targets
/// only the resolution limit.
pub fn slice_digits(w: &[f32], params: &DeviceParams, k: usize) -> Vec<Vec<f32>> {
    assert!(k >= 1, "slice count must be >= 1");
    let gain = slice_gain(params);
    let mut out = vec![vec![0.0f32; w.len()]; k];
    for (i, &wi) in w.iter().enumerate() {
        let mut resid = wi as f64;
        let mut scale = 1.0f64;
        for (s, plane) in out.iter_mut().enumerate() {
            let d = (resid * scale).clamp(-1.0, 1.0) as f32;
            plane[i] = d;
            if s + 1 < k {
                resid -= clean_programmed_weight(d, params, true) / scale;
                scale *= gain;
            }
        }
    }
    out
}

/// Deterministic probe drive vector `k` over `rows` word lines.  The
/// four base profiles (flat, ramp up, ramp down, alternating) span
/// enough input variation for a per-column affine fit; higher probe
/// indices reuse the profiles at reduced amplitude.
pub fn probe_input(k: usize, i: usize, rows: usize) -> f32 {
    let amp = 1.0 / (1 + k / 4) as f32;
    let base = match k % 4 {
        0 => 0.5,
        1 => (i + 1) as f32 / rows as f32,
        2 => 1.0 - i as f32 / rows as f32,
        _ => {
            if i % 2 == 0 {
                0.25
            } else {
                0.75
            }
        }
    };
    amp * base
}

/// Least-squares affine fit `y_noisy ≈ g · y_clean + o` over probe
/// pairs, with a guarded fallback to a pure offset trim when the fit is
/// degenerate or implausible.  Returns `(g, o)`; correct a read with
/// `(y - o) / g`.
pub fn probe_affine_fit(y_clean: &[f64], y_noisy: &[f64]) -> (f64, f64) {
    let n = y_clean.len() as f64;
    debug_assert_eq!(y_clean.len(), y_noisy.len());
    if y_clean.len() < 2 {
        return (1.0, 0.0);
    }
    let mc = y_clean.iter().sum::<f64>() / n;
    let mn = y_noisy.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut var = 0.0;
    for (&c, &y) in y_clean.iter().zip(y_noisy) {
        let dc = c - mc;
        cov += dc * (y - mn);
        var += dc * dc;
    }
    if var < 1e-18 {
        return (1.0, mn - mc);
    }
    let g = cov / var;
    if !g.is_finite() || !(0.25..=4.0).contains(&g) {
        // Implausible column gain: fall back to offset-only trim.
        return (1.0, mn - mc);
    }
    (g, mn - g * mc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    #[test]
    fn parse_roundtrip_and_labels() {
        let c = MitigationConfig::parse("diff,slice:2,avg:4,cal").unwrap();
        assert!(c.differential && c.calibrate);
        assert_eq!(c.slices, 2);
        assert_eq!(c.replicas, 4);
        assert_eq!(c.label(), "diff+slice:2+avg:4+cal");
        assert_eq!(c.array_count(), 16);

        let none = MitigationConfig::parse("").unwrap();
        assert!(none.is_noop());
        assert_eq!(none.label(), "none");
        assert_eq!(MitigationConfig::parse("none").unwrap(), none);
        assert_eq!(none.array_count(), 1);

        let avg = MitigationConfig::parse(" avg:2 ").unwrap();
        assert_eq!(avg.replicas, 2);
        assert!(!avg.is_noop());
        assert_eq!(avg.label(), "avg:2");

        let cal = MitigationConfig::parse("cal:8").unwrap();
        assert_eq!(cal.probes, 8);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(MitigationConfig::parse("frob").is_err());
        assert!(MitigationConfig::parse("slice").is_err());
        assert!(MitigationConfig::parse("slice:0").is_err());
        assert!(MitigationConfig::parse("slice:99").is_err());
        assert!(MitigationConfig::parse("avg:zero").is_err());
        assert!(MitigationConfig::parse("avg:100").is_err());
        assert!(MitigationConfig::parse("diff:2").is_err());
        assert!(MitigationConfig::parse("cal:2").is_err());
    }

    #[test]
    fn slice_gain_tracks_device_resolution() {
        assert_eq!(slice_gain(&presets::epiram().params), 64.0); // 64 states
        assert_eq!(slice_gain(&presets::ag_si().params), 64.0); // 97 states
        assert_eq!(slice_gain(&presets::alox_hfo2().params), 32.0); // 40 states
    }

    #[test]
    fn clean_programmed_weight_is_quantized_target() {
        let params = crate::device::params::DeviceParams::ideal().with_weight_bits(6);
        // No NL: the clean realized weight is the pulse-quantized target.
        for &v in &[0.0f32, 0.5, -0.73, 1.0, -1.0] {
            let got = clean_programmed_weight(v, &params, false);
            assert!((got - v as f64).abs() <= 1.0 / 63.0 + 1e-9, "v={v} got={got}");
        }
        // Verified path quantizes but never applies the NL curve.
        let nl = params.with_nonlinearity(2.4, -4.88);
        let open = clean_programmed_weight(0.5, &nl, false);
        let ver = clean_programmed_weight(0.5, &nl, true);
        assert!((ver - 0.5).abs() < 0.02);
        assert!((open - 0.5).abs() > (ver - 0.5).abs());
    }

    #[test]
    fn slice_digits_refine_the_quantization_residual() {
        let params = presets::ag_si().params; // 97 states, G = 64
        let w: Vec<f32> = vec![0.3, -0.87, 0.501, 0.0, 1.0, -1.0, 0.013];
        let digits = slice_digits(&w, &params, 3);
        let gain = slice_gain(&params);
        for (i, &wi) in w.iter().enumerate() {
            // Recombined quantized realization must beat single-array
            // pulse-count quantization.
            let single = (clean_programmed_weight(wi, &params, true) - wi as f64).abs();
            let mut combined = 0.0f64;
            let mut scale = 1.0f64;
            for plane in digits.iter() {
                combined += clean_programmed_weight(plane[i], &params, true) / scale;
                scale *= gain;
            }
            let sliced = (combined - wi as f64).abs();
            assert!(
                sliced <= single + 1e-12,
                "w={wi}: sliced {sliced} vs single {single}"
            );
            // Three slices: residual below one part in G^2 of a step.
            assert!(sliced < 1e-4, "w={wi}: sliced {sliced}");
        }
        // Digits stay programmable.
        for plane in &digits {
            assert!(plane.iter().all(|d| (-1.0..=1.0).contains(d)));
        }
    }

    #[test]
    fn probe_inputs_vary_across_probes() {
        let rows = 32;
        for k in 0..8 {
            let v: Vec<f32> = (0..rows).map(|i| probe_input(k, i, rows)).collect();
            assert!(v.iter().all(|&x| (0.0..=1.0).contains(&x)), "probe {k}");
        }
        // Distinct profiles: flat vs ramp.
        assert_ne!(probe_input(0, 3, rows), probe_input(1, 3, rows));
    }

    #[test]
    fn affine_fit_recovers_distortion() {
        let clean: Vec<f64> = vec![0.1, 0.9, -0.4, 0.5];
        let noisy: Vec<f64> = clean.iter().map(|&c| 1.1 * c + 0.07).collect();
        let (g, o) = probe_affine_fit(&clean, &noisy);
        assert!((g - 1.1).abs() < 1e-12);
        assert!((o - 0.07).abs() < 1e-12);
        // Identity data yields the exact identity map.
        let (g, o) = probe_affine_fit(&clean, &clean);
        assert_eq!(g, 1.0);
        assert_eq!(o, 0.0);
        // Degenerate clean variance: offset-only fallback.
        let flat = vec![0.5; 4];
        let off: Vec<f64> = flat.iter().map(|&c| c + 0.2).collect();
        let (g, o) = probe_affine_fit(&flat, &off);
        assert_eq!(g, 1.0);
        assert!((o - 0.2).abs() < 1e-12);
    }
}
