//! Serializable point-in-time copies of the metrics registry.
//!
//! A [`MetricsSnapshot`] exports through the artifact codec in both
//! framings: pretty JSON (`METRICS.json`) and a MELB envelope under
//! its own tag ([`crate::util::codec::METRICS_SNAPSHOT`], disjoint
//! from value and transport tags).  Snapshots subtract
//! ([`MetricsSnapshot::delta_since`]) so a caller can bracket a
//! workload and report exactly its activity, and merge
//! (element-wise, order-independent) so fleet-wide telemetry is a
//! fold over per-node snapshots in any order.

use crate::error::{Error, Result};
use crate::util::codec::{decode_envelope, encode_envelope, METRICS_SNAPSHOT};
use crate::util::json::{obj, Json};

use super::hist::HistogramSnapshot;
use super::registry::{CounterId, GaugeId, Stage};

/// Snapshot document schema version (DESIGN.md §17).
pub const SNAPSHOT_VERSION: u64 = 1;

/// A plain-value copy of every registry metric.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter totals, indexed by [`CounterId`] discriminant.
    pub counters: [u64; CounterId::COUNT],
    /// Gauge levels, indexed by [`GaugeId`] discriminant.
    pub gauges: [u64; GaugeId::COUNT],
    /// Per-stage latency histograms, indexed by [`Stage`] discriminant.
    pub stages: [HistogramSnapshot; Stage::COUNT],
}

impl MetricsSnapshot {
    /// An all-zero snapshot (the merge identity).
    pub fn empty() -> Self {
        const E: HistogramSnapshot = HistogramSnapshot {
            counts: [0; super::hist::BUCKETS],
            count: 0,
            sum: 0,
        };
        Self {
            counters: [0; CounterId::COUNT],
            gauges: [0; GaugeId::COUNT],
            stages: [E; Stage::COUNT],
        }
    }

    /// Total for counter `id`.
    pub fn counter(&self, id: CounterId) -> u64 {
        self.counters[id.index()]
    }

    /// Level of gauge `id`.
    pub fn gauge(&self, id: GaugeId) -> u64 {
        self.gauges[id.index()]
    }

    /// Latency histogram for stage `id`.
    pub fn stage(&self, id: Stage) -> &HistogramSnapshot {
        &self.stages[id.index()]
    }

    /// The activity between `base` (earlier) and `self` (later):
    /// counters and stage histograms subtract (saturating), gauges are
    /// levels and keep the later value.
    pub fn delta_since(&self, base: &MetricsSnapshot) -> MetricsSnapshot {
        let mut out = self.clone();
        for i in 0..CounterId::COUNT {
            out.counters[i] = self.counters[i].saturating_sub(base.counters[i]);
        }
        for i in 0..Stage::COUNT {
            out.stages[i] = self.stages[i].delta_since(&base.stages[i]);
        }
        out
    }

    /// Element-wise rollup: counters and stage histograms add, gauges
    /// add too (fleet-wide residency/depth is the sum of per-node
    /// levels).  Associative and commutative, so any rollup order
    /// produces the identical fleet snapshot.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for i in 0..CounterId::COUNT {
            self.counters[i] += other.counters[i];
        }
        for i in 0..GaugeId::COUNT {
            self.gauges[i] += other.gauges[i];
        }
        for i in 0..Stage::COUNT {
            let h = other.stages[i].clone();
            self.stages[i].merge(&h);
        }
    }

    /// Total nanoseconds recorded across every stage — the per-stage
    /// accounting sum the breakdown perf test checks against measured
    /// end-to-end latency.
    pub fn stage_sum_ns(&self) -> u64 {
        self.stages.iter().map(|h| h.sum).sum()
    }

    /// Snapshot document (DESIGN.md §17): named counters/gauges plus a
    /// per-stage histogram object, deterministic key order via
    /// [`Json::Obj`].
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            CounterId::ALL
                .iter()
                .map(|id| (id.name().to_string(), Json::Num(self.counter(*id) as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            GaugeId::ALL
                .iter()
                .map(|id| (id.name().to_string(), Json::Num(self.gauge(*id) as f64)))
                .collect(),
        );
        let stages = Json::Obj(
            Stage::ALL
                .iter()
                .map(|id| (id.name().to_string(), self.stage(*id).to_json()))
                .collect(),
        );
        obj([
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("counters", counters),
            ("gauges", gauges),
            ("stages", stages),
        ])
    }

    /// Strict parse of the snapshot document.  Unknown counter/gauge/
    /// stage names are ignored (forward compatibility — additive
    /// metrics never bump the version), missing ones read as zero, but
    /// a wrong version or a malformed histogram is a typed error.
    pub fn from_json(doc: &Json) -> Result<Self> {
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Parse("metrics snapshot: missing version".into()))?
            as u64;
        if version > SNAPSHOT_VERSION {
            return Err(Error::Parse(format!(
                "metrics snapshot: version {version} is newer than this binary \
                 ({SNAPSHOT_VERSION})"
            )));
        }
        let mut snap = MetricsSnapshot::empty();
        if let Some(counters) = doc.get("counters").and_then(Json::as_obj) {
            for id in CounterId::ALL {
                if let Some(v) = counters.get(id.name()) {
                    snap.counters[id.index()] = v
                        .as_usize()
                        .ok_or_else(|| {
                            Error::Parse(format!("metrics snapshot: bad counter {}", id.name()))
                        })? as u64;
                }
            }
        }
        if let Some(gauges) = doc.get("gauges").and_then(Json::as_obj) {
            for id in GaugeId::ALL {
                if let Some(v) = gauges.get(id.name()) {
                    snap.gauges[id.index()] = v
                        .as_usize()
                        .ok_or_else(|| {
                            Error::Parse(format!("metrics snapshot: bad gauge {}", id.name()))
                        })? as u64;
                }
            }
        }
        if let Some(stages) = doc.get("stages").and_then(Json::as_obj) {
            for id in Stage::ALL {
                if let Some(v) = stages.get(id.name()) {
                    snap.stages[id.index()] = HistogramSnapshot::from_json(v)?;
                }
            }
        }
        Ok(snap)
    }

    /// One MELB envelope frame under the metrics tag.  Fallible like
    /// every binary encode (the u32 frame-field bound), though a
    /// snapshot's fixed metric names can never trip it in practice.
    pub fn encode_melb(&self) -> crate::error::Result<Vec<u8>> {
        encode_envelope(METRICS_SNAPSHOT, &self.to_json())
    }

    /// Decode one metrics frame.  Rejects other envelope tags, any
    /// truncated or oversized frame (the hardened reader bounds every
    /// declared length), and trailing bytes — a metrics artifact is a
    /// single frame, not a stream.
    pub fn decode_melb(bytes: &[u8]) -> Result<Self> {
        let (tag, payload, used) = decode_envelope(bytes)?;
        if tag != METRICS_SNAPSHOT {
            return Err(Error::Parse(format!(
                "metrics snapshot: envelope tag {tag:#04x} is not the metrics \
                 tag ({METRICS_SNAPSHOT:#04x})"
            )));
        }
        if used != bytes.len() {
            return Err(Error::Parse(format!(
                "metrics snapshot: {} trailing bytes",
                bytes.len() - used
            )));
        }
        Self::from_json(&payload)
    }
}

impl Default for MetricsSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::codec::ENVELOPE_REQUEST;

    fn sample() -> MetricsSnapshot {
        let mut s = MetricsSnapshot::empty();
        s.counters[CounterId::CacheHits.index()] = 12;
        s.counters[CounterId::BytesOut.index()] = 4096;
        s.gauges[GaugeId::CacheEntries.index()] = 3;
        for v in [100u64, 2_000, 2_000, 1 << 22] {
            s.stages[Stage::Read.index()].record(v);
        }
        s.stages[Stage::QueueWait.index()].record(5_000);
        s
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let s = sample();
        let back = MetricsSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.counter(CounterId::CacheHits), 12);
        assert_eq!(back.stage(Stage::Read).count, 4);
        assert_eq!(s.stage_sum_ns(), 100 + 2_000 + 2_000 + (1 << 22) + 5_000);
    }

    #[test]
    fn melb_round_trip_and_tag_rejection() {
        let s = sample();
        let frame = s.encode_melb().unwrap();
        assert_eq!(MetricsSnapshot::decode_melb(&frame).unwrap(), s);
        // A transport envelope is not a metrics artifact.
        let wire = encode_envelope(ENVELOPE_REQUEST, &s.to_json()).unwrap();
        assert!(MetricsSnapshot::decode_melb(&wire).is_err());
        // Trailing bytes are rejected (single-frame artifact).
        let mut padded = frame.clone();
        padded.push(0);
        assert!(MetricsSnapshot::decode_melb(&padded).is_err());
    }

    #[test]
    fn delta_and_merge_invert() {
        let base = sample();
        let mut later = sample();
        later.counters[CounterId::CacheHits.index()] += 5;
        later.stages[Stage::Read.index()].record(999);
        let delta = later.delta_since(&base);
        assert_eq!(delta.counter(CounterId::CacheHits), 5);
        assert_eq!(delta.stage(Stage::Read).count, 1);
        assert_eq!(delta.stage(Stage::Read).sum, 999);
        let mut rebuilt = base.clone();
        rebuilt.merge(&delta);
        // Gauges are levels: delta keeps the later value, so align
        // them before comparing the additive parts.
        rebuilt.gauges = later.gauges;
        assert_eq!(rebuilt, later);
    }

    #[test]
    fn newer_version_is_rejected_unknown_names_ignored() {
        let mut doc = sample().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("version".into(), Json::Num((SNAPSHOT_VERSION + 1) as f64));
        }
        assert!(MetricsSnapshot::from_json(&doc).is_err());
        let mut doc = sample().to_json();
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(c)) = m.get_mut("counters") {
                c.insert("a_future_counter".into(), Json::Num(7.0));
            }
        }
        assert_eq!(MetricsSnapshot::from_json(&doc).unwrap(), sample());
    }
}
