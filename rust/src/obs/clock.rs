//! Monotonic time behind a mockable trait.
//!
//! Every stage-tracing measurement in [`crate::obs`] reads time
//! through [`Clock`], so tests can drive spans with a [`MockClock`]
//! and assert exact bucket placement, while production uses one
//! process-wide [`MonotonicClock`].  The trait deals in nanoseconds
//! since an arbitrary fixed origin — only differences are meaningful,
//! which is exactly what histograms of durations need.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// A monotone nanosecond clock.  `now_ns` is non-decreasing; the
/// origin is arbitrary (only differences between two readings carry
/// meaning).
pub trait Clock: Send + Sync {
    /// Nanoseconds since the clock's (arbitrary) origin.
    fn now_ns(&self) -> u64;
}

/// Production clock: a process-lifetime `Instant` anchor, lazily
/// pinned at the first reading.  Anchoring (instead of calling
/// `Instant::now` twice per span and subtracting `Instant`s) keeps
/// the reading a plain `u64`, so span math is integer arithmetic and
/// the histogram never sees a non-monotone value.
pub struct MonotonicClock {
    anchor: OnceLock<Instant>,
}

impl MonotonicClock {
    /// Unanchored clock; the origin pins at the first `now_ns` call.
    pub const fn new() -> Self {
        Self { anchor: OnceLock::new() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        let anchor = self.anchor.get_or_init(Instant::now);
        anchor.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }
}

/// Test clock: time advances only when told to, so span durations are
/// exact and deterministic.
#[derive(Default)]
pub struct MockClock {
    now: AtomicU64,
}

impl MockClock {
    /// A mock clock reading 0 until advanced.
    pub const fn new() -> Self {
        Self { now: AtomicU64::new(0) }
    }

    /// Move time forward by `ns` nanoseconds.
    pub fn advance(&self, ns: u64) {
        self.now.fetch_add(ns, Ordering::Relaxed);
    }

    /// Jump time to an absolute reading of `ns` nanoseconds.
    pub fn set(&self, ns: u64) {
        self.now.store(ns, Ordering::Relaxed);
    }
}

impl Clock for MockClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_is_non_decreasing() {
        let c = MonotonicClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn mock_clock_advances_exactly() {
        let c = MockClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(1_500);
        assert_eq!(c.now_ns(), 1_500);
        c.set(42);
        assert_eq!(c.now_ns(), 42);
    }
}
