//! Fixed log2-bucket latency histograms with order-independent merge.
//!
//! A [`Histogram`] is 64 atomic buckets over `u64` nanosecond values:
//! value `v` lands in bucket `floor(log2 v)` (bucket 0 holds `{0, 1}`),
//! so bucket `i >= 1` covers `[2^i, 2^(i+1))` and the full `u64` range
//! is representable with no configuration and no allocation.
//! Percentiles are answered from the bucket's geometric-mean
//! representative `sqrt(2) * 2^i`, which bounds the relative error of
//! any quoted percentile by `sqrt(2)` (DESIGN.md §17) — the price of
//! an O(1)-memory, lock-free, mergeable sketch over exact sorted
//! samples.
//!
//! [`HistogramSnapshot::merge`] is element-wise addition, hence
//! associative and commutative: a fleet rollup equals any permutation
//! of per-node rollups bit-for-bit (proptested in
//! `tests/proptests.rs`).  The `sum` field is an *exact* nanosecond
//! total (not bucketed), which is what the per-stage breakdown
//! accounting checks against end-to-end latency.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::json::{obj, Json};

/// Number of log2 buckets — one per `u64` bit, covering every
/// possible nanosecond duration.
pub const BUCKETS: usize = 64;

/// Lock-free concurrent histogram of `u64` values (nanoseconds by
/// convention).  All operations are `Relaxed` atomics: each recording
/// is an independent event on independent atomics, `fetch_add` never
/// loses an increment at any ordering, and every exact read
/// (snapshots for reports) happens after the recording threads are
/// joined, which already establishes happens-before.
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    /// An empty histogram (`const`: usable in `static` initializers).
    pub const fn new() -> Self {
        const Z: AtomicU64 = AtomicU64::new(0);
        Self { counts: [Z; BUCKETS], count: AtomicU64::new(0), sum: AtomicU64::new(0) }
    }

    /// Bucket index of a value: `floor(log2 v)`, with 0 mapping to
    /// bucket 0 (so bucket 0 holds `{0, 1}`).
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            63 - v.leading_zeros() as usize
        }
    }

    /// Record one value (nanoseconds by convention).
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a [`Duration`] as nanoseconds (saturating at `u64::MAX`).
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Owned point-in-time copy (exact after writers are joined).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (dst, src) in counts.iter_mut().zip(self.counts.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistogramSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    /// Zero every bucket and the exact totals.
    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A consistent point-in-time copy of a [`Histogram`]: plain `u64`s,
/// mergeable, serializable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket counts (`counts[i]` = values in `[2^i, 2^(i+1))`,
    /// bucket 0 = `{0, 1}`).
    pub counts: [u64; BUCKETS],
    /// Total recorded values (`== counts.sum()`).
    pub count: u64,
    /// Exact (unbucketed) sum of recorded values, nanoseconds.
    pub sum: u64,
}

impl HistogramSnapshot {
    /// An all-zero snapshot (the merge identity).
    pub fn empty() -> Self {
        Self { counts: [0; BUCKETS], count: 0, sum: 0 }
    }

    /// Record into a snapshot directly (single-threaded accumulation,
    /// e.g. a collector thread folding latencies).
    pub fn record(&mut self, v: u64) {
        self.counts[Histogram::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Record a [`Duration`] as nanoseconds (saturating at `u64::MAX`).
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Element-wise addition — associative and commutative, so any
    /// rollup order produces the identical merged histogram.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (dst, src) in self.counts.iter_mut().zip(other.counts.iter()) {
            *dst += src;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Element-wise `saturating_sub` against an earlier snapshot of
    /// the same histogram: the activity between the two points.
    pub fn delta_since(&self, base: &HistogramSnapshot) -> HistogramSnapshot {
        let mut counts = [0u64; BUCKETS];
        for i in 0..BUCKETS {
            counts[i] = self.counts[i].saturating_sub(base.counts[i]);
        }
        HistogramSnapshot {
            counts,
            count: self.count.saturating_sub(base.count),
            sum: self.sum.saturating_sub(base.sum),
        }
    }

    /// Has nothing been recorded?
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Mean of recorded values, nanoseconds (NaN when empty) — exact,
    /// from the unbucketed sum.
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile, answered as the geometric-mean
    /// representative of the bucket holding that rank (nanoseconds;
    /// NaN when empty).  Monotone in `p`; relative error bounded by
    /// `sqrt(2)` (see the module docs).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Self::representative(i);
            }
        }
        Self::representative(BUCKETS - 1)
    }

    /// Percentile in milliseconds — the unit every serving report
    /// quotes.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile(p) / 1e6
    }

    /// Geometric mean of a bucket's bounds: `sqrt(2^i * 2^(i+1))
    /// = sqrt(2) * 2^i` (bucket 0, holding `{0, 1}`, answers 1).
    fn representative(i: usize) -> f64 {
        if i == 0 {
            1.0
        } else {
            std::f64::consts::SQRT_2 * (i as f64).exp2()
        }
    }

    /// JSON shape (DESIGN.md §17): exact `count`/`sum_ns` plus sparse
    /// `[bucket, count]` pairs for the non-empty buckets.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)]))
            .collect();
        obj([
            ("count", Json::Num(self.count as f64)),
            ("sum_ns", Json::Num(self.sum as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    /// Strict parse: bucket indices must be in range and the sparse
    /// bucket counts must total `count`, so a truncated or corrupted
    /// document is a typed error, never a silently-wrong histogram.
    pub fn from_json(doc: &Json) -> Result<Self> {
        let count = doc
            .get("count")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Parse("histogram: missing count".into()))?
            as u64;
        let sum = doc
            .get("sum_ns")
            .and_then(Json::as_f64)
            .filter(|s| *s >= 0.0 && s.is_finite())
            .ok_or_else(|| Error::Parse("histogram: missing sum_ns".into()))?
            as u64;
        let pairs = doc
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Parse("histogram: missing buckets".into()))?;
        let mut counts = [0u64; BUCKETS];
        let mut total = 0u64;
        for pair in pairs {
            let pair = pair
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| Error::Parse("histogram: bucket must be [index, count]".into()))?;
            let i = pair[0]
                .as_usize()
                .filter(|&i| i < BUCKETS)
                .ok_or_else(|| Error::Parse("histogram: bucket index out of range".into()))?;
            let c = pair[1]
                .as_usize()
                .ok_or_else(|| Error::Parse("histogram: bad bucket count".into()))?
                as u64;
            counts[i] = counts[i]
                .checked_add(c)
                .ok_or_else(|| Error::Parse("histogram: bucket count overflow".into()))?;
            total += c;
        }
        if total != count {
            return Err(Error::Parse(format!(
                "histogram: bucket counts total {total}, declared count {count}"
            )));
        }
        Ok(Self { counts, count, sum })
    }
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_mapping_covers_the_u64_range() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(1 << 20), 20);
        assert_eq!(Histogram::bucket_of((1 << 21) - 1), 20);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
    }

    #[test]
    fn record_and_snapshot_are_exact_on_count_and_sum() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 1000, 1 << 30] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 3 + 1000 + (1 << 30));
        assert_eq!(s.counts.iter().sum::<u64>(), 5);
        h.reset();
        assert!(h.snapshot().is_empty());
    }

    #[test]
    fn percentiles_are_monotone_and_error_bounded() {
        let mut s = HistogramSnapshot::empty();
        // 100 values spread over three decades.
        for i in 0..100u64 {
            s.record(1_000 + i * 10_000);
        }
        let (p50, p95, p99) = (s.percentile(50.0), s.percentile(95.0), s.percentile(99.0));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // sqrt(2) relative error bound against the exact nearest-rank
        // answer over the raw samples.
        let exact_p95 = 1_000.0 + 94.0 * 10_000.0;
        let ratio = p95 / exact_p95;
        assert!(
            ratio <= std::f64::consts::SQRT_2 && ratio >= 1.0 / std::f64::consts::SQRT_2,
            "p95 {p95} vs exact {exact_p95}"
        );
        assert!(HistogramSnapshot::empty().percentile(50.0).is_nan());
    }

    #[test]
    fn merge_is_element_wise_addition() {
        let mut a = HistogramSnapshot::empty();
        let mut b = HistogramSnapshot::empty();
        a.record(10);
        a.record(5_000);
        b.record(9);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.count, 3);
        assert_eq!(ab.sum, 10 + 5_000 + 9);
        // Delta inverts merge.
        assert_eq!(ab.delta_since(&b), a);
    }

    #[test]
    fn json_round_trip_and_strict_rejections() {
        let mut s = HistogramSnapshot::empty();
        for v in [0u64, 3, 70, 70, 1 << 40] {
            s.record(v);
        }
        let back = HistogramSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // Inconsistent declared count is rejected.
        let mut doc = s.to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("count".into(), Json::Num(99.0));
        }
        assert!(HistogramSnapshot::from_json(&doc).is_err());
        // Out-of-range bucket index is rejected.
        let bad = obj([
            ("count", Json::Num(1.0)),
            ("sum_ns", Json::Num(1.0)),
            (
                "buckets",
                Json::Arr(vec![Json::Arr(vec![Json::Num(64.0), Json::Num(1.0)])]),
            ),
        ]);
        assert!(HistogramSnapshot::from_json(&bad).is_err());
    }

    #[test]
    fn concurrent_recording_never_under_counts() {
        // The Relaxed-ordering contract: 4 threads x 10_000 increments
        // land exactly, because fetch_add is an atomic RMW and the
        // join establishes the happens-before for the final read.
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 7 + (i % 97));
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count, 40_000);
        assert_eq!(snap.counts.iter().sum::<u64>(), 40_000);
    }
}
