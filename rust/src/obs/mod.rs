//! Unified telemetry: structured metrics and stage-level tracing.
//!
//! One registry ([`registry()`]), one stage taxonomy ([`Stage`]), one
//! rollup semantics ([`MetricsSnapshot::merge`]) — every subsystem
//! that used to keep ad-hoc counters (shard fault stats, program-cache
//! hit/miss, per-node rollups, scheduler latency samples) reports
//! through here, so `meliso metrics`, `serve-bench`, and `fleet-bench`
//! all quote the same numbers with the same bucket semantics.
//!
//! Two standing invariants, both asserted by tests:
//!
//! * **Telemetry never perturbs results.**  Instrumentation only reads
//!   clocks and bumps atomics; the bit-identity proptests run every
//!   engine with observability on and off and require identical
//!   outputs.
//! * **Near-zero cost when disabled.**  The registry is disabled by
//!   default; every helper below starts with one `Relaxed` load and a
//!   branch, touching no clock and no other atomics when the gate is
//!   off.  When enabled, the `serve-cached-128` perf test bounds the
//!   overhead budget.
//!
//! Recording goes through the free functions ([`incr`], [`record`],
//! [`time_stage`], [`stage_start`]/[`stage_end`]) so call sites stay
//! one line.  Time comes from a [`Clock`] so tests can drive spans
//! deterministically with a [`MockClock`].

#![warn(missing_docs)]

pub mod clock;
pub mod hist;
pub mod registry;
pub mod snapshot;

pub use clock::{Clock, MockClock, MonotonicClock};
pub use hist::{Histogram, HistogramSnapshot, BUCKETS};
pub use registry::{registry, Counter, CounterId, Gauge, GaugeId, Registry, Stage};
pub use snapshot::{MetricsSnapshot, SNAPSHOT_VERSION};

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// The process-wide production clock (anchored on first use).
static CLOCK: MonotonicClock = MonotonicClock::new();

/// Nanoseconds from the process clock (monotone, arbitrary origin).
pub fn now_ns() -> u64 {
    CLOCK.now_ns()
}

/// Is global telemetry collection on?
#[inline]
pub fn enabled() -> bool {
    registry().enabled()
}

/// Turn global telemetry collection on or off.  Tests that flip this
/// must hold [`test_lock`] — the gate is process-wide.
pub fn set_enabled(on: bool) {
    registry().set_enabled(on);
}

/// Increment a registry counter by one (no-op while disabled).
#[inline]
pub fn incr(id: CounterId) {
    let r = registry();
    if r.enabled() {
        r.counter(id).incr();
    }
}

/// Add `n` to a registry counter (no-op while disabled).
#[inline]
pub fn add(id: CounterId, n: u64) {
    let r = registry();
    if r.enabled() {
        r.counter(id).add(n);
    }
}

/// Set a registry gauge (no-op while disabled).
#[inline]
pub fn gauge_set(id: GaugeId, v: u64) {
    let r = registry();
    if r.enabled() {
        r.gauge(id).set(v);
    }
}

/// Record a duration into a stage histogram (no-op while disabled).
#[inline]
pub fn record(stage: Stage, d: Duration) {
    let r = registry();
    if r.enabled() {
        r.stage(stage).record_duration(d);
    }
}

/// Record raw nanoseconds into a stage histogram (no-op while
/// disabled).
#[inline]
pub fn record_ns(stage: Stage, ns: u64) {
    let r = registry();
    if r.enabled() {
        r.stage(stage).record(ns);
    }
}

/// Start a stage measurement: a clock reading while enabled, `None`
/// while disabled.  Pair with [`stage_end`] when the span does not fit
/// a closure (e.g. it brackets a lock region with early returns).
#[inline]
pub fn stage_start() -> Option<u64> {
    if enabled() {
        Some(now_ns())
    } else {
        None
    }
}

/// Finish a measurement begun by [`stage_start`].  Tolerates the gate
/// flipping mid-span (a `None` start records nothing).
#[inline]
pub fn stage_end(stage: Stage, start: Option<u64>) {
    if let Some(t0) = start {
        let r = registry();
        if r.enabled() {
            r.stage(stage).record(now_ns().saturating_sub(t0));
        }
    }
}

/// Time a closure as one stage span.  Generic over the return type, so
/// fallible work passes through untouched:
///
/// ```ignore
/// let out = obs::time_stage(Stage::Read, || handle.read(&input))?;
/// ```
#[inline]
pub fn time_stage<T>(stage: Stage, f: impl FnOnce() -> T) -> T {
    let start = stage_start();
    let out = f();
    stage_end(stage, start);
    out
}

/// A span that records into an explicit histogram through an explicit
/// clock on drop — the mockable building block underneath the global
/// helpers, used directly by tests that assert exact bucket placement.
pub struct StageSpan<'a> {
    clock: &'a dyn Clock,
    hist: &'a Histogram,
    start: u64,
}

impl<'a> StageSpan<'a> {
    /// Open a span now; it records `end - start` into `hist` on drop.
    pub fn start(clock: &'a dyn Clock, hist: &'a Histogram) -> Self {
        Self { clock, hist, start: clock.now_ns() }
    }
}

impl Drop for StageSpan<'_> {
    fn drop(&mut self) {
        self.hist.record(self.clock.now_ns().saturating_sub(self.start));
    }
}

static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Serializes tests that enable the global registry or assert on its
/// deltas.  Cargo runs tests in parallel within a binary; without this
/// lock, one test's instrumentation would bleed into another's
/// snapshot.  Poisoning is ignored — the lock guards test isolation,
/// not data integrity.
pub fn test_lock() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_helpers_record_nothing() {
        let _guard = test_lock();
        set_enabled(false);
        registry().reset();
        incr(CounterId::CacheHits);
        add(CounterId::BytesOut, 128);
        gauge_set(GaugeId::QueueDepth, 9);
        record_ns(Stage::Read, 1_000);
        time_stage(Stage::Program, || ());
        assert!(stage_start().is_none());
        let s = registry().snapshot();
        assert_eq!(s, MetricsSnapshot::empty());
    }

    #[test]
    fn enabled_helpers_record_and_reset_clears() {
        let _guard = test_lock();
        registry().reset();
        set_enabled(true);
        incr(CounterId::RequestsServed);
        add(CounterId::BytesIn, 64);
        gauge_set(GaugeId::CacheEntries, 2);
        record_ns(Stage::QueueWait, 4_096);
        let got = time_stage(Stage::Read, || 7u32);
        assert_eq!(got, 7);
        let s = registry().snapshot();
        set_enabled(false);
        // `>=`: while the gate is on, parallel tests traversing
        // instrumented paths may also record into the global registry —
        // exact accounting is pinned in the isolated `integration_obs`
        // binary.
        assert!(s.counter(CounterId::RequestsServed) >= 1);
        assert!(s.counter(CounterId::BytesIn) >= 64);
        assert!(s.stage(Stage::QueueWait).count >= 1);
        assert!(s.stage(Stage::QueueWait).sum >= 4_096);
        assert!(s.stage(Stage::Read).count >= 1);
        // Gate now off: nothing can record, so reset leaves an empty
        // registry.
        registry().reset();
        assert_eq!(registry().snapshot(), MetricsSnapshot::empty());
    }

    #[test]
    fn stage_span_records_exact_durations_via_mock_clock() {
        let clock = MockClock::new();
        let hist = Histogram::new();
        {
            let _span = StageSpan::start(&clock, &hist);
            clock.advance(4_096);
        }
        {
            let _span = StageSpan::start(&clock, &hist);
            clock.advance(10);
        }
        let s = hist.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.sum, 4_106);
        assert_eq!(s.counts[12], 1); // 4096 = 2^12
        assert_eq!(s.counts[3], 1); // 10 in [8, 16)
    }
}
