//! The process-wide metrics registry: enum-indexed atomic counters,
//! gauges, and per-stage histograms behind one `enabled` gate.
//!
//! The registry is a `const`-initialized `static` — no lazy init, no
//! locks, no allocation.  Counter and stage identities are closed
//! enums, so every metric access is an array index into pre-existing
//! atomics: recording is a handful of `Relaxed` atomic ops, and the
//! *disabled* path through the [`crate::obs`] helpers is a single
//! relaxed load and a branch (no clock read, no atomics touched) —
//! the zero-cost contract the serve perf test asserts.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use super::hist::Histogram;
use super::snapshot::MetricsSnapshot;

/// A monotone event counter.
///
/// All operations are deliberately `Ordering::Relaxed`: each
/// increment is an independent atomic RMW on a single cell (no
/// increment can be lost at any ordering), the counter never
/// publishes other memory, and every read that must be exact happens
/// after the writing threads are joined — the join is the
/// happens-before edge, not the counter.  The `MELISO_THREADS=4`
/// consistency tests pin this down with known workloads.
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter (`const`: usable in `static` initializers).
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Count one event.
    #[inline]
    pub fn incr(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` events at once.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current total (exact only after writers are joined).
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Zero the counter.
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Counter").field(&self.get()).finish()
    }
}

/// A last-value-wins level gauge (`Relaxed` for the same reasons as
/// [`Counter`]; concurrent `set`s race benignly — a gauge is a
/// sample, not a ledger).
pub struct Gauge(AtomicU64);

impl Gauge {
    /// A zeroed gauge (`const`: usable in `static` initializers).
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Record the current level.
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Last recorded level.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Zero the gauge.
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Gauge").field(&self.get()).finish()
    }
}

/// The request-lifecycle stage taxonomy (DESIGN.md §17).  Stages are
/// recorded at the *call sites that own the work* — never inside the
/// engines a stage delegates to — so stage durations never nest and
/// their sum accounts for end-to-end latency once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Enqueue to the moment a worker starts serving the request.
    QueueWait,
    /// Window time spent coalescing a batch after its first request.
    BatchCoalesce,
    /// Program-cache probe (lock + LRU touch), hit or miss.
    CacheLookup,
    /// Crossbar programming on a cache miss or uncached serve (the
    /// fused program+read path attributes the whole fused call here).
    Program,
    /// Programmed-crossbar read at the serve call site.
    Read,
    /// Envelope serialization onto the transport boundary.
    TransportEncode,
    /// Envelope deserialization off the transport boundary.
    TransportDecode,
    /// ABFT checksum verify/correct during sharded reads.
    ShardVerify,
    /// One layer forward inside the inference pipeline.
    PipelineLayer,
    /// Time a request sat queued before admission control shed it at
    /// `pop_batch` for a missed deadline (the wasted wait — work the
    /// queue held but never served; DESIGN.md §18).
    ShedWait,
}

impl Stage {
    /// Number of stages (sizes the registry and snapshot arrays).
    pub const COUNT: usize = 10;

    /// Every stage, in lifecycle order — the single source of the
    /// stage list for snapshots, tables, and accounting sums.
    pub const ALL: [Stage; Self::COUNT] = [
        Stage::QueueWait,
        Stage::BatchCoalesce,
        Stage::CacheLookup,
        Stage::Program,
        Stage::Read,
        Stage::TransportEncode,
        Stage::TransportDecode,
        Stage::ShardVerify,
        Stage::PipelineLayer,
        Stage::ShedWait,
    ];

    /// Stable snake_case name (snapshot JSON keys, table rows).
    pub fn name(&self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchCoalesce => "batch_coalesce",
            Stage::CacheLookup => "cache_lookup",
            Stage::Program => "program",
            Stage::Read => "read",
            Stage::TransportEncode => "transport_encode",
            Stage::TransportDecode => "transport_decode",
            Stage::ShardVerify => "shard_verify",
            Stage::PipelineLayer => "pipeline_layer",
            Stage::ShedWait => "shed_wait",
        }
    }

    pub(crate) fn index(&self) -> usize {
        *self as usize
    }
}

/// Registry-wide event counters — the migrated union of the formerly
/// ad-hoc serve/shard telemetry, plus the admission-control family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterId {
    /// Requests fully served (decoded back to the client).
    RequestsServed,
    /// Batches executed by scheduler workers.
    BatchesServed,
    /// Program-cache hits.
    CacheHits,
    /// Program-cache misses.
    CacheMisses,
    /// Program-cache LRU evictions.
    CacheEvictions,
    /// Crossbar programming passes executed.
    ProgramsExecuted,
    /// Crossbar reads executed.
    ReadsExecuted,
    /// Transport bytes received (decoded envelopes).
    BytesIn,
    /// Transport bytes sent (encoded envelopes).
    BytesOut,
    /// Faults injected by the shard fault model.
    FaultsInjected,
    /// Faults flagged by the checksum verifier.
    FaultsDetected,
    /// Faults corrected by the checksum reduction.
    FaultsCorrected,
    /// Faults detected but beyond the code's correction radius.
    FaultsUncorrectable,
    /// Requests bounced off a closed node queue and re-routed by the
    /// fleet router (detours — still served; DESIGN.md §18).
    RequestsShed,
    /// Admissions refused because the queue was full in shed-on-full
    /// mode (never served).
    AdmissionRejected,
    /// Admissions refused because the SLO deadline had already passed
    /// at `push` (never queued).
    AdmissionExpired,
    /// Queued requests dropped at `pop_batch` because their deadline
    /// expired while waiting (never served).
    AdmissionDeadlineMissed,
}

impl CounterId {
    /// Number of counters (sizes the registry and snapshot arrays).
    pub const COUNT: usize = 17;

    /// Every counter, in declaration order (index order).
    pub const ALL: [CounterId; Self::COUNT] = [
        CounterId::RequestsServed,
        CounterId::BatchesServed,
        CounterId::CacheHits,
        CounterId::CacheMisses,
        CounterId::CacheEvictions,
        CounterId::ProgramsExecuted,
        CounterId::ReadsExecuted,
        CounterId::BytesIn,
        CounterId::BytesOut,
        CounterId::FaultsInjected,
        CounterId::FaultsDetected,
        CounterId::FaultsCorrected,
        CounterId::FaultsUncorrectable,
        CounterId::RequestsShed,
        CounterId::AdmissionRejected,
        CounterId::AdmissionExpired,
        CounterId::AdmissionDeadlineMissed,
    ];

    /// Stable snake_case name (snapshot JSON keys, table rows).
    pub fn name(&self) -> &'static str {
        match self {
            CounterId::RequestsServed => "requests_served",
            CounterId::BatchesServed => "batches_served",
            CounterId::CacheHits => "cache_hits",
            CounterId::CacheMisses => "cache_misses",
            CounterId::CacheEvictions => "cache_evictions",
            CounterId::ProgramsExecuted => "programs_executed",
            CounterId::ReadsExecuted => "reads_executed",
            CounterId::BytesIn => "bytes_in",
            CounterId::BytesOut => "bytes_out",
            CounterId::FaultsInjected => "faults_injected",
            CounterId::FaultsDetected => "faults_detected",
            CounterId::FaultsCorrected => "faults_corrected",
            CounterId::FaultsUncorrectable => "faults_uncorrectable",
            CounterId::RequestsShed => "requests_shed",
            CounterId::AdmissionRejected => "admission_rejected",
            CounterId::AdmissionExpired => "admission_expired",
            CounterId::AdmissionDeadlineMissed => "admission_deadline_missed",
        }
    }

    pub(crate) fn index(&self) -> usize {
        *self as usize
    }
}

/// Level gauges (instantaneous values, sampled not summed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GaugeId {
    /// Program-cache resident entries.
    CacheEntries,
    /// Bounded-queue depth at the last scheduler touch.
    QueueDepth,
    /// Requests popped from a node's queue and not yet served (sampled
    /// per node at batch boundaries; with queue depth it makes up the
    /// load signal the fleet router's least-loaded placement reads).
    NodeInflight,
}

impl GaugeId {
    /// Number of gauges (sizes the registry and snapshot arrays).
    pub const COUNT: usize = 3;

    /// Every gauge, in declaration order (index order).
    pub const ALL: [GaugeId; Self::COUNT] =
        [GaugeId::CacheEntries, GaugeId::QueueDepth, GaugeId::NodeInflight];

    /// Stable snake_case name (snapshot JSON keys, table rows).
    pub fn name(&self) -> &'static str {
        match self {
            GaugeId::CacheEntries => "cache_entries",
            GaugeId::QueueDepth => "queue_depth",
            GaugeId::NodeInflight => "node_inflight",
        }
    }

    pub(crate) fn index(&self) -> usize {
        *self as usize
    }
}

/// The metrics registry: one `enabled` gate, one atomic cell per
/// counter/gauge, one [`Histogram`] per stage.
pub struct Registry {
    enabled: AtomicBool,
    counters: [Counter; CounterId::COUNT],
    gauges: [Gauge; GaugeId::COUNT],
    stages: [Histogram; Stage::COUNT],
}

impl Registry {
    /// A zeroed, *disabled* registry.  `const`, so the process-wide
    /// instance is ready before any instrumented code runs; local
    /// instances make exact-count unit tests trivial:
    ///
    /// ```
    /// use meliso::obs::{CounterId, Registry, Stage};
    ///
    /// let r = Registry::new();
    /// r.counter(CounterId::CacheHits).incr();
    /// r.counter(CounterId::CacheHits).add(2);
    /// r.stage(Stage::Read).record(1_500);
    /// let snap = r.snapshot();
    /// assert_eq!(snap.counter(CounterId::CacheHits), 3);
    /// assert_eq!(snap.stage(Stage::Read).count, 1);
    /// ```
    pub const fn new() -> Self {
        const C: Counter = Counter::new();
        const G: Gauge = Gauge::new();
        const H: Histogram = Histogram::new();
        Self {
            enabled: AtomicBool::new(false),
            counters: [C; CounterId::COUNT],
            gauges: [G; GaugeId::COUNT],
            stages: [H; Stage::COUNT],
        }
    }

    /// Is recording on?  The [`crate::obs`] helpers check this before
    /// touching any metric (the disabled path is this load + branch).
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip recording on or off (existing values are untouched).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// The counter cell for `id`.
    pub fn counter(&self, id: CounterId) -> &Counter {
        &self.counters[id.index()]
    }

    /// The gauge cell for `id`.
    pub fn gauge(&self, id: GaugeId) -> &Gauge {
        &self.gauges[id.index()]
    }

    /// The latency histogram for stage `id`.
    pub fn stage(&self, id: Stage) -> &Histogram {
        &self.stages[id.index()]
    }

    /// Zero every metric (the `enabled` gate is left as-is).
    pub fn reset(&self) {
        for c in &self.counters {
            c.reset();
        }
        for g in &self.gauges {
            g.reset();
        }
        for h in &self.stages {
            h.reset();
        }
    }

    /// Copy every metric into an owned, serializable
    /// [`MetricsSnapshot`] (values are read `Relaxed`; snapshot after
    /// joining writers for exact totals).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::empty();
        for id in CounterId::ALL {
            snap.counters[id.index()] = self.counter(id).get();
        }
        for id in GaugeId::ALL {
            snap.gauges[id.index()] = self.gauge(id).get();
        }
        for id in Stage::ALL {
            snap.stages[id.index()] = self.stage(id).snapshot();
        }
        snap
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// The process-wide registry (`const`-initialized: ready before any
/// instrumented code can run, with no lazy-init branch on the hot
/// path).
static GLOBAL: Registry = Registry::new();

/// The process-wide [`Registry`] every instrumented subsystem records
/// into.
pub fn registry() -> &'static Registry {
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enum_indices_match_the_all_arrays() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i, "{}", s.name());
        }
        for (i, c) in CounterId::ALL.iter().enumerate() {
            assert_eq!(c.index(), i, "{}", c.name());
        }
        for (i, g) in GaugeId::ALL.iter().enumerate() {
            assert_eq!(g.index(), i, "{}", g.name());
        }
    }

    #[test]
    fn local_registry_counts_and_resets() {
        let r = Registry::new();
        assert!(!r.enabled());
        r.counter(CounterId::CacheHits).add(3);
        r.gauge(GaugeId::QueueDepth).set(7);
        r.stage(Stage::Read).record(1_000);
        let s = r.snapshot();
        assert_eq!(s.counter(CounterId::CacheHits), 3);
        assert_eq!(s.gauge(GaugeId::QueueDepth), 7);
        assert_eq!(s.stage(Stage::Read).count, 1);
        r.reset();
        let s = r.snapshot();
        assert_eq!(s.counter(CounterId::CacheHits), 0);
        assert_eq!(s.stage(Stage::Read).count, 0);
    }

    #[test]
    fn concurrent_counter_increments_are_exact() {
        // The deliberate-Relaxed contract under the thread-matrix
        // width: 4 writers, a known per-writer workload, exact total.
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = &c;
                s.spawn(move || {
                    for _ in 0..25_000 {
                        c.incr();
                    }
                });
            }
        });
        assert_eq!(c.get(), 100_000);
    }
}
