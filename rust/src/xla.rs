//! Stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The offline registry does not vendor the `xla` crate, so this module
//! provides the exact API surface [`crate::runtime`] consumes, with
//! every runtime entry point failing honestly: [`PjRtClient::cpu`]
//! returns an error, which [`crate::runtime::XlaRuntime::new`] surfaces
//! as `Error::Xla` and the CLI reports as "engine unavailable".  The
//! native and tiled engines cover every benchmark without it.
//!
//! When a real PJRT binding is vendored, delete this module, add the
//! dependency, and the rest of the crate compiles unchanged — the
//! signatures below mirror xla-rs 0.5.x.

use std::path::Path;

/// Error type of the stubbed binding.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(
            "PJRT/XLA backend is not built into this binary (the `xla` \
             crate is not vendored in the offline registry); use \
             `--engine native` or `--engine tiled`"
                .to_string(),
        )
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// PJRT client handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub build.
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors `execute::<Literal>(&[Literal])` of the real binding:
    /// one buffer row per device, one buffer per output.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer handle returned by [`PjRtLoadedExecutable::execute`].
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (text form).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<Self, Error> {
        // Validate readability so the error names the real problem
        // (missing artifact vs missing backend) even in the stub.
        std::fs::read_to_string(path)
            .map_err(|e| Error(format!("cannot read HLO text {}: {e}", path.display())))?;
        Err(Error::unavailable())
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host literal (typed tensor) handle.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable_with_actionable_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("native"), "{msg}");
    }

    #[test]
    fn hlo_text_error_distinguishes_missing_file() {
        let err = HloModuleProto::from_text_file(Path::new("/nonexistent/m.hlo.txt"))
            .unwrap_err();
        assert!(err.to_string().contains("cannot read"), "{err}");
    }
}
