//! Jacobi iteration with the off-diagonal products on the operator.
//!
//! `x_{k+1} = D^{-1} (b - R x_k)` where `R = A - D`.  On a crossbar
//! operator the full product `A x` is read in one analog step and the
//! diagonal correction happens digitally — the split used by memristor
//! solver proposals (Liu et al. 2018).

use super::operator::LinearOperator;
use super::{norm2, SolveOpts, SolveResult};
use crate::error::{Error, Result};

/// Solve `A x = b` by Jacobi iteration.  `diag` is the exact diagonal
/// of `A` (digitally stored, as in the hybrid analog/digital scheme);
/// `op` provides the (possibly noisy) full product.  `exact` computes
/// the honest residual history.
pub fn jacobi(
    op: &dyn LinearOperator,
    exact: &dyn LinearOperator,
    diag: &[f64],
    b: &[f64],
    opts: &SolveOpts,
) -> Result<SolveResult> {
    let (n, m) = op.dim();
    if n != m {
        return Err(Error::Solver(format!("jacobi needs square A, got {n}x{m}")));
    }
    if diag.iter().any(|&d| d.abs() < 1e-14) {
        return Err(Error::Solver("jacobi: zero diagonal entry".into()));
    }
    let bnorm = norm2(b).max(1e-30);
    let mut x = vec![0.0; n];
    let mut ax = vec![0.0; n];
    let mut history = Vec::with_capacity(opts.max_iters);

    for k in 0..opts.max_iters {
        // x' = x + D^{-1} (b - A x): equivalent splitting that needs
        // only the full product.
        op.apply(&x, &mut ax);
        for i in 0..n {
            x[i] += (b[i] - ax[i]) / diag[i];
        }
        // True residual on the exact operator.
        exact.apply(&x, &mut ax);
        let res: f64 = norm2(
            &b.iter()
                .zip(&ax)
                .map(|(bi, ai)| bi - ai)
                .collect::<Vec<f64>>(),
        ) / bnorm;
        history.push(res);
        if res < opts.tol {
            return Ok(SolveResult {
                x,
                iterations: k + 1,
                converged: true,
                residual_history: history,
            });
        }
        if !res.is_finite() || res > 1e12 {
            return Err(Error::Solver(format!("jacobi diverged at iter {k}")));
        }
    }
    Ok(SolveResult {
        x,
        iterations: opts.max_iters,
        converged: false,
        residual_history: history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::operator::ExactOperator;
    use crate::util::rng::Xoshiro256;

    /// Diagonally dominant random system (Jacobi-convergent).
    pub(crate) fn dd_system(n: usize, seed: u64) -> (ExactOperator, Vec<f64>, Vec<f64>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            let mut row_sum = 0.0;
            for j in 0..n {
                if i != j {
                    let v = rng.uniform_in(-0.5, 0.5);
                    a[i * n + j] = v;
                    row_sum += v.abs();
                }
            }
            a[i * n + i] = row_sum + rng.uniform_in(0.5, 1.5);
        }
        let diag: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        (ExactOperator::new(n, n, a), diag, b)
    }

    #[test]
    fn converges_on_diagonally_dominant() {
        let (a, diag, b) = dd_system(24, 171);
        let r = jacobi(&a, &a, &diag, &b, &SolveOpts::default()).unwrap();
        assert!(r.converged, "history tail: {:?}", r.residual_history.last());
        // Verify the solution satisfies the system.
        let mut ax = vec![0.0; 24];
        a.apply(&r.x, &mut ax);
        for i in 0..24 {
            assert!((ax[i] - b[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn residual_history_decreases_overall() {
        let (a, diag, b) = dd_system(16, 172);
        let r = jacobi(&a, &a, &diag, &b, &SolveOpts::default()).unwrap();
        let h = &r.residual_history;
        assert!(h[h.len() - 1] < h[0]);
    }

    #[test]
    fn rejects_nonsquare_and_zero_diag() {
        let rect = ExactOperator::new(2, 3, vec![0.0; 6]);
        assert!(jacobi(&rect, &rect, &[1.0, 1.0], &[0.0, 0.0], &SolveOpts::default())
            .is_err());
        let (a, _, b) = dd_system(4, 173);
        assert!(jacobi(&a, &a, &[1.0, 0.0, 1.0, 1.0], &b, &SolveOpts::default()).is_err());
    }

    #[test]
    fn iteration_budget_respected() {
        let (a, diag, b) = dd_system(16, 174);
        let opts = SolveOpts { max_iters: 3, tol: 1e-30 };
        let r = jacobi(&a, &a, &diag, &b, &opts).unwrap();
        assert!(!r.converged);
        assert_eq!(r.iterations, 3);
        assert_eq!(r.residual_history.len(), 3);
    }
}
