//! Power iteration for the dominant eigenpair — used to pick safe
//! Richardson relaxation factors and to study spectral error
//! amplification on noisy crossbars.

use super::operator::LinearOperator;
use super::{dot, norm2};
use crate::error::{Error, Result};

/// Dominant eigenvalue estimate and its eigenvector.
#[derive(Debug, Clone)]
pub struct PowerResult {
    pub eigenvalue: f64,
    pub eigenvector: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
}

/// Run power iteration from a deterministic start vector.
pub fn power_iteration(
    op: &dyn LinearOperator,
    max_iters: usize,
    tol: f64,
) -> Result<PowerResult> {
    let (n, m) = op.dim();
    if n != m {
        return Err(Error::Solver(format!(
            "power iteration needs square A, got {n}x{m}"
        )));
    }
    // Deterministic non-degenerate start.
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin() * 0.1).collect();
    let nv = norm2(&v);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut av = vec![0.0; n];
    let mut lambda = 0.0;

    for k in 0..max_iters {
        op.apply(&v, &mut av);
        let new_lambda = dot(&v, &av); // Rayleigh quotient
        let nav = norm2(&av);
        if nav < 1e-300 {
            return Err(Error::Solver("power iteration hit the null space".into()));
        }
        for i in 0..n {
            v[i] = av[i] / nav;
        }
        if (new_lambda - lambda).abs() <= tol * (1.0 + new_lambda.abs()) && k > 0 {
            return Ok(PowerResult {
                eigenvalue: new_lambda,
                eigenvector: v,
                iterations: k + 1,
                converged: true,
            });
        }
        lambda = new_lambda;
    }
    Ok(PowerResult {
        eigenvalue: lambda,
        eigenvector: v,
        iterations: max_iters,
        converged: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::operator::ExactOperator;

    #[test]
    fn diagonal_matrix_dominant_eigenvalue() {
        let n = 5;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = (i + 1) as f64;
        }
        let op = ExactOperator::new(n, n, a);
        let r = power_iteration(&op, 500, 1e-12).unwrap();
        assert!(r.converged);
        assert!((r.eigenvalue - 5.0).abs() < 1e-6);
        // Eigenvector concentrates on the last coordinate.
        assert!(r.eigenvector[4].abs() > 0.999);
    }

    #[test]
    fn symmetric_2x2_known_spectrum() {
        // [[2, 1], [1, 2]] has eigenvalues 3 and 1.
        let op = ExactOperator::new(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let r = power_iteration(&op, 500, 1e-12).unwrap();
        assert!((r.eigenvalue - 3.0).abs() < 1e-8);
    }

    #[test]
    fn rejects_nonsquare() {
        let op = ExactOperator::new(2, 3, vec![0.0; 6]);
        assert!(power_iteration(&op, 10, 1e-6).is_err());
    }
}
