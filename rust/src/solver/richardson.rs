//! Damped Richardson iteration `x_{k+1} = x_k + omega (b - A x_k)` —
//! the simplest analog-friendly solver: one crossbar read and one
//! AXPY per step.

use super::operator::LinearOperator;
use super::{norm2, SolveOpts, SolveResult};
use crate::error::{Error, Result};

/// Solve `A x = b` with relaxation factor `omega` (must satisfy
/// `0 < omega < 2 / lambda_max(A)` for SPD `A`).
pub fn richardson(
    op: &dyn LinearOperator,
    exact: &dyn LinearOperator,
    b: &[f64],
    omega: f64,
    opts: &SolveOpts,
) -> Result<SolveResult> {
    let (n, m) = op.dim();
    if n != m {
        return Err(Error::Solver(format!(
            "richardson needs square A, got {n}x{m}"
        )));
    }
    if omega <= 0.0 {
        return Err(Error::Solver(format!("omega must be positive, got {omega}")));
    }
    let bnorm = norm2(b).max(1e-30);
    let mut x = vec![0.0; n];
    let mut ax = vec![0.0; n];
    let mut history = Vec::with_capacity(opts.max_iters);

    for k in 0..opts.max_iters {
        op.apply(&x, &mut ax);
        for i in 0..n {
            x[i] += omega * (b[i] - ax[i]);
        }
        exact.apply(&x, &mut ax);
        let res = norm2(
            &b.iter()
                .zip(&ax)
                .map(|(bi, ai)| bi - ai)
                .collect::<Vec<f64>>(),
        ) / bnorm;
        history.push(res);
        if res < opts.tol {
            return Ok(SolveResult {
                x,
                iterations: k + 1,
                converged: true,
                residual_history: history,
            });
        }
        if !res.is_finite() || res > 1e12 {
            return Err(Error::Solver(format!("richardson diverged at iter {k}")));
        }
    }
    Ok(SolveResult {
        x,
        iterations: opts.max_iters,
        converged: false,
        residual_history: history,
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::solver::operator::ExactOperator;
    use crate::util::rng::Xoshiro256;

    /// Random SPD system `A = M^T M / n + I`.
    pub(crate) fn spd_system(n: usize, seed: u64) -> (ExactOperator, Vec<f64>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let m: Vec<f64> = (0..n * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += m[k * n + i] * m[k * n + j];
                }
                a[i * n + j] = s / n as f64 + if i == j { 1.0 } else { 0.0 };
            }
        }
        let b: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        (ExactOperator::new(n, n, a), b)
    }

    #[test]
    fn converges_on_spd() {
        let (a, b) = spd_system(20, 181);
        let r = richardson(&a, &a, &b, 0.4, &SolveOpts { max_iters: 2000, tol: 1e-8 })
            .unwrap();
        assert!(r.converged);
        let mut ax = vec![0.0; 20];
        a.apply(&r.x, &mut ax);
        for i in 0..20 {
            assert!((ax[i] - b[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn too_large_omega_diverges() {
        let (a, b) = spd_system(16, 182);
        let r = richardson(&a, &a, &b, 5.0, &SolveOpts::default());
        // Either an explicit divergence error or no convergence.
        match r {
            Err(_) => {}
            Ok(res) => assert!(!res.converged),
        }
    }

    #[test]
    fn rejects_bad_omega() {
        let (a, b) = spd_system(4, 183);
        assert!(richardson(&a, &a, &b, -0.1, &SolveOpts::default()).is_err());
    }
}
