//! In-memory linear solvers — the "LISO" in MELISO.
//!
//! The paper's §IV outlook motivates RRAM VMM as the kernel of linear
//! algebra and optimization solvers; this module closes that loop: the
//! stationary and Krylov solvers below take their matrix-vector
//! products from a programmed (noisy) crossbar, so the VMM error
//! populations measured by the benchmark translate directly into
//! solver convergence behaviour — see `examples/linear_solver.rs`, the
//! `solver` registry experiment (`meliso run solver`), and the
//! `meliso solve` subcommand.  [`CrossbarOperator::program_mitigated`]
//! runs the products through the error-mitigation pipeline
//! ([`crate::mitigation`]), which lowers the convergence floors the
//! experiment measures.

pub mod cg;
pub mod jacobi;
pub mod operator;
pub mod power;
pub mod richardson;

pub use cg::conjugate_gradient;
pub use jacobi::jacobi;
pub use operator::{CrossbarOperator, ExactOperator, LinearOperator};
pub use power::power_iteration;
pub use richardson::richardson;

/// Shared solver options.
#[derive(Debug, Clone, Copy)]
pub struct SolveOpts {
    pub max_iters: usize,
    /// Relative residual target `||b - Ax|| / ||b||`.
    pub tol: f64,
}

impl Default for SolveOpts {
    fn default() -> Self {
        Self { max_iters: 500, tol: 1e-6 }
    }
}

/// Solver outcome with convergence telemetry.
#[derive(Debug, Clone)]
pub struct SolveResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    /// Relative residual per iteration (true residual, computed with
    /// the exact operator for honesty even when iterating on a noisy
    /// crossbar).
    pub residual_history: Vec<f64>,
}

pub(crate) fn norm2(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}
