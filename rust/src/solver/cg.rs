//! Conjugate gradients on SPD systems, with products on the (possibly
//! noisy) operator.  Noise makes CG behave like inexact/perturbed CG:
//! convergence stalls at a floor set by the VMM error level — exactly
//! the phenomenon the error-distribution analysis predicts.

use super::operator::LinearOperator;
use super::{dot, norm2, SolveOpts, SolveResult};
use crate::error::{Error, Result};

/// Solve SPD `A x = b` by conjugate gradients.
pub fn conjugate_gradient(
    op: &dyn LinearOperator,
    exact: &dyn LinearOperator,
    b: &[f64],
    opts: &SolveOpts,
) -> Result<SolveResult> {
    let (n, m) = op.dim();
    if n != m {
        return Err(Error::Solver(format!("cg needs square A, got {n}x{m}")));
    }
    let bnorm = norm2(b).max(1e-30);
    let mut x = vec![0.0; n];
    let mut r: Vec<f64> = b.to_vec(); // r = b - A*0
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut true_r = vec![0.0; n];
    let mut rs_old = dot(&r, &r);
    let mut history = Vec::with_capacity(opts.max_iters);

    for k in 0..opts.max_iters {
        op.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap.abs() < 1e-300 {
            return Err(Error::Solver(format!("cg breakdown at iter {k}")));
        }
        let alpha = rs_old / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);

        exact.apply(&x, &mut true_r);
        for i in 0..n {
            true_r[i] = b[i] - true_r[i];
        }
        let res = norm2(&true_r) / bnorm;
        history.push(res);
        if res < opts.tol {
            return Ok(SolveResult {
                x,
                iterations: k + 1,
                converged: true,
                residual_history: history,
            });
        }
        if !res.is_finite() {
            return Err(Error::Solver(format!("cg diverged at iter {k}")));
        }

        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    Ok(SolveResult {
        x,
        iterations: opts.max_iters,
        converged: false,
        residual_history: history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::richardson::tests::spd_system;

    #[test]
    fn cg_converges_fast_on_spd() {
        let (a, b) = spd_system(32, 191);
        let r = conjugate_gradient(&a, &a, &b, &SolveOpts::default()).unwrap();
        assert!(r.converged);
        // CG on an n-dim SPD system: at most n iterations in exact
        // arithmetic (plus slack for fp).
        assert!(r.iterations <= 40, "iters={}", r.iterations);
    }

    #[test]
    fn cg_beats_richardson_iterations() {
        let (a, b) = spd_system(24, 192);
        let cg = conjugate_gradient(&a, &a, &b, &SolveOpts::default()).unwrap();
        let ri = crate::solver::richardson(
            &a,
            &a,
            &b,
            0.3,
            &SolveOpts { max_iters: 5000, tol: 1e-6 },
        )
        .unwrap();
        assert!(cg.converged && ri.converged);
        assert!(cg.iterations < ri.iterations);
    }

    #[test]
    fn rejects_nonsquare() {
        use crate::solver::operator::ExactOperator;
        let rect = ExactOperator::new(2, 3, vec![0.0; 6]);
        assert!(conjugate_gradient(&rect, &rect, &[1.0, 1.0], &SolveOpts::default())
            .is_err());
    }

    #[test]
    fn solution_satisfies_system() {
        let (a, b) = spd_system(16, 193);
        let r = conjugate_gradient(&a, &a, &b, &SolveOpts::default()).unwrap();
        let mut ax = vec![0.0; 16];
        a.apply(&r.x, &mut ax);
        for i in 0..16 {
            assert!((ax[i] - b[i]).abs() < 1e-4);
        }
    }
}
