//! Linear operators: the exact matrix and its crossbar realization.

use std::sync::Mutex;

use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::mitigation::{MitigatedMatrix, MitigationConfig, ReadScratch};
use crate::util::rng::Xoshiro256;

/// Anything that can apply `y = A x` (and `A^T x` for Krylov methods
/// on nonsymmetric systems).
pub trait LinearOperator {
    fn dim(&self) -> (usize, usize);
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// Transpose apply.  Operators without a transpose pipeline return
    /// [`Error::Unsupported`] — a recoverable error, so library callers
    /// can fall back (e.g. to a normal-equations-free method) instead
    /// of aborting.
    fn apply_t(&self, _x: &[f64], _y: &mut [f64]) -> Result<()> {
        Err(Error::Unsupported(
            "transpose apply not supported by this operator".into(),
        ))
    }
}

/// Exact dense operator (f64) — the software baseline.
#[derive(Debug, Clone)]
pub struct ExactOperator {
    n: usize,
    m: usize,
    /// Row-major `n x m`.
    a: Vec<f64>,
}

impl ExactOperator {
    pub fn new(n: usize, m: usize, a: Vec<f64>) -> Self {
        assert_eq!(a.len(), n * m);
        Self { n, m, a }
    }

    pub fn matrix(&self) -> &[f64] {
        &self.a
    }
}

impl LinearOperator for ExactOperator {
    fn dim(&self) -> (usize, usize) {
        (self.n, self.m)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.m);
        assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            y[i] = crate::solver::dot(&self.a[i * self.m..(i + 1) * self.m], x);
        }
    }

    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<()> {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.m);
        y.fill(0.0);
        for i in 0..self.n {
            let xi = x[i];
            for j in 0..self.m {
                y[j] += self.a[i * self.m + j] * xi;
            }
        }
        Ok(())
    }
}

/// A matrix programmed onto (tiled) crossbars with a device's full
/// non-ideality model; `apply` runs on the simulated hardware.
///
/// Matrix entries must lie in `[-scale, scale]`; they are normalized by
/// `scale` for programming and the read is rescaled, mirroring how a
/// deployment maps numeric ranges onto conductance ranges.
///
/// Both directions run through the mitigation pipeline
/// ([`MitigatedMatrix`]); [`CrossbarOperator::program`] uses the
/// identity config and is bit-for-bit the pre-mitigation operator.
#[derive(Debug)]
pub struct CrossbarOperator {
    n: usize,
    m: usize,
    scale: f64,
    /// Pipeline programmed with A^T (so a column read gives A x).
    forward: MitigatedMatrix,
    /// Pipeline programmed with A (for transpose products).
    transpose: MitigatedMatrix,
    /// Reusable apply staging (`LinearOperator::apply` takes `&self`,
    /// so the per-iteration buffers live behind an uncontended lock).
    scratch: Mutex<ApplyScratch>,
}

/// Input/output staging reused across solver iterations: f32 views of
/// the f64 vectors plus the mitigation pipeline's read scratch.
#[derive(Debug, Default)]
struct ApplyScratch {
    xf: Vec<f32>,
    yf: Vec<f32>,
    read: ReadScratch,
}

impl CrossbarOperator {
    /// Program matrix `a` (row-major `n x m`, f64) under `params`,
    /// without mitigation.
    pub fn program(
        n: usize,
        m: usize,
        a: &[f64],
        params: &DeviceParams,
        rng: &mut Xoshiro256,
    ) -> Self {
        Self::program_mitigated(n, m, a, params, rng, &MitigationConfig::NONE)
    }

    /// Program matrix `a` through the given mitigation pipeline.
    pub fn program_mitigated(
        n: usize,
        m: usize,
        a: &[f64],
        params: &DeviceParams,
        rng: &mut Xoshiro256,
        mitigation: &MitigationConfig,
    ) -> Self {
        assert_eq!(a.len(), n * m);
        let scale = a
            .iter()
            .fold(0.0f64, |acc, &v| acc.max(v.abs()))
            .max(1e-12);
        // The crossbar computes y = x^T W with x over rows of W; to get
        // y = A x we program W = A^T (shape m x n).
        let mut at = vec![0.0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                at[j * n + i] = (a[i * m + j] / scale) as f32;
            }
        }
        // Solvers deploy with write-verify (paper §III: "essential to
        // mitigate ... in real-world applications"); the residual
        // programming error + read-path mismatch still set the floor —
        // which is exactly what the mitigation pipeline then attacks.
        let forward = MitigatedMatrix::program(m, n, &at, params, 32, 32, rng, mitigation, true);
        let aw: Vec<f32> = a.iter().map(|&v| (v / scale) as f32).collect();
        let transpose = MitigatedMatrix::program(n, m, &aw, params, 32, 32, rng, mitigation, true);
        Self {
            n,
            m,
            scale,
            forward,
            transpose,
            scratch: Mutex::new(ApplyScratch::default()),
        }
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Physical crossbars programmed across both directions.
    pub fn array_count(&self) -> usize {
        self.forward.array_count() + self.transpose.array_count()
    }
}

impl LinearOperator for CrossbarOperator {
    fn dim(&self) -> (usize, usize) {
        (self.n, self.m)
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.m);
        assert_eq!(y.len(), self.n);
        let mut guard = self.scratch.lock().unwrap();
        let s = &mut *guard;
        s.xf.clear();
        s.xf.extend(x.iter().map(|&v| v as f32));
        s.yf.resize(self.n, 0.0);
        self.forward.read_scratch(&s.xf, &mut s.yf, &mut s.read);
        for (o, &v) in y.iter_mut().zip(s.yf.iter()) {
            *o = v as f64 * self.scale;
        }
    }

    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<()> {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.m);
        let mut guard = self.scratch.lock().unwrap();
        let s = &mut *guard;
        s.xf.clear();
        s.xf.extend(x.iter().map(|&v| v as f32));
        s.yf.resize(self.m, 0.0);
        self.transpose.read_scratch(&s.xf, &mut s.yf, &mut s.read);
        for (o, &v) in y.iter_mut().zip(s.yf.iter()) {
            *o = v as f64 * self.scale;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::params::DeviceParams;

    fn random_matrix(n: usize, m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n * m).map(|_| rng.uniform_in(-2.0, 2.0)).collect()
    }

    #[test]
    fn exact_operator_applies() {
        let a = ExactOperator::new(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut y = vec![0.0; 2];
        a.apply(&[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
        let mut yt = vec![0.0; 3];
        a.apply_t(&[1.0, 1.0], &mut yt).unwrap();
        assert_eq!(yt, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn default_transpose_apply_is_recoverable() {
        // An operator without a transpose pipeline must return a typed
        // error, not abort the process.
        struct ForwardOnly;
        impl LinearOperator for ForwardOnly {
            fn dim(&self) -> (usize, usize) {
                (2, 2)
            }
            fn apply(&self, _x: &[f64], y: &mut [f64]) {
                y.fill(0.0);
            }
        }
        let mut y = vec![0.0; 2];
        let err = ForwardOnly.apply_t(&[1.0, 1.0], &mut y).unwrap_err();
        assert!(matches!(err, Error::Unsupported(_)));
        assert!(err.to_string().contains("transpose"));
    }

    #[test]
    fn crossbar_operator_matches_exact_when_ideal() {
        let (n, m) = (48, 40);
        let a = random_matrix(n, m, 161);
        let exact = ExactOperator::new(n, m, a.clone());
        let mut rng = Xoshiro256::seed_from_u64(162);
        let xb = CrossbarOperator::program(n, m, &a, &DeviceParams::ideal(), &mut rng);
        let x: Vec<f64> = (0..m).map(|i| ((i % 5) as f64 - 2.0) / 2.0).collect();
        let mut ye = vec![0.0; n];
        let mut yx = vec![0.0; n];
        exact.apply(&x, &mut ye);
        xb.apply(&x, &mut yx);
        for i in 0..n {
            assert!((ye[i] - yx[i]).abs() < 0.05, "{} vs {}", ye[i], yx[i]);
        }
        // Transpose path too.
        let xt: Vec<f64> = (0..n).map(|i| (i as f64 / n as f64) - 0.5).collect();
        let mut yte = vec![0.0; m];
        let mut ytx = vec![0.0; m];
        exact.apply_t(&xt, &mut yte).unwrap();
        xb.apply_t(&xt, &mut ytx).unwrap();
        for j in 0..m {
            assert!((yte[j] - ytx[j]).abs() < 0.05);
        }
    }

    #[test]
    fn mitigated_operator_tightens_apply() {
        use crate::device::presets;
        let (n, m) = (48, 48);
        let a = random_matrix(n, m, 164);
        let exact = ExactOperator::new(n, m, a.clone());
        let params = presets::ag_si().params;
        let mut rng = Xoshiro256::seed_from_u64(165);
        let plain = CrossbarOperator::program(n, m, &a, &params, &mut rng);
        let mitigated = CrossbarOperator::program_mitigated(
            n,
            m,
            &a,
            &params,
            &mut rng,
            &MitigationConfig::parse("diff,avg:4").unwrap(),
        );
        assert_eq!(plain.array_count(), 2);
        assert_eq!(mitigated.array_count(), 16);
        let x: Vec<f64> = (0..m).map(|i| ((i % 7) as f64 - 3.0) / 3.0).collect();
        let mut ye = vec![0.0; n];
        exact.apply(&x, &mut ye);
        let rms = |op: &CrossbarOperator| -> f64 {
            let mut y = vec![0.0; n];
            op.apply(&x, &mut y);
            let s: f64 = y.iter().zip(&ye).map(|(a, b)| (a - b) * (a - b)).sum();
            (s / n as f64).sqrt()
        };
        let e_plain = rms(&plain);
        let e_mit = rms(&mitigated);
        assert!(e_mit < e_plain, "plain {e_plain} vs mitigated {e_mit}");
    }

    #[test]
    fn scale_recovered() {
        let a = vec![0.0, -8.0, 2.0, 4.0];
        let mut rng = Xoshiro256::seed_from_u64(163);
        let xb = CrossbarOperator::program(2, 2, &a, &DeviceParams::ideal(), &mut rng);
        assert_eq!(xb.scale(), 8.0);
        let mut y = vec![0.0; 2];
        xb.apply(&[1.0, 1.0], &mut y);
        assert!((y[0] + 8.0).abs() < 0.1);
        assert!((y[1] - 6.0).abs() < 0.1);
    }
}
