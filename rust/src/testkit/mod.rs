//! In-repo property-testing mini-framework.
//!
//! The offline registry has no `proptest`, so this provides the same
//! role: generate many random cases from strategies, run an invariant,
//! and on failure shrink toward a minimal counterexample before
//! panicking with a reproducible seed.  Deliberately small — just what
//! the invariant suites in `rust/tests/proptests.rs` need.

use crate::util::rng::Xoshiro256;

/// A value generator with an optional shrink order.
pub trait Strategy {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value;
    /// Candidate simpler values, most aggressive first.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform f64 in a range, shrinking toward the midpoint/zero.
#[derive(Debug, Clone, Copy)]
pub struct FloatIn {
    pub lo: f64,
    pub hi: f64,
}

impl Strategy for FloatIn {
    type Value = f64;

    fn generate(&self, rng: &mut Xoshiro256) -> f64 {
        rng.uniform_in(self.lo, self.hi)
    }

    fn shrink(&self, value: &f64) -> Vec<f64> {
        let target = if self.lo <= 0.0 && self.hi >= 0.0 { 0.0 } else { self.lo };
        let mut out = Vec::new();
        let mut v = *value;
        for _ in 0..8 {
            v = (v + target) / 2.0;
            if (v - *value).abs() < 1e-12 {
                break;
            }
            out.push(v);
        }
        out
    }
}

/// Uniform usize in `[lo, hi]`, shrinking toward `lo`.
#[derive(Debug, Clone, Copy)]
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Strategy for UsizeIn {
    type Value = usize;

    fn generate(&self, rng: &mut Xoshiro256) -> usize {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as usize
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut v = *value;
        while v > self.lo {
            v = self.lo + (v - self.lo) / 2;
            out.push(v);
            if v == self.lo {
                break;
            }
        }
        out
    }
}

/// Pick one of a fixed set (no shrinking).
#[derive(Debug, Clone)]
pub struct OneOf<T: Clone + std::fmt::Debug>(pub Vec<T>);

impl<T: Clone + std::fmt::Debug> Strategy for OneOf<T> {
    type Value = T;

    fn generate(&self, rng: &mut Xoshiro256) -> T {
        self.0[rng.below(self.0.len() as u64) as usize].clone()
    }
}

/// Product of two strategies.  Shrinks one coordinate at a time (left
/// first), so a counterexample minimizes coordinate-wise: the shrink
/// loop in [`check`] keeps descending as long as *any* coordinate can
/// still shrink while the property keeps failing.
#[derive(Debug, Clone, Copy)]
pub struct Tuple2<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Tuple2<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b));
        }
        out
    }
}

/// Product of three strategies; shrinks coordinate-wise like
/// [`Tuple2`].
#[derive(Debug, Clone, Copy)]
pub struct Tuple3<A, B, C>(pub A, pub B, pub C);

impl<A: Strategy, B: Strategy, C: Strategy> Strategy for Tuple3<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);

    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
        (
            self.0.generate(rng),
            self.1.generate(rng),
            self.2.generate(rng),
        )
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone(), value.2.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b, value.2.clone()));
        }
        for c in self.2.shrink(&value.2) {
            out.push((value.0.clone(), value.1.clone(), c));
        }
        out
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xBEEF,
            max_shrink_steps: 200,
        }
    }
}

/// Check `prop` over `cfg.cases` generated values; panic with the
/// (shrunk) counterexample and seed on failure.
pub fn check<S, P>(cfg: Config, strategy: &S, prop: P)
where
    S: Strategy,
    P: Fn(&S::Value) -> bool,
{
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    for case in 0..cfg.cases {
        let value = strategy.generate(&mut rng);
        if prop(&value) {
            continue;
        }
        // Shrink.
        let mut worst = value.clone();
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink_steps {
            for cand in strategy.shrink(&worst) {
                steps += 1;
                if !prop(&cand) {
                    worst = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed at case {case} (seed {:#x}): \
             counterexample {worst:?} (original {value:?})",
            cfg.seed
        );
    }
}

/// Two-strategy product helper.
pub fn check2<A, B, P>(cfg: Config, sa: &A, sb: &B, prop: P)
where
    A: Strategy,
    B: Strategy,
    P: Fn(&A::Value, &B::Value) -> bool,
{
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    for case in 0..cfg.cases {
        let a = sa.generate(&mut rng);
        let b = sb.generate(&mut rng);
        assert!(
            prop(&a, &b),
            "property failed at case {case} (seed {:#x}): ({a:?}, {b:?})",
            cfg.seed
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default(), &FloatIn { lo: -1.0, hi: 1.0 }, |v| {
            v.abs() <= 1.0
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(Config::default(), &FloatIn { lo: 0.0, hi: 10.0 }, |v| *v < 5.0);
    }

    #[test]
    fn shrinking_moves_toward_zero() {
        let s = FloatIn { lo: -4.0, hi: 4.0 };
        let shrunk = s.shrink(&4.0);
        assert!(!shrunk.is_empty());
        assert!(shrunk[0].abs() < 4.0);
    }

    #[test]
    fn usize_strategy_in_bounds() {
        let s = UsizeIn { lo: 2, hi: 9 };
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..100 {
            let v = s.generate(&mut rng);
            assert!((2..=9).contains(&v));
        }
        assert!(s.shrink(&9).contains(&2));
    }

    #[test]
    fn one_of_picks_members() {
        let s = OneOf(vec!["a", "b"]);
        let mut rng = Xoshiro256::seed_from_u64(2);
        for _ in 0..20 {
            let v = s.generate(&mut rng);
            assert!(v == "a" || v == "b");
        }
    }

    #[test]
    fn tuple_strategies_generate_in_bounds_and_shrink_coordinatewise() {
        let s = Tuple2(UsizeIn { lo: 1, hi: 9 }, FloatIn { lo: -2.0, hi: 2.0 });
        let mut rng = Xoshiro256::seed_from_u64(3);
        for _ in 0..50 {
            let (n, x) = s.generate(&mut rng);
            assert!((1..=9).contains(&n));
            assert!((-2.0..=2.0).contains(&x));
        }
        let shrunk = s.shrink(&(9, 2.0));
        // Each candidate changes exactly one coordinate.
        assert!(shrunk.iter().any(|&(n, x)| n < 9 && x == 2.0));
        assert!(shrunk.iter().any(|&(n, x)| n == 9 && x.abs() < 2.0));

        let t = Tuple3(
            UsizeIn { lo: 0, hi: 4 },
            UsizeIn { lo: 2, hi: 6 },
            UsizeIn { lo: 1, hi: 3 },
        );
        let shrunk = t.shrink(&(4, 6, 3));
        assert!(shrunk.contains(&(2, 6, 3)));
        assert!(shrunk.contains(&(4, 4, 3)));
        assert!(shrunk.contains(&(4, 6, 2)));
        // Fully shrunk values produce no candidates.
        assert!(t.shrink(&(0, 2, 1)).is_empty());
    }

    #[test]
    fn tuple_check_shrinks_to_minimal_counterexample() {
        // Property fails iff a + b >= 10; the minimal failing pair
        // reachable by halving toward the lows is found by check()'s
        // shrink loop — catch the panic and inspect the message.
        let result = std::panic::catch_unwind(|| {
            check(
                Config { cases: 64, seed: 5, max_shrink_steps: 200 },
                &Tuple2(UsizeIn { lo: 0, hi: 100 }, UsizeIn { lo: 0, hi: 100 }),
                |&(a, b)| a + b < 10,
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("counterexample"), "{msg}");
    }

    #[test]
    fn check2_runs() {
        check2(
            Config::default(),
            &UsizeIn { lo: 1, hi: 8 },
            &FloatIn { lo: 0.1, hi: 2.0 },
            |n, x| (*n as f64) * x > 0.0,
        );
    }
}
