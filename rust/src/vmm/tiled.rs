//! Tiled crossbar engine: benchmark populations at arbitrary workload
//! geometries by mapping each sample's weight matrix onto a grid of
//! physical crossbar tiles ([`crate::crossbar::tile::TiledCrossbar`])
//! with bit-line current summation across the grid.
//!
//! This opens the benchmark beyond the paper's single 32x32 protocol:
//! the `size-sweep` experiment runs 64x64 through 512x512 populations
//! through the same [`crate::coordinator::Coordinator`] path, following
//! the scalable/distributed direction of arXiv:2508.13298.
//!
//! The engine consumes the standard [`VmmBatch`] contract — the noise
//! planes cover the *logical* geometry and are sliced per tile, so
//! each tile's physics is a deterministic function of the sample's
//! `(w, z)` and the tile geometry (every tile is its own programming
//! cycle, with the cycle severity normalized over its real cells).
//! With a single tile the output is bit-identical to
//! [`super::NativeEngine`].  Samples are fanned across the scoped pool
//! exactly like the native engine; results are bit-identical for any
//! thread count.

use crate::crossbar::array::PulseTable;
use crate::crossbar::tile::{TileScratch, TiledCrossbar};
use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::util::pool::{run_blocked, Parallelism};

use super::engine::{VmmBatch, VmmEngine, VmmOutput};
use super::program::{ProgramSpec, ProgrammedRead, ProgrammedVmm};
use super::software::software_vmm_batch;

/// Crossbar engine for arbitrary-size workloads over a tile grid.
#[derive(Debug, Clone, Copy)]
pub struct TiledEngine {
    /// Physical tile geometry (paper hardware: 32x32).
    pub tile_rows: usize,
    pub tile_cols: usize,
    /// How many workers one `forward` call fans samples across.
    pub par: Parallelism,
}

impl Default for TiledEngine {
    fn default() -> Self {
        Self {
            tile_rows: crate::ROWS,
            tile_cols: crate::COLS,
            par: Parallelism::Auto,
        }
    }
}

impl TiledEngine {
    /// Engine with square tiles of the given size.
    pub fn with_tile(tile: usize) -> Self {
        Self {
            tile_rows: tile,
            tile_cols: tile,
            ..Self::default()
        }
    }

    /// Set the engine-level parallelism.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Tiles needed for one `rows x cols` sample.
    pub fn tiles_for(&self, rows: usize, cols: usize) -> usize {
        rows.div_ceil(self.tile_rows) * cols.div_ceil(self.tile_cols)
    }
}

/// Program-once handle of the tiled engine: the materialized tile grid
/// ([`TiledCrossbar::program_with_noise`], bit-identical to the
/// streaming `forward` path), read in parallel over requests.
struct ProgrammedTiles {
    grid: TiledCrossbar,
    par: Parallelism,
}

impl ProgrammedRead for ProgrammedTiles {
    fn rows(&self) -> usize {
        self.grid.rows()
    }

    fn cols(&self) -> usize {
        self.grid.cols()
    }

    fn read_batch(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let (r, c) = (self.grid.rows(), self.grid.cols());
        if x.len() != batch * r {
            return Err(Error::Geometry(format!(
                "read batch expects {} inputs ({batch} x {r} rows), got {}",
                batch * r,
                x.len()
            )));
        }
        // Per-worker tile staging: zero allocation per served request.
        let (tr, tc) = (self.grid.tile_rows(), self.grid.tile_cols());
        Ok(run_blocked(
            self.par,
            batch,
            c,
            || (vec![0.0f32; tr], vec![0.0f32; tc]),
            |s, (tx, ty), out| {
                self.grid.read_with(&x[s * r..(s + 1) * r], out, tx, ty);
            },
        ))
    }
}

impl VmmEngine for TiledEngine {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        spec.check()?;
        if self.tile_rows == 0 || self.tile_cols == 0 {
            return Err(Error::Config("tile geometry must be positive".into()));
        }
        let table = PulseTable::new(params, false);
        let grid = TiledCrossbar::program_with_noise(
            spec.rows,
            spec.cols,
            &spec.w,
            params,
            self.tile_rows,
            self.tile_cols,
            [&spec.noise.z0, &spec.noise.z1, &spec.noise.z2],
            &table,
        );
        Ok(ProgrammedVmm::new(spec, ProgrammedTiles { grid, par: self.par }))
    }

    fn cache_config(&self) -> String {
        format!("tiled:{}x{}", self.tile_rows, self.tile_cols)
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        if self.tile_rows == 0 || self.tile_cols == 0 {
            return Err(Error::Config("tile geometry must be positive".into()));
        }
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        let table = PulseTable::new(params, false);
        // Stream tiles through a per-worker scratch array — no
        // per-sample allocation, same arithmetic as materializing a
        // TiledCrossbar per sample.
        let y_hw = run_blocked(
            self.par,
            b,
            c,
            || TileScratch::new(self.tile_rows, self.tile_cols),
            |s, scratch, out| {
                let z = [batch.z_of(s, 0), batch.z_of(s, 1), batch.z_of(s, 2)];
                TiledCrossbar::vmm_with_noise(
                    r,
                    c,
                    batch.w_of(s),
                    params,
                    z,
                    &table,
                    batch.x_of(s),
                    out,
                    scratch,
                );
            },
        );
        let y_sw = software_vmm_batch(batch);
        Ok(VmmOutput { y_hw, y_sw })
    }

    fn internal_parallelism(&self) -> usize {
        self.par.threads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::stats::moments::Moments;
    use crate::util::rng::Xoshiro256;
    use crate::vmm::NativeEngine;

    fn random_batch(b: usize, r: usize, c: usize, seed: u64) -> VmmBatch {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut vb = VmmBatch::zeros(b, r, c);
        rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut vb.x, -1.0, 1.0);
        rng.fill_normal_f32(&mut vb.z);
        vb
    }

    #[test]
    fn single_tile_bit_identical_to_native_engine() {
        let b = random_batch(6, 32, 32, 211);
        let params = presets::ag_si().params;
        let tiled = TiledEngine::default().forward(&b, &params).unwrap();
        let native = NativeEngine::sequential().forward(&b, &params).unwrap();
        assert_eq!(tiled.y_hw, native.y_hw);
        assert_eq!(tiled.y_sw, native.y_sw);
    }

    #[test]
    fn parallel_fan_is_bit_identical_to_sequential() {
        let b = random_batch(9, 64, 64, 212);
        let params = presets::epiram().params;
        let seq = TiledEngine::default()
            .with_parallelism(Parallelism::Fixed(1))
            .forward(&b, &params)
            .unwrap();
        let par = TiledEngine::default()
            .with_parallelism(Parallelism::Fixed(4))
            .forward(&b, &params)
            .unwrap();
        assert_eq!(seq.y_hw, par.y_hw);
    }

    #[test]
    fn ideal_device_tracks_software_at_128() {
        let b = random_batch(2, 128, 128, 213);
        let out = TiledEngine::default()
            .forward(&b, &DeviceParams::ideal())
            .unwrap();
        for (i, &e) in out.errors().iter().enumerate() {
            // 128-term sums of f32-quantized weights: loose bound.
            assert!(e.abs() < 0.1, "element {i}: e={e}");
        }
    }

    #[test]
    fn error_variance_grows_with_size() {
        let params = presets::epiram().params;
        let var_at = |size: usize, seed: u64| {
            let b = random_batch(8, size, size, seed);
            let out = TiledEngine::default().forward(&b, &params).unwrap();
            Moments::from_slice(&out.errors()).variance()
        };
        let v32 = var_at(32, 214);
        let v128 = var_at(128, 215);
        // More rows per output -> more accumulated device error.
        assert!(v128 > v32, "v128={v128} v32={v32}");
    }

    #[test]
    fn ragged_geometry_supported() {
        let b = random_batch(3, 50, 70, 216);
        let params = presets::taox_hfox().params;
        let out = TiledEngine::default().forward(&b, &params).unwrap();
        assert_eq!(out.y_hw.len(), 3 * 70);
        assert!(out.errors().iter().all(|e| e.is_finite()));
        let eng = TiledEngine::default();
        assert_eq!(eng.tiles_for(50, 70), 2 * 3);
    }

    #[test]
    fn programmed_read_bit_identical_to_uncached_forward() {
        // Ragged grid incl. padded tiles: the materialized program
        // must serve exactly what the streaming per-sample path does.
        let mut rng = Xoshiro256::seed_from_u64(218);
        let (r, c) = (50, 70);
        let mut w = vec![0.0f32; r * c];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let spec = ProgramSpec::from_seed(r, c, w, 2180);
        let params = presets::epiram().params;
        let mut x = vec![0.0f32; 4 * r];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let uncached = TiledEngine::default()
            .with_parallelism(Parallelism::Fixed(1))
            .forward(&spec.to_batch(&x, 4), &params)
            .unwrap();
        for par in [Parallelism::Fixed(1), Parallelism::Auto] {
            let handle = TiledEngine::default()
                .with_parallelism(par)
                .program(&spec, &params)
                .unwrap();
            let served = handle.forward(&x, 4).unwrap();
            assert_eq!(served.y_hw, uncached.y_hw, "{par:?}");
            assert_eq!(served.y_sw, uncached.y_sw);
        }
    }

    #[test]
    fn zero_tile_rejected() {
        let eng = TiledEngine { tile_rows: 0, ..TiledEngine::default() };
        let b = random_batch(1, 8, 8, 217);
        assert!(eng.forward(&b, &presets::epiram().params).is_err());
    }
}
