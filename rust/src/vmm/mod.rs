//! VMM engines: the pluggable compute backends of the benchmark.
//!
//! * [`SoftwareEngine`] — exact f64 reference (the paper's
//!   "software-calculated dot product").
//! * [`NativeEngine`] — pure-rust crossbar simulation, sample-by-sample
//!   identical physics to the artifacts; runs without `make artifacts`.
//! * [`XlaEngine`] — executes the AOT-lowered L2/L1 pipeline through
//!   PJRT; the production hot path.

pub mod engine;
pub mod native;
pub mod software;
pub mod xla_engine;

pub use engine::{VmmBatch, VmmEngine, VmmOutput};
pub use native::NativeEngine;
pub use software::{software_vmm_batch, SoftwareEngine};
pub use xla_engine::XlaEngine;
