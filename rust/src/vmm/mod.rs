//! VMM engines: the pluggable compute backends of the benchmark.
//!
//! * [`SoftwareEngine`] — exact f64 reference (the paper's
//!   "software-calculated dot product").
//! * [`NativeEngine`] — pure-rust crossbar simulation, sample-by-sample
//!   identical physics to the artifacts; fans samples across the worker
//!   pool; runs without `make artifacts`.
//! * [`TiledEngine`] — arbitrary-size workloads over a grid of physical
//!   crossbar tiles (64x64 through 512x512 and beyond).
//! * [`ShardedEngine`] — one VMM partitioned across a grid of
//!   independently programmed crossbar shards, with ABFT-style checksum
//!   detection/correction of gross shard faults in the reduction.
//! * [`XlaEngine`] — executes the AOT-lowered L2/L1 pipeline through
//!   PJRT; the production hot path (requires the `xla` binding).
//!
//! Every engine also implements the program-once/read-many split
//! ([`VmmEngine::program`] -> [`ProgrammedVmm`], see [`program`]) that
//! the request-serving subsystem ([`crate::serve`]) builds on.

pub mod engine;
pub mod native;
pub mod program;
pub mod sharded;
pub mod software;
pub mod tiled;
pub mod xla_engine;

pub use engine::{DynEngine, VmmBatch, VmmEngine, VmmOutput};
pub use program::{ProgramSpec, ProgrammedRead, ProgrammedVmm, ReplayProgrammed};
pub use native::NativeEngine;
pub use sharded::{ShardCounts, ShardStats, ShardedEngine, DEFAULT_CHECKSUM_THRESHOLD};
pub use software::{software_vmm_batch, software_vmm_single, SoftwareEngine};
pub use tiled::TiledEngine;
pub use xla_engine::XlaEngine;
