//! Pure-rust crossbar simulation engine.
//!
//! Mirrors the artifact math exactly (same quantization, pulse curve,
//! C2C accumulation, clipping, mismatch transform — all in f32 where
//! the artifact computes in f32), so a population simulated natively is
//! statistically identical to the XLA path and numerically identical
//! per sample up to f32 associativity.  Used for artifact-free runs,
//! cross-validation, and as the baseline in the perf comparison.
//!
//! ## Parallelism
//!
//! `forward` fans the batch across the scoped worker pool
//! ([`crate::util::pool::run_blocked`]) in contiguous sample blocks.
//! Each worker owns one reusable [`CrossbarArray`]/[`ProgramNoise`]
//! scratch pair and the per-device [`PulseTable`] is built once per
//! call — no per-sample allocation on the hot path.  Every sample's
//! physics is independent and written to its own output slice, so the
//! result is **bit-identical for any thread count** (the determinism
//! guard in `rust/tests/integration_tiled.rs` enforces this).

use crate::crossbar::array::{CrossbarArray, ProgramScratch, PulseTable};
use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::util::pool::{run_blocked, Parallelism};

use super::engine::{VmmBatch, VmmEngine, VmmOutput};
use super::program::{ProgramSpec, ProgrammedRead, ProgrammedVmm};
use super::software::software_vmm_batch;

/// Native (no-XLA) crossbar engine with engine-level parallelism.
#[derive(Debug, Clone, Copy)]
pub struct NativeEngine {
    /// How many workers one `forward` call fans samples across.
    pub par: Parallelism,
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self { par: Parallelism::Auto }
    }
}

impl NativeEngine {
    /// Engine that fans each batch across all available CPUs.
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine with an explicit worker count (1 = the sequential
    /// baseline, exercised through the same code path).
    pub fn with_parallelism(par: Parallelism) -> Self {
        Self { par }
    }

    /// The sequential post-fix baseline used by the perf comparison.
    pub fn sequential() -> Self {
        Self::with_parallelism(Parallelism::Fixed(1))
    }
}

/// Program-once handle of the native engine: one materialized array;
/// reads are fanned over the pool exactly like `forward` fans samples
/// (the array is immutable at read time, so sharing it is free).
struct ProgrammedArray {
    arr: CrossbarArray,
    par: Parallelism,
}

impl ProgrammedRead for ProgrammedArray {
    fn rows(&self) -> usize {
        self.arr.rows()
    }

    fn cols(&self) -> usize {
        self.arr.cols()
    }

    fn read_batch(&self, x: &[f32], batch: usize) -> crate::error::Result<Vec<f32>> {
        let (r, c) = (self.arr.rows(), self.arr.cols());
        if x.len() != batch * r {
            return Err(Error::Geometry(format!(
                "read batch expects {} inputs ({batch} x {r} rows), got {}",
                batch * r,
                x.len()
            )));
        }
        Ok(run_blocked(self.par, batch, c, || (), |s, _scratch, out| {
            self.arr.read(&x[s * r..(s + 1) * r], out);
        }))
    }
}

impl VmmEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        spec.check()?;
        let table = PulseTable::new(params, false);
        let mut arr = CrossbarArray::zeroed(spec.rows, spec.cols);
        arr.reprogram(&spec.w, params, &spec.noise, &table);
        Ok(ProgrammedVmm::new(spec, ProgrammedArray { arr, par: self.par }))
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        // Shared per-device pulse table: one grid build per call
        // instead of one per sample.
        let table = PulseTable::new(params, false);
        let y_hw = run_blocked(
            self.par,
            b,
            c,
            || ProgramScratch::new(r, c),
            |s, scratch, out| {
                scratch.load_noise([batch.z_of(s, 0), batch.z_of(s, 1), batch.z_of(s, 2)]);
                scratch.arr.reprogram(batch.w_of(s), params, &scratch.noise, &table);
                scratch.arr.read(batch.x_of(s), out);
            },
        );
        let y_sw = software_vmm_batch(batch);
        Ok(VmmOutput { y_hw, y_sw })
    }

    fn internal_parallelism(&self) -> usize {
        self.par.threads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::stats::moments::Moments;
    use crate::util::rng::Xoshiro256;

    fn random_batch(b: usize, r: usize, c: usize, seed: u64, noisy: bool) -> VmmBatch {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut vb = VmmBatch::zeros(b, r, c);
        rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut vb.x, -1.0, 1.0);
        if noisy {
            rng.fill_normal_f32(&mut vb.z);
        }
        vb
    }

    #[test]
    fn ideal_device_near_zero_error() {
        let b = random_batch(8, 32, 32, 141, false);
        let out = NativeEngine::default()
            .forward(&b, &DeviceParams::ideal())
            .unwrap();
        for &e in &out.errors() {
            assert!(e.abs() < 5e-3, "e={e}");
        }
    }

    #[test]
    fn table1_device_produces_structured_error() {
        let b = random_batch(64, 32, 32, 142, true);
        let params = presets::ag_si().params;
        let out = NativeEngine::default().forward(&b, &params).unwrap();
        let m = Moments::from_slice(&out.errors());
        // Non-ideal Ag:a-Si: errors are definitely not zero…
        assert!(m.variance() > 0.1);
        // …but bounded (conductances clip, inputs are bounded).
        assert!(m.max().abs() < 64.0 && m.min().abs() < 64.0);
    }

    #[test]
    fn deterministic_given_noise() {
        let b = random_batch(4, 16, 16, 143, true);
        let params = presets::epiram().params;
        let o1 = NativeEngine::default().forward(&b, &params).unwrap();
        let o2 = NativeEngine::default().forward(&b, &params).unwrap();
        assert_eq!(o1.y_hw, o2.y_hw);
    }

    #[test]
    fn parallel_fan_is_bit_identical_to_sequential() {
        let b = random_batch(37, 32, 32, 146, true);
        let params = presets::ag_si().params;
        let seq = NativeEngine::sequential().forward(&b, &params).unwrap();
        for threads in [2usize, 3, 8] {
            let par = NativeEngine::with_parallelism(Parallelism::Fixed(threads))
                .forward(&b, &params)
                .unwrap();
            assert_eq!(seq.y_hw, par.y_hw, "threads={threads}");
            assert_eq!(seq.y_sw, par.y_sw);
        }
        let auto = NativeEngine::default().forward(&b, &params).unwrap();
        assert_eq!(seq.y_hw, auto.y_hw);
    }

    #[test]
    fn internal_parallelism_reported() {
        assert_eq!(NativeEngine::sequential().internal_parallelism(), 1);
        assert_eq!(
            NativeEngine::with_parallelism(Parallelism::Fixed(5)).internal_parallelism(),
            5
        );
        assert!(NativeEngine::default().internal_parallelism() >= 1);
    }

    #[test]
    fn programmed_read_bit_identical_to_uncached_forward() {
        // Program once, serve many: every request must decode exactly
        // as the uncached per-sample path with the same (w, z).
        let mut rng = Xoshiro256::seed_from_u64(147);
        let mut w = vec![0.0f32; 32 * 32];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let spec = ProgramSpec::from_seed(32, 32, w, 1470);
        let params = presets::ag_si().params;
        let mut x = vec![0.0f32; 5 * 32];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let uncached = NativeEngine::sequential()
            .forward(&spec.to_batch(&x, 5), &params)
            .unwrap();
        for par in [Parallelism::Fixed(1), Parallelism::Auto] {
            let handle = NativeEngine::with_parallelism(par)
                .program(&spec, &params)
                .unwrap();
            let served = handle.forward(&x, 5).unwrap();
            assert_eq!(served.y_hw, uncached.y_hw, "{par:?}");
            assert_eq!(served.y_sw, uncached.y_sw);
            // The hot read path agrees with the measurement path.
            assert_eq!(handle.read(&x, 5).unwrap(), served.y_hw);
        }
    }

    #[test]
    fn error_ordering_across_devices() {
        // Fig. 5 shape at unit scale: EpiRAM < Ag:a-Si on identical
        // workloads (both with non-idealities).
        let b = random_batch(128, 32, 32, 144, true);
        let var = |p: &DeviceParams| {
            let out = NativeEngine::default().forward(&b, p).unwrap();
            Moments::from_slice(&out.errors()).variance()
        };
        let epi = var(&presets::epiram().params);
        let ag = var(&presets::ag_si().params);
        let al = var(&presets::alox_hfo2().params);
        assert!(epi < ag, "epi={epi} ag={ag}");
        assert!(epi < al, "epi={epi} al={al}");
    }

    #[test]
    fn software_reference_is_exact_dot() {
        let b = random_batch(2, 8, 8, 145, true);
        let out = NativeEngine::default()
            .forward(&b, &presets::taox_hfox().params)
            .unwrap();
        for s in 0..2 {
            for j in 0..8 {
                let want: f64 = (0..8)
                    .map(|i| b.x_of(s)[i] as f64 * b.w_of(s)[i * 8 + j] as f64)
                    .sum();
                assert!((out.y_sw[s * 8 + j] as f64 - want).abs() < 1e-5);
            }
        }
    }
}
