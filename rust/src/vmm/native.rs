//! Pure-rust crossbar simulation engine.
//!
//! Mirrors the artifact math exactly (same quantization, pulse curve,
//! C2C accumulation, clipping, mismatch transform — all in f32 where
//! the artifact computes in f32), so a population simulated natively is
//! statistically identical to the XLA path and numerically identical
//! per sample up to f32 associativity.  Used for artifact-free runs,
//! cross-validation, and as the baseline in the perf comparison.

use crate::crossbar::array::{CrossbarArray, ProgramNoise};
use crate::device::params::DeviceParams;
use crate::error::Result;

use super::engine::{VmmBatch, VmmEngine, VmmOutput};
use super::software::software_vmm_batch;

/// Native (no-XLA) crossbar engine.
#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl VmmEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        let cells = r * c;
        let mut y_hw = vec![0.0f32; b * c];
        // Reusable noise view (copies are cheap relative to program()).
        let mut noise = ProgramNoise::zeros(cells);
        for s in 0..b {
            noise.z0.copy_from_slice(batch.z_of(s, 0));
            noise.z1.copy_from_slice(batch.z_of(s, 1));
            noise.z2.copy_from_slice(batch.z_of(s, 2));
            let arr = CrossbarArray::program(r, c, batch.w_of(s), params, &noise);
            arr.read(batch.x_of(s), &mut y_hw[s * c..(s + 1) * c]);
        }
        let y_sw = software_vmm_batch(batch);
        Ok(VmmOutput { y_hw, y_sw })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::stats::moments::Moments;
    use crate::util::rng::Xoshiro256;

    fn random_batch(b: usize, r: usize, c: usize, seed: u64, noisy: bool) -> VmmBatch {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut vb = VmmBatch::zeros(b, r, c);
        rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut vb.x, -1.0, 1.0);
        if noisy {
            rng.fill_normal_f32(&mut vb.z);
        }
        vb
    }

    #[test]
    fn ideal_device_near_zero_error() {
        let b = random_batch(8, 32, 32, 141, false);
        let out = NativeEngine.forward(&b, &DeviceParams::ideal()).unwrap();
        for &e in &out.errors() {
            assert!(e.abs() < 5e-3, "e={e}");
        }
    }

    #[test]
    fn table1_device_produces_structured_error() {
        let b = random_batch(64, 32, 32, 142, true);
        let params = presets::ag_si().params;
        let out = NativeEngine.forward(&b, &params).unwrap();
        let m = Moments::from_slice(&out.errors());
        // Non-ideal Ag:a-Si: errors are definitely not zero…
        assert!(m.variance() > 0.1);
        // …but bounded (conductances clip, inputs are bounded).
        assert!(m.max().abs() < 64.0 && m.min().abs() < 64.0);
    }

    #[test]
    fn deterministic_given_noise() {
        let b = random_batch(4, 16, 16, 143, true);
        let params = presets::epiram().params;
        let o1 = NativeEngine.forward(&b, &params).unwrap();
        let o2 = NativeEngine.forward(&b, &params).unwrap();
        assert_eq!(o1.y_hw, o2.y_hw);
    }

    #[test]
    fn error_ordering_across_devices() {
        // Fig. 5 shape at unit scale: EpiRAM < Ag:a-Si on identical
        // workloads (both with non-idealities).
        let b = random_batch(128, 32, 32, 144, true);
        let var = |p: &DeviceParams| {
            let out = NativeEngine.forward(&b, p).unwrap();
            Moments::from_slice(&out.errors()).variance()
        };
        let epi = var(&presets::epiram().params);
        let ag = var(&presets::ag_si().params);
        let al = var(&presets::alox_hfo2().params);
        assert!(epi < ag, "epi={epi} ag={ag}");
        assert!(epi < al, "epi={epi} al={al}");
    }

    #[test]
    fn software_reference_is_exact_dot() {
        let b = random_batch(2, 8, 8, 145, true);
        let out = NativeEngine
            .forward(&b, &presets::taox_hfox().params)
            .unwrap();
        for s in 0..2 {
            for j in 0..8 {
                let want: f64 = (0..8)
                    .map(|i| b.x_of(s)[i] as f64 * b.w_of(s)[i * 8 + j] as f64)
                    .sum();
                assert!((out.y_sw[s * 8 + j] as f64 - want).abs() < 1e-5);
            }
        }
    }
}
