//! Sharded multi-crossbar engine: partition each sample's weight
//! matrix into an `R x C` grid of independently programmed crossbar
//! shards ([`crate::shard::ShardGrid`]), compute the shard partials in
//! parallel, and reduce them with an ABFT-style checksum check
//! ([`crate::shard::ChecksumCode`]) that detects — and for a single
//! gross per-shard fault, corrects — stuck/dead bit lines **before**
//! the partials are accumulated into the output.  This is the
//! scalable/distributed execution model of arXiv:2508.13298 with the
//! error correction integrated into the partitioning, a mitigation the
//! per-device strategies in [`crate::mitigation`] cannot express.
//!
//! ## Physics
//!
//! Each shard is its own programming cycle over its slice of the
//! logical weight/noise planes, with the per-cycle severity normalized
//! over the shard's real cells — the same sub-block contract as
//! [`crate::crossbar::tile::TiledCrossbar`], so with a `1x1` grid (and
//! no correction firing) the output is **bit-identical** to
//! [`super::NativeEngine`].  Checksum columns are appended to the
//! shard's array with zero programming noise, modeling verified
//! (closed-loop trimmed) reference lines: real ABFT deployments
//! program the checksum lines with write–verify because the whole
//! correction hinges on them.  They still pass through the device's
//! quantization, so the check sees honest encode error.
//!
//! ## Detection threshold
//!
//! The sum check accumulates the analog error of all `clen` data
//! columns, so its clean-run floor grows like
//! `sqrt(rlen * clen) * sigma_cell`, while a gross stuck-line fault
//! grows like `rlen * level / 2`.  The engine therefore scales its
//! [`ShardedEngine::threshold`] factor by `sqrt(rlen * clen)`:
//! `abs_threshold = threshold * sqrt(shard cells)`.  The default
//! ([`DEFAULT_CHECKSUM_THRESHOLD`]) balances false fires against missed
//! faults on the Table I devices; deployments with quieter devices (or
//! mitigated programming) should lower it, and the `shard-sweep`
//! experiment measures exactly this operating curve.
//!
//! ## Determinism
//!
//! Shard partials are fanned over the scoped pool in `(sample, shard)`
//! jobs, each writing only its own slice; fault draws are pure
//! functions of `(fault seed, sample, shard)`; and the
//! verify-correct-accumulate reduction runs on the calling thread in
//! fixed shard order.  The result is bit-identical for any thread
//! count (`rust/tests/integration_sharded.rs` enforces this).

use std::sync::Arc;

use crate::crossbar::array::{CrossbarArray, ProgramScratch, PulseTable};
use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::obs::{self, Counter, CounterId, Stage};
use crate::shard::{ChecksumCode, FaultSpec, ShardGrid, ShardRegion, Verdict};
use crate::util::pool::{run_blocked, Parallelism};

use super::engine::{VmmBatch, VmmEngine, VmmOutput};
use super::program::{ProgramSpec, ProgrammedRead, ProgrammedVmm};
use super::software::software_vmm_batch;

/// Default detection-threshold factor (scaled by `sqrt(shard cells)`;
/// see the module docs).  Chosen from the operating curve: a rail
/// fault shifts the sum check by `~rlen/2` while the clean floor sits
/// at the accumulated per-cell noise, so `0.35 * sqrt(cells)` (≈ 11 at
/// a 32x32 shard vs a ~16 mean fault) detects ~90% of rail faults on
/// quiet-to-moderate devices with near-zero false fires; on very noisy
/// devices detection is genuinely marginal — the `shard-sweep`
/// experiment measures exactly this.
pub const DEFAULT_CHECKSUM_THRESHOLD: f64 = 0.35;

/// Checksum telemetry counters, shared by every clone of an engine
/// (and with the [`crate::coordinator::Coordinator`] it is moved into).
/// Counts accumulate across `forward` calls until [`ShardStats::reset`].
///
/// The counters are [`obs::Counter`]s (always active — reports depend
/// on them); each recording additionally mirrors into the global
/// registry's fault counters when telemetry is enabled.
#[derive(Debug, Default)]
pub struct ShardStats {
    injected: Counter,
    detected: Counter,
    corrected: Counter,
    uncorrectable: Counter,
}

impl ShardStats {
    /// Count `n` injected faults.
    fn record_injected(&self, n: u64) {
        self.injected.add(n);
        obs::add(CounterId::FaultsInjected, n);
    }

    /// Count a batch of verify verdicts.
    fn record_verdicts(&self, detected: u64, corrected: u64, uncorrectable: u64) {
        if detected == 0 {
            return;
        }
        self.detected.add(detected);
        obs::add(CounterId::FaultsDetected, detected);
        if corrected > 0 {
            self.corrected.add(corrected);
            obs::add(CounterId::FaultsCorrected, corrected);
        }
        if uncorrectable > 0 {
            self.uncorrectable.add(uncorrectable);
            obs::add(CounterId::FaultsUncorrectable, uncorrectable);
        }
    }

    /// Consistent snapshot of the counters.
    pub fn snapshot(&self) -> ShardCounts {
        ShardCounts {
            injected: self.injected.get(),
            detected: self.detected.get(),
            corrected: self.corrected.get(),
            uncorrectable: self.uncorrectable.get(),
        }
    }

    /// Zero all counters.
    pub fn reset(&self) {
        self.injected.reset();
        self.detected.reset();
        self.corrected.reset();
        self.uncorrectable.reset();
    }
}

/// One snapshot of [`ShardStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCounts {
    /// Faults injected by the configured [`FaultSpec`].
    pub injected: u64,
    /// Shard partials whose sum check fired.
    pub detected: u64,
    /// Detections that decoded to a single column and were corrected.
    pub corrected: u64,
    /// Detections with an inconsistent locator pattern, left untouched.
    pub uncorrectable: u64,
}

/// Sharded multi-crossbar engine with checksum error correction.
#[derive(Debug, Clone)]
pub struct ShardedEngine {
    /// Shard grid rows (row blocks of the weight matrix).
    pub grid_r: usize,
    /// Shard grid columns (column blocks of the weight matrix).
    pub grid_c: usize,
    /// How many workers one `forward` call fans `(sample, shard)` jobs
    /// across.
    pub par: Parallelism,
    /// Append checksum columns and verify/correct at reduction time.
    pub checksum: bool,
    /// Detection-threshold factor, scaled by `sqrt(shard cells)` at
    /// verification (see the module docs).
    pub threshold: f64,
    /// Optional gross-fault injection policy.
    pub fault: Option<FaultSpec>,
    stats: Arc<ShardStats>,
}

impl Default for ShardedEngine {
    fn default() -> Self {
        Self::new(2, 2)
    }
}

impl ShardedEngine {
    /// Engine over an `grid_r x grid_c` shard grid with checksum
    /// correction on at the default threshold.
    pub fn new(grid_r: usize, grid_c: usize) -> Self {
        Self {
            grid_r,
            grid_c,
            par: Parallelism::Auto,
            checksum: true,
            threshold: DEFAULT_CHECKSUM_THRESHOLD,
            fault: None,
            stats: Arc::new(ShardStats::default()),
        }
    }

    /// Set the engine-level parallelism.
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.par = par;
        self
    }

    /// Enable or disable the checksum columns + reduction check.
    pub fn with_checksum(mut self, on: bool) -> Self {
        self.checksum = on;
        self
    }

    /// Set the detection-threshold factor.
    pub fn with_threshold(mut self, threshold: f64) -> Self {
        self.threshold = threshold;
        self
    }

    /// Attach a fault-injection policy.
    pub fn with_fault(mut self, fault: FaultSpec) -> Self {
        self.fault = Some(fault);
        self
    }

    /// Shared telemetry handle (survives moving the engine into a
    /// coordinator).
    pub fn stats(&self) -> Arc<ShardStats> {
        Arc::clone(&self.stats)
    }

    /// Current counter snapshot.
    pub fn counts(&self) -> ShardCounts {
        self.stats.snapshot()
    }
}

/// Copy shard region `reg` of a logical `(_, cols)` plane into the
/// scratch plane of row stride `width`, zero-filling everything else
/// (padded rows/columns and the checksum columns' noise).
fn gather_region(src: &[f32], cols: usize, reg: &ShardRegion, width: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..reg.rlen {
        let s0 = (reg.r0 + i) * cols + reg.c0;
        out[i * width..i * width + reg.clen].copy_from_slice(&src[s0..s0 + reg.clen]);
    }
}

/// Program-once handle of the sharded engine: every shard's augmented
/// array materialized once (checksum columns encoded, faults — if a
/// policy is attached — drawn as the stream's *sample 0* cell, since a
/// deployed fabric programs one physical instance).  Reads fan over
/// requests; each request's verify-correct-accumulate reduction runs
/// in fixed shard order with the same arithmetic as `forward`, so
/// served outputs are bit-identical to the uncached path on the same
/// `(w, z)`.
struct ProgrammedShards {
    rows: usize,
    cols: usize,
    grid: ShardGrid,
    codes: Vec<ChecksumCode>,
    arrays: Vec<CrossbarArray>,
    width: usize,
    max_r: usize,
    checksum: bool,
    threshold: f64,
    par: Parallelism,
    stats: Arc<ShardStats>,
}

impl ProgrammedRead for ProgrammedShards {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn read_batch(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        if x.len() != batch * self.rows {
            return Err(Error::Geometry(format!(
                "read batch expects {} inputs ({batch} x {} rows), got {}",
                batch * self.rows,
                self.rows,
                x.len()
            )));
        }
        let nshards = self.grid.count();
        let y = run_blocked(
            self.par,
            batch,
            self.cols,
            || (vec![0.0f32; self.max_r], vec![0.0f32; self.width]),
            |s, scratch, out| {
                let (tx, partial) = scratch;
                for k in 0..nshards {
                    let reg = self.grid.region(k);
                    tx.fill(0.0);
                    let x0 = s * self.rows + reg.r0;
                    tx[..reg.rlen].copy_from_slice(&x[x0..x0 + reg.rlen]);
                    self.arrays[k].read(&tx[..], &mut partial[..]);
                    let (data, rest) = partial.split_at_mut(reg.clen);
                    if self.checksum {
                        let span = obs::stage_start();
                        let code = &self.codes[k];
                        let cells = (reg.rlen * reg.clen) as f64;
                        let abs_threshold = self.threshold * cells.sqrt();
                        match code.verify(data, &rest[..code.extra()], abs_threshold) {
                            Verdict::Clean => {}
                            Verdict::Fault { col, delta } => {
                                data[col] = (data[col] as f64 + delta) as f32;
                                self.stats.record_verdicts(1, 1, 0);
                            }
                            Verdict::Detected => {
                                self.stats.record_verdicts(1, 0, 1);
                            }
                        }
                        obs::stage_end(Stage::ShardVerify, span);
                    }
                    let yrow = &mut out[reg.c0..reg.c0 + reg.clen];
                    for (yj, &pj) in yrow.iter_mut().zip(data.iter()) {
                        *yj += pj;
                    }
                }
            },
        );
        Ok(y)
    }
}

impl VmmEngine for ShardedEngine {
    fn name(&self) -> &'static str {
        "sharded"
    }

    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        spec.check()?;
        let (r, c) = (spec.rows, spec.cols);
        let grid = ShardGrid::new(r, c, self.grid_r, self.grid_c)?;
        let nshards = grid.count();
        let extra_max = if self.checksum {
            crate::shard::extra_cols(grid.max_clen())
        } else {
            0
        };
        let width = grid.max_clen() + extra_max;
        let max_r = grid.max_rlen();
        let table = PulseTable::new(params, false);
        let codes: Vec<ChecksumCode> = if self.checksum {
            (0..nshards)
                .map(|k| ChecksumCode::new(grid.region(k).clen))
                .collect()
        } else {
            Vec::new()
        };
        let mut scratch = ProgramScratch::new(max_r, width);
        let mut arrays = Vec::with_capacity(nshards);
        let mut injected = 0u64;
        for k in 0..nshards {
            let reg = grid.region(k);
            gather_region(&spec.w, c, &reg, width, &mut scratch.w);
            gather_region(&spec.noise.z0, c, &reg, width, &mut scratch.noise.z0);
            gather_region(&spec.noise.z1, c, &reg, width, &mut scratch.noise.z1);
            gather_region(&spec.noise.z2, c, &reg, width, &mut scratch.noise.z2);
            if self.checksum {
                let code = &codes[k];
                for i in 0..reg.rlen {
                    let row = &mut scratch.w[i * width..i * width + reg.clen + code.extra()];
                    let (data, cs) = row.split_at_mut(reg.clen);
                    code.encode_row(data, cs);
                }
            }
            let mut arr = CrossbarArray::zeroed(max_r, width);
            arr.reprogram_active(&scratch.w, params, &scratch.noise, &table, reg.rlen * reg.clen);
            if let Some(f) = self.fault {
                if let Some(col) = f.draw(0, k, reg.clen) {
                    arr.force_column(col, f.level);
                    injected += 1;
                }
            }
            arrays.push(arr);
        }
        if injected > 0 {
            self.stats.record_injected(injected);
        }
        Ok(ProgrammedVmm::new(
            spec,
            ProgrammedShards {
                rows: r,
                cols: c,
                grid,
                codes,
                arrays,
                width,
                max_r,
                checksum: self.checksum,
                threshold: self.threshold,
                par: self.par,
                stats: Arc::clone(&self.stats),
            },
        ))
    }

    fn cache_config(&self) -> String {
        let fault = match self.fault {
            Some(f) => format!("{}@{}:{}", f.rate, f.level, f.seed),
            None => "none".into(),
        };
        format!(
            "sharded:{}x{}:cs={}:t={}:fault={}",
            self.grid_r, self.grid_c, self.checksum, self.threshold, fault
        )
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        let (b, r, c) = (batch.batch, batch.rows, batch.cols);
        let grid = ShardGrid::new(r, c, self.grid_r, self.grid_c)?;
        let nshards = grid.count();
        let max_r = grid.max_rlen();
        // Scratch width covers the widest shard plus its checksum
        // columns; every job's partial slice shares this stride.
        let extra_max = if self.checksum {
            crate::shard::extra_cols(grid.max_clen())
        } else {
            0
        };
        let width = grid.max_clen() + extra_max;
        let table = PulseTable::new(params, false);
        let stats = &self.stats;
        let checksum = self.checksum;
        let fault = self.fault;
        // One code per shard index (shared by every sample's job and
        // the reduction): a grid has at most two distinct column-block
        // widths, so per-job construction would be pure waste.
        let codes: Vec<ChecksumCode> = if checksum {
            (0..nshards)
                .map(|k| ChecksumCode::new(grid.region(k).clen))
                .collect()
        } else {
            Vec::new()
        };

        // Parallel phase: one job per (sample, shard), each programming
        // its augmented shard array and reading its partial into its
        // own stride-`width` slice — bit-deterministic for any pool
        // width.
        let mut partials = run_blocked(
            self.par,
            b * nshards,
            width,
            || ProgramScratch::new(max_r, width),
            |q, scratch, out| {
                let (s, k) = (q / nshards, q % nshards);
                let reg = grid.region(k);
                gather_region(batch.w_of(s), c, &reg, width, &mut scratch.w);
                gather_region(batch.z_of(s, 0), c, &reg, width, &mut scratch.noise.z0);
                gather_region(batch.z_of(s, 1), c, &reg, width, &mut scratch.noise.z1);
                gather_region(batch.z_of(s, 2), c, &reg, width, &mut scratch.noise.z2);
                if checksum {
                    let code = &codes[k];
                    for i in 0..reg.rlen {
                        let row = &mut scratch.w[i * width..i * width + reg.clen + code.extra()];
                        let (data, cs) = row.split_at_mut(reg.clen);
                        code.encode_row(data, cs);
                    }
                }
                let active = reg.rlen * reg.clen;
                scratch
                    .arr
                    .reprogram_active(&scratch.w, params, &scratch.noise, &table, active);
                if let Some(f) = fault {
                    if let Some(col) = f.draw(s, k, reg.clen) {
                        scratch.arr.force_column(col, f.level);
                        stats.record_injected(1);
                    }
                }
                scratch.x.fill(0.0);
                let xs = &batch.x_of(s)[reg.r0..reg.r0 + reg.rlen];
                scratch.x[..reg.rlen].copy_from_slice(xs);
                scratch.arr.read(&scratch.x, out);
            },
        );

        // Sequential reduction: verify/correct each shard partial, then
        // accumulate into the output in fixed shard order.
        let mut y_hw = vec![0.0f32; b * c];
        let (mut detected, mut corrected, mut uncorrectable) = (0u64, 0u64, 0u64);
        for s in 0..b {
            for k in 0..nshards {
                let reg = grid.region(k);
                let base = (s * nshards + k) * width;
                let part = &mut partials[base..base + width];
                let (data, rest) = part.split_at_mut(reg.clen);
                if checksum {
                    let code = &codes[k];
                    let cells = (reg.rlen * reg.clen) as f64;
                    let abs_threshold = self.threshold * cells.sqrt();
                    match code.verify(data, &rest[..code.extra()], abs_threshold) {
                        Verdict::Clean => {}
                        Verdict::Fault { col, delta } => {
                            data[col] = (data[col] as f64 + delta) as f32;
                            detected += 1;
                            corrected += 1;
                        }
                        Verdict::Detected => {
                            detected += 1;
                            uncorrectable += 1;
                        }
                    }
                }
                let yrow = &mut y_hw[s * c + reg.c0..s * c + reg.c0 + reg.clen];
                for (yj, &pj) in yrow.iter_mut().zip(data.iter()) {
                    *yj += pj;
                }
            }
        }
        self.stats.record_verdicts(detected, corrected, uncorrectable);

        let y_sw = software_vmm_batch(batch);
        Ok(VmmOutput { y_hw, y_sw })
    }

    fn internal_parallelism(&self) -> usize {
        self.par.threads()
    }

    fn shard_counts(&self) -> Option<ShardCounts> {
        Some(self.counts())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::util::rng::Xoshiro256;
    use crate::vmm::NativeEngine;

    fn random_batch(b: usize, r: usize, c: usize, seed: u64) -> VmmBatch {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut vb = VmmBatch::zeros(b, r, c);
        rng.fill_uniform_f32(&mut vb.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut vb.x, 0.0, 1.0);
        rng.fill_normal_f32(&mut vb.z);
        vb
    }

    #[test]
    fn unit_grid_without_checksum_bit_identical_to_native() {
        let b = random_batch(6, 32, 32, 301);
        let params = presets::ag_si().params;
        let sharded = ShardedEngine::new(1, 1)
            .with_checksum(false)
            .forward(&b, &params)
            .unwrap();
        let native = NativeEngine::sequential().forward(&b, &params).unwrap();
        assert_eq!(sharded.y_hw, native.y_hw);
        assert_eq!(sharded.y_sw, native.y_sw);
    }

    #[test]
    fn unit_grid_with_clean_checksum_bit_identical_to_native() {
        // Checksum columns must be transparent when no correction
        // fires: a high threshold guarantees Clean verdicts here.
        let b = random_batch(6, 32, 32, 302);
        let params = presets::epiram().params;
        let sharded = ShardedEngine::new(1, 1)
            .with_threshold(64.0)
            .forward(&b, &params)
            .unwrap();
        let native = NativeEngine::sequential().forward(&b, &params).unwrap();
        assert_eq!(sharded.y_hw, native.y_hw);
        assert_eq!(sharded.counts().detected, 0);
    }

    #[test]
    fn parallel_fan_is_bit_identical_to_sequential() {
        let b = random_batch(9, 48, 40, 303);
        let params = presets::epiram().params;
        let fault = FaultSpec::stuck_at_on(0.3, 77);
        let run = |threads| {
            ShardedEngine::new(3, 2)
                .with_parallelism(Parallelism::Fixed(threads))
                .with_fault(fault)
                .forward(&b, &params)
                .unwrap()
        };
        let seq = run(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(seq.y_hw, run(threads).y_hw, "threads={threads}");
        }
    }

    #[test]
    fn injected_gross_fault_is_corrected_on_quiet_device() {
        // Near-ideal device: the checksum floor is tiny, so a low
        // threshold cleanly separates faults from clean shards.
        let b = random_batch(8, 64, 64, 304);
        let params = DeviceParams::ideal();
        let fault = FaultSpec::stuck_at_on(1.0, 9);
        let corrected = ShardedEngine::new(2, 2)
            .with_threshold(0.05)
            .with_fault(fault)
            .forward(&b, &params)
            .unwrap();
        let broken = ShardedEngine::new(2, 2)
            .with_checksum(false)
            .with_fault(fault)
            .forward(&b, &params)
            .unwrap();
        fn max_abs(out: &VmmOutput) -> f64 {
            out.errors().iter().fold(0.0f64, |m, e| m.max(e.abs()))
        }
        assert!(max_abs(&broken) > 4.0, "fault too small: {}", max_abs(&broken));
        assert!(max_abs(&corrected) < 1.0, "residual too big: {}", max_abs(&corrected));
    }

    #[test]
    fn counters_track_injection_and_correction() {
        let b = random_batch(8, 64, 64, 305);
        let engine = ShardedEngine::new(2, 2)
            .with_threshold(0.05)
            .with_fault(FaultSpec::stuck_at_on(1.0, 9));
        engine.forward(&b, &DeviceParams::ideal()).unwrap();
        let counts = engine.counts();
        // rate 1.0: one fault per (sample, shard).
        assert_eq!(counts.injected, 8 * 4);
        assert_eq!(counts.detected, counts.injected);
        assert_eq!(counts.corrected, counts.injected);
        assert_eq!(counts.uncorrectable, 0);
        engine.stats().reset();
        assert_eq!(engine.counts(), ShardCounts::default());
    }

    #[test]
    fn programmed_read_bit_identical_to_uncached_forward() {
        // A served request must decode exactly as the uncached path on
        // the same (w, z) — including through the checksum reduction.
        let mut rng = Xoshiro256::seed_from_u64(310);
        let (r, c) = (48, 40);
        let mut w = vec![0.0f32; r * c];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let spec = ProgramSpec::from_seed(r, c, w, 3100);
        let params = presets::epiram().params;
        let mut x = vec![0.0f32; 5 * r];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let engine = |par| ShardedEngine::new(3, 2).with_parallelism(par);
        let uncached = engine(Parallelism::Fixed(1))
            .forward(&spec.to_batch(&x, 5), &params)
            .unwrap();
        for par in [Parallelism::Fixed(1), Parallelism::Auto] {
            let handle = engine(par).program(&spec, &params).unwrap();
            let served = handle.forward(&x, 5).unwrap();
            assert_eq!(served.y_hw, uncached.y_hw, "{par:?}");
            assert_eq!(served.y_sw, uncached.y_sw);
        }
    }

    #[test]
    fn programmed_fault_draw_matches_sample_zero() {
        // A deployed fabric programs once: its fault cells are the
        // stream's sample-0 draws, so serving one request bit-equals
        // the uncached single-sample batch under the same policy.
        let mut rng = Xoshiro256::seed_from_u64(311);
        let mut w = vec![0.0f32; 64 * 64];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let spec = ProgramSpec::from_seed(64, 64, w, 3110);
        let mut x = vec![0.0f32; 64];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let fault = FaultSpec::stuck_at_on(1.0, 9);
        let engine = ShardedEngine::new(2, 2)
            .with_threshold(0.05)
            .with_fault(fault);
        let handle = engine.program(&spec, &DeviceParams::ideal()).unwrap();
        let served = handle.forward(&x, 1).unwrap();
        let uncached = ShardedEngine::new(2, 2)
            .with_threshold(0.05)
            .with_fault(fault)
            .forward(&spec.to_batch(&x, 1), &DeviceParams::ideal())
            .unwrap();
        assert_eq!(served.y_hw, uncached.y_hw);
        // Programming injected one fault per shard; the read detected
        // and corrected each.
        let counts = engine.counts();
        assert_eq!(counts.injected, 4);
        assert_eq!(counts.corrected, 4);
    }

    #[test]
    fn ragged_grid_supported() {
        let b = random_batch(3, 50, 70, 306);
        let params = presets::taox_hfox().params;
        let out = ShardedEngine::new(3, 4).forward(&b, &params).unwrap();
        assert_eq!(out.y_hw.len(), 3 * 70);
        assert!(out.errors().iter().all(|e| e.is_finite()));
    }

    #[test]
    fn oversize_or_zero_grid_rejected() {
        let b = random_batch(1, 8, 8, 307);
        let params = presets::epiram().params;
        assert!(ShardedEngine::new(0, 1).forward(&b, &params).is_err());
        assert!(ShardedEngine::new(9, 1).forward(&b, &params).is_err());
        assert!(ShardedEngine::new(1, 9).forward(&b, &params).is_err());
    }

    #[test]
    fn internal_parallelism_reported() {
        assert_eq!(
            ShardedEngine::new(2, 2)
                .with_parallelism(Parallelism::Fixed(5))
                .internal_parallelism(),
            5
        );
        assert!(ShardedEngine::default().internal_parallelism() >= 1);
    }
}
