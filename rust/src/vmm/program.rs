//! Program-once/read-many split of the engine contract.
//!
//! Every batch engine in this crate historically reprogrammed its
//! crossbar from scratch for every sample of every `forward` call —
//! the right model for Monte-Carlo error populations, and exactly the
//! wrong one for *serving*, where weights are programmed once and read
//! millions of times (the deployment model of arXiv:2508.13298).  The
//! device physics already separates the two phases: all stochastic
//! draws (C2C walk, mismatch residue) enter at **program** time, and
//! the analog **read** is a deterministic function of the programmed
//! conductances and the drive vector.  Splitting the contract is
//! therefore physically faithful, not an approximation:
//!
//! * [`ProgramSpec`] — one weight matrix plus the explicit noise draws
//!   of its single programming cycle (seedable via
//!   [`ProgramSpec::from_seed`]).
//! * [`crate::vmm::VmmEngine::program`] — engine-specific programming,
//!   returning a [`ProgrammedVmm`] handle.
//! * [`ProgrammedVmm::read`] / [`ProgrammedVmm::forward`] — the
//!   read-many phase: serve any number of input vectors against the
//!   programmed arrays, **bit-identical** to the engine's `forward` on
//!   a batch carrying the same `(w, z)` per sample (the property suite
//!   in `rust/tests/proptests.rs` enforces this for every engine).
//!
//! Engines without a materialized-array path (the artifact-pinned XLA
//! engine, the mitigation adapter) return a [`ReplayProgrammed`]
//! handle, which replays the full `forward` with the stored `(w, z)`
//! replicated per request — bit-identical by construction, amortizing
//! nothing, but letting the serving layer treat every engine uniformly
//! (the [`crate::serve::ProgramCache`] still deduplicates handles).

use std::sync::Arc;

use crate::crossbar::array::ProgramNoise;
use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::obs::{self, CounterId};
use crate::util::codec::Codec;
use crate::util::json::{obj, Json};
use crate::util::rng::Xoshiro256;

use super::engine::{DynEngine, VmmBatch, VmmEngine, VmmOutput};
use super::software::software_vmm_single;

/// One weight matrix plus the explicit programming-noise draws of its
/// single programming cycle — everything an engine needs to program
/// arrays once and serve reads forever after.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub rows: usize,
    pub cols: usize,
    /// Target weights, row-major `(rows, cols)`, in `[-1, 1]`.
    pub w: Vec<f32>,
    /// The cycle's noise draws over the logical geometry (`z0` C2C+,
    /// `z1` C2C-, `z2` mismatch).
    pub noise: ProgramNoise,
    /// Seed label identifying the noise content (cache identity; see
    /// [`crate::serve::ProgramCache`]).
    pub program_seed: u64,
}

impl ProgramSpec {
    /// Spec with noise drawn from `program_seed` in channel order
    /// (`z0`, `z1`, `z2`) — the same stream discipline as the
    /// coordinator's artifact-input packing.
    pub fn from_seed(rows: usize, cols: usize, w: Vec<f32>, program_seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(program_seed);
        let noise = ProgramNoise::sample(&mut rng, rows * cols);
        Self { rows, cols, w, noise, program_seed }
    }

    /// Spec with caller-supplied noise planes; `program_seed` is the
    /// caller's label for that noise content (it must uniquely identify
    /// the planes, or the program cache will conflate distinct
    /// programs).
    pub fn with_noise(
        rows: usize,
        cols: usize,
        w: Vec<f32>,
        noise: ProgramNoise,
        program_seed: u64,
    ) -> Self {
        Self { rows, cols, w, noise, program_seed }
    }

    /// Validate internal consistency.
    pub fn check(&self) -> Result<()> {
        let cells = self.rows * self.cols;
        if self.rows == 0 || self.cols == 0 {
            return Err(Error::Shape("program spec geometry must be positive".into()));
        }
        if self.w.len() != cells {
            return Err(Error::Shape(format!(
                "program spec w: {} != {cells}",
                self.w.len()
            )));
        }
        for (name, plane) in [
            ("z0", &self.noise.z0),
            ("z1", &self.noise.z1),
            ("z2", &self.noise.z2),
        ] {
            if plane.len() != cells {
                return Err(Error::Shape(format!(
                    "program spec {name}: {} != {cells}",
                    plane.len()
                )));
            }
        }
        Ok(())
    }

    /// The uncached batch equivalent to serving `batch` requests
    /// against this program: every sample carries the spec's `(w, z)`,
    /// inputs are the request vectors (row-major `(batch, rows)`).
    /// This is the comparison object of the cached-vs-uncached
    /// bit-equality properties.
    pub fn to_batch(&self, x: &[f32], batch: usize) -> VmmBatch {
        assert_eq!(x.len(), batch * self.rows, "request buffer size mismatch");
        let cells = self.rows * self.cols;
        let mut vb = VmmBatch::zeros(batch, self.rows, self.cols);
        vb.x.copy_from_slice(x);
        for s in 0..batch {
            vb.w[s * cells..(s + 1) * cells].copy_from_slice(&self.w);
            let zb = s * 3 * cells;
            vb.z[zb..zb + cells].copy_from_slice(&self.noise.z0);
            vb.z[zb + cells..zb + 2 * cells].copy_from_slice(&self.noise.z1);
            vb.z[zb + 2 * cells..zb + 3 * cells].copy_from_slice(&self.noise.z2);
        }
        vb
    }

    /// Serialize every field to the artifact value model, losslessly:
    /// each `f32` widens exactly to `f64`, and the 64-bit seed label is
    /// split into two 32-bit halves (a single `f64` cannot carry all
    /// 64 bits).  Custom-noise specs ([`ProgramSpec::with_noise`])
    /// round-trip too — the planes travel with the document.
    pub fn to_json(&self) -> Json {
        let plane =
            |p: &[f32]| Json::Arr(p.iter().map(|&v| Json::Num(v as f64)).collect());
        obj([
            ("kind", Json::Str("program-spec".into())),
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("seed_hi", Json::Num((self.program_seed >> 32) as f64)),
            ("seed_lo", Json::Num((self.program_seed & 0xFFFF_FFFF) as f64)),
            ("w", plane(&self.w)),
            ("z0", plane(&self.noise.z0)),
            ("z1", plane(&self.noise.z1)),
            ("z2", plane(&self.noise.z2)),
        ])
    }

    /// Rebuild a spec from [`ProgramSpec::to_json`] output, validating
    /// geometry.
    pub fn from_json(v: &Json) -> Result<ProgramSpec> {
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Parse(format!("program spec missing '{key}'")))
        };
        let plane = |key: &str| -> Result<Vec<f32>> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Parse(format!("program spec missing '{key}'")))?
                .iter()
                .map(|e| {
                    e.as_f64()
                        .map(|x| x as f32)
                        .ok_or_else(|| Error::Parse(format!("non-numeric entry in '{key}'")))
                })
                .collect()
        };
        let program_seed = ((num("seed_hi")? as u64) << 32) | (num("seed_lo")? as u64);
        let spec = ProgramSpec::with_noise(
            num("rows")? as usize,
            num("cols")? as usize,
            plane("w")?,
            ProgramNoise { z0: plane("z0")?, z1: plane("z1")?, z2: plane("z2")? },
            program_seed,
        );
        spec.check()?;
        Ok(spec)
    }

    /// Persist to `path` in the framing the path convention selects
    /// ([`Codec::for_path`]): `.json` text or `.melb` binary — the
    /// deployment artifact a serving node programs its cache from.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        Codec::for_path(path).write(path, &self.to_json())
    }

    /// Load a persisted spec (either framing — the codec sniffs).
    pub fn load(path: &std::path::Path) -> Result<ProgramSpec> {
        Self::from_json(&Codec::read(path)?)
    }
}

/// Engine-specific programmed state: the read-many half of the split
/// contract.  Implementations hold materialized arrays (or a replay
/// closure over the full engine) and serve batched reads from them.
pub trait ProgrammedRead: Send + Sync {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// Decoded analog reads of `batch` input vectors (row-major
    /// `(batch, rows)`), returned row-major `(batch, cols)`.
    fn read_batch(&self, x: &[f32], batch: usize) -> Result<Vec<f32>>;
}

/// A programmed crossbar handle: program once, read many.  Cheaply
/// cloneable (the programmed state is shared), so the serving cache
/// can hand the same program to many scheduler workers.
#[derive(Clone)]
pub struct ProgrammedVmm {
    read: Arc<dyn ProgrammedRead>,
    /// Exact target weights, retained for the software reference of
    /// [`ProgrammedVmm::forward`].
    w: Arc<Vec<f32>>,
    rows: usize,
    cols: usize,
}

impl std::fmt::Debug for ProgrammedVmm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProgrammedVmm")
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .finish()
    }
}

impl ProgrammedVmm {
    /// Wrap an engine's programmed state for the given spec.
    pub fn new<R: ProgrammedRead + 'static>(spec: &ProgramSpec, read: R) -> Self {
        debug_assert_eq!(read.rows(), spec.rows);
        debug_assert_eq!(read.cols(), spec.cols);
        Self {
            read: Arc::new(read),
            w: Arc::new(spec.w.clone()),
            rows: spec.rows,
            cols: spec.cols,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The serving hot path: hardware reads only, row-major
    /// `(batch, cols)`.  Nothing here is cached — every read is a
    /// fresh pass over the programmed conductances, so any read-path
    /// stochasticity stays fresh per request by construction.
    pub fn read(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        if x.len() != batch * self.rows {
            return Err(Error::Shape(format!(
                "serve read: x {} != {} ({} requests x {} rows)",
                x.len(),
                batch * self.rows,
                batch,
                self.rows
            )));
        }
        obs::incr(CounterId::ReadsExecuted);
        self.read.read_batch(x, batch)
    }

    /// The measurement path: hardware reads plus the exact software
    /// reference — the same output contract as
    /// [`crate::vmm::VmmEngine::forward`], for error telemetry and the
    /// bit-equality properties.
    pub fn forward(&self, x: &[f32], batch: usize) -> Result<VmmOutput> {
        let y_hw = self.read(x, batch)?;
        let mut y_sw = vec![0.0f32; batch * self.cols];
        let mut acc = vec![0.0f64; self.cols];
        for s in 0..batch {
            software_vmm_single(
                &self.w,
                &x[s * self.rows..(s + 1) * self.rows],
                self.rows,
                self.cols,
                &mut acc,
                &mut y_sw[s * self.cols..(s + 1) * self.cols],
            );
        }
        Ok(VmmOutput { y_hw, y_sw })
    }
}

/// Fallback programmed handle for engines without a materialized-array
/// path: every read replays the engine's full `forward` on the stored
/// `(w, z)` replicated per request — bit-identical to the uncached
/// path by construction, with zero amortization.
pub struct ReplayProgrammed {
    engine: DynEngine,
    spec: ProgramSpec,
    params: DeviceParams,
}

impl ReplayProgrammed {
    pub fn new(engine: DynEngine, spec: ProgramSpec, params: DeviceParams) -> Self {
        Self { engine, spec, params }
    }
}

impl ProgrammedRead for ReplayProgrammed {
    fn rows(&self) -> usize {
        self.spec.rows
    }

    fn cols(&self) -> usize {
        self.spec.cols
    }

    fn read_batch(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let cols = self.spec.cols;
        let rows = self.spec.rows;
        let mut y = vec![0.0f32; batch * cols];
        // Honour pinned batch sizes (XLA artifacts): serve the request
        // batch in engine-sized chunks, largest fitting first.  A
        // remainder smaller than every pinned size is padded up to the
        // smallest one with zero drives (grounded word lines) — sample
        // physics is independent, so the real requests decode
        // bit-identically and the pad outputs are discarded.
        let preferred = self.engine.preferred_batches();
        let mut start = 0;
        while start < batch {
            let remaining = batch - start;
            let (len, run) = if preferred.is_empty() {
                (remaining, remaining)
            } else {
                match preferred.iter().copied().find(|&b| b <= remaining) {
                    Some(b) => (b, b),
                    None => (remaining, *preferred.last().unwrap()),
                }
            };
            let mut xs = vec![0.0f32; run * rows];
            xs[..len * rows].copy_from_slice(&x[start * rows..(start + len) * rows]);
            let vb = self.spec.to_batch(&xs, run);
            let out = self.engine.forward(&vb, &self.params)?;
            y[start * cols..(start + len) * cols].copy_from_slice(&out.y_hw[..len * cols]);
            start += len;
        }
        Ok(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::vmm::{NativeEngine, SoftwareEngine};

    fn spec(rows: usize, cols: usize, seed: u64) -> ProgramSpec {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x57);
        let mut w = vec![0.0f32; rows * cols];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        ProgramSpec::from_seed(rows, cols, w, seed)
    }

    #[test]
    fn from_seed_is_deterministic_and_checked() {
        let a = spec(8, 6, 11);
        let b = spec(8, 6, 11);
        assert_eq!(a.noise.z0, b.noise.z0);
        assert_eq!(a.noise.z2, b.noise.z2);
        a.check().unwrap();
        let mut bad = spec(4, 4, 1);
        bad.w.pop();
        assert!(bad.check().is_err());
        let mut bad = spec(4, 4, 1);
        bad.noise.z1.pop();
        assert!(bad.check().is_err());
    }

    #[test]
    fn to_batch_replicates_program_per_sample() {
        let sp = spec(5, 7, 21);
        let mut rng = Xoshiro256::seed_from_u64(99);
        let mut x = vec![0.0f32; 3 * 5];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let vb = sp.to_batch(&x, 3);
        vb.check().unwrap();
        for s in 0..3 {
            assert_eq!(vb.w_of(s), &sp.w[..]);
            assert_eq!(vb.z_of(s, 0), &sp.noise.z0[..]);
            assert_eq!(vb.z_of(s, 1), &sp.noise.z1[..]);
            assert_eq!(vb.z_of(s, 2), &sp.noise.z2[..]);
            assert_eq!(vb.x_of(s), &x[s * 5..(s + 1) * 5]);
        }
    }

    #[test]
    fn replay_handle_bit_equals_uncached_forward() {
        let sp = spec(16, 12, 31);
        let params = presets::ag_si().params;
        let engine = DynEngine::new(NativeEngine::sequential());
        let handle = ProgrammedVmm::new(
            &sp,
            ReplayProgrammed::new(engine.clone(), sp.clone(), params),
        );
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut x = vec![0.0f32; 4 * 16];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let served = handle.forward(&x, 4).unwrap();
        let uncached = engine.forward(&sp.to_batch(&x, 4), &params).unwrap();
        assert_eq!(served.y_hw, uncached.y_hw);
        assert_eq!(served.y_sw, uncached.y_sw);
    }

    #[test]
    fn replay_pads_remainders_for_pinned_batch_engines() {
        // An engine with pinned batch sizes and no batch-1 artifact:
        // the replay handle must pad the remainder up to a supported
        // size (zero drives), never submit an unsupported batch, and
        // still serve the real requests bit-identically.
        #[derive(Clone)]
        struct Pinned(NativeEngine);
        impl VmmEngine for Pinned {
            fn name(&self) -> &'static str {
                "pinned"
            }
            fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
                assert_eq!(batch.batch, 4, "only batch-4 'artifacts' exist");
                self.0.forward(batch, params)
            }
            fn preferred_batches(&self) -> Vec<usize> {
                vec![4]
            }
        }
        let sp = spec(8, 8, 51);
        let params = presets::epiram().params;
        let handle = ProgrammedVmm::new(
            &sp,
            ReplayProgrammed::new(
                DynEngine::new(Pinned(NativeEngine::sequential())),
                sp.clone(),
                params,
            ),
        );
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut x = vec![0.0f32; 6 * 8];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        // 6 requests = one full pinned batch + a padded remainder of 2.
        let served = handle.forward(&x, 6).unwrap();
        let uncached = NativeEngine::sequential()
            .forward(&sp.to_batch(&x, 6), &params)
            .unwrap();
        assert_eq!(served.y_hw, uncached.y_hw);
        assert_eq!(served.y_hw.len(), 6 * 8);
    }

    #[test]
    fn read_rejects_bad_request_buffer() {
        let sp = spec(8, 8, 41);
        let handle = ProgrammedVmm::new(
            &sp,
            ReplayProgrammed::new(
                DynEngine::new(SoftwareEngine),
                sp.clone(),
                DeviceParams::ideal(),
            ),
        );
        assert!(handle.read(&[0.0; 7], 1).is_err());
        assert!(handle.read(&[], 0).unwrap().is_empty());
    }

    #[test]
    fn spec_roundtrips_through_both_codec_framings() {
        let sp = spec(9, 7, 0xDEAD_BEEF_CAFE_F00D); // full-width seed
        let doc = sp.to_json();
        let back = ProgramSpec::from_json(&doc).unwrap();
        assert_eq!(back.rows, sp.rows);
        assert_eq!(back.cols, sp.cols);
        assert_eq!(back.program_seed, sp.program_seed);
        assert_eq!(back.w, sp.w);
        assert_eq!(back.noise.z0, sp.noise.z0);
        assert_eq!(back.noise.z1, sp.noise.z1);
        assert_eq!(back.noise.z2, sp.noise.z2);
        // Through files in both framings: still bit-exact.
        let dir = std::env::temp_dir().join("meliso_spec_codec_test");
        let _ = std::fs::remove_dir_all(&dir);
        for name in ["spec.json", "spec.melb"] {
            let path = dir.join(name);
            sp.save(&path).unwrap();
            let loaded = ProgramSpec::load(&path).unwrap();
            assert_eq!(loaded.w, sp.w, "{name}");
            assert_eq!(loaded.noise.z2, sp.noise.z2, "{name}");
            assert_eq!(loaded.program_seed, sp.program_seed, "{name}");
        }
        // Corrupt geometry is rejected by the embedded check.
        let mut truncated = sp.clone();
        truncated.w.pop();
        assert!(ProgramSpec::from_json(&truncated.to_json()).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn default_trait_program_is_unsupported() {
        struct Bare;
        impl VmmEngine for Bare {
            fn name(&self) -> &'static str {
                "bare"
            }
            fn forward(&self, _: &VmmBatch, _: &DeviceParams) -> Result<VmmOutput> {
                unreachable!()
            }
        }
        let err = Bare.program(&spec(4, 4, 5), &DeviceParams::ideal()).unwrap_err();
        assert!(err.to_string().contains("bare"), "{err}");
        assert_eq!(Bare.cache_config(), "bare");
    }
}
