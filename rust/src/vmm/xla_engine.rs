//! The XLA engine: runs the AOT-lowered MELISO pipeline (L2 model +
//! L1 Pallas kernel) through PJRT.  This is the production request
//! path — python is long gone by the time this executes.

use std::sync::Arc;

use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::runtime::XlaRuntime;

use super::engine::{DynEngine, VmmBatch, VmmEngine, VmmOutput};
use super::program::{ProgramSpec, ProgrammedVmm, ReplayProgrammed};

/// PJRT-backed engine over the `meliso_fwd` artifacts.
#[derive(Debug, Clone)]
pub struct XlaEngine {
    rt: Arc<XlaRuntime>,
    batches: Vec<usize>,
}

impl XlaEngine {
    /// Wrap a runtime; discovers available `meliso_fwd` batch sizes
    /// from the manifest.
    pub fn new(rt: Arc<XlaRuntime>) -> Result<Self> {
        let batches = rt.manifest().batches_for("meliso_fwd");
        if batches.is_empty() {
            return Err(Error::Artifact(
                "manifest has no meliso_fwd artifacts".into(),
            ));
        }
        Ok(Self { rt, batches })
    }

    /// Convenience: load from the default artifacts directory.
    pub fn from_default_dir() -> Result<Self> {
        let rt = Arc::new(XlaRuntime::new(&XlaRuntime::default_dir())?);
        Self::new(rt)
    }

    pub fn runtime(&self) -> &Arc<XlaRuntime> {
        &self.rt
    }

    /// Largest artifact batch ≤ n, or the smallest artifact if none fit.
    pub fn plan_batch(&self, n: usize) -> usize {
        self.batches
            .iter()
            .copied()
            .find(|&b| b <= n)
            .unwrap_or_else(|| *self.batches.last().unwrap())
    }

    /// Raw differential crossbar read through the `meliso_vmm`
    /// artifact (the L1 kernel alone) — used by the kernel-level
    /// cross-check and the hot-path bench.
    pub fn raw_vmm(
        &self,
        gp: &[f32],
        gn: &[f32],
        v: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        let outs = self.rt.execute_f32("meliso_vmm", batch, &[gp, gn, v])?;
        Ok(outs.into_iter().next().unwrap())
    }

    /// Conductance programming through the `meliso_program` artifact.
    pub fn program(
        &self,
        w: &[f32],
        z: &[f32],
        params: &DeviceParams,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let p = params.to_f32_vec();
        let mut outs = self
            .rt
            .execute_f32("meliso_program", batch, &[w, z, &p])?;
        let gn = outs.pop().unwrap();
        let gp = outs.pop().unwrap();
        Ok((gp, gn))
    }
}

impl VmmEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        let b = batch.batch;
        if !self.batches.contains(&b) {
            return Err(Error::Artifact(format!(
                "no meliso_fwd artifact for batch {b}; available: {:?} \
                 (the coordinator chunks to these)",
                self.batches
            )));
        }
        let p = params.to_f32_vec();
        let mut outs = self
            .rt
            .execute_f32("meliso_fwd", b, &[&batch.w, &batch.x, &batch.z, &p])?;
        let y_sw = outs.pop().unwrap();
        let y_hw = outs.pop().unwrap();
        Ok(VmmOutput { y_hw, y_sw })
    }

    fn preferred_batches(&self) -> Vec<usize> {
        self.batches.clone()
    }

    /// The artifact path has no materialized-array form (conductances
    /// live device-side, behind pinned shapes), so serving replays the
    /// full forward per read batch — bit-identical, unamortized.
    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        spec.check()?;
        Ok(ProgrammedVmm::new(
            spec,
            ReplayProgrammed::new(DynEngine::new(self.clone()), spec.clone(), *params),
        ))
    }
}

// Execution through PJRT is internally synchronized; the engine holds
// only Arc'd state.
unsafe impl Send for XlaEngine {}
unsafe impl Sync for XlaEngine {}

#[cfg(test)]
mod tests {
    //! Full engine behaviour (numerics vs native) is covered by
    //! `rust/tests/integration_xla.rs`, which requires artifacts.
    use super::*;

    #[test]
    fn missing_artifacts_error_is_actionable() {
        // Note: build the runtime against an explicit bad path instead
        // of mutating MELISO_ARTIFACTS — env mutation races the
        // default_dir test in runtime::client under the parallel test
        // runner.
        let err = XlaRuntime::new(std::path::Path::new("/nonexistent/meliso-artifacts"))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("artifact"), "{msg}");
    }
}
