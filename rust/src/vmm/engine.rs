//! The engine abstraction: one batch of MELISO forward passes, plus
//! the program-once/read-many split used by the serving subsystem
//! (see [`super::program`]).

use crate::device::params::DeviceParams;
use crate::error::Result;

use super::program::{ProgramSpec, ProgrammedVmm};

/// One batch of VMM jobs, in the artifact's input layout.
///
/// * `w` — target weights, `(batch, rows, cols)` row-major, `[-1, 1]`.
/// * `x` — input vectors, `(batch, rows)`, `[-1, 1]`.
/// * `z` — standard-normal noise, `(batch, 3, rows, cols)`: channel 0
///   C2C for the positive device, 1 for the negative device, 2 baseline
///   mismatch.
#[derive(Debug, Clone)]
pub struct VmmBatch {
    pub batch: usize,
    pub rows: usize,
    pub cols: usize,
    pub w: Vec<f32>,
    pub x: Vec<f32>,
    pub z: Vec<f32>,
}

impl VmmBatch {
    /// Allocate a zeroed batch.
    pub fn zeros(batch: usize, rows: usize, cols: usize) -> Self {
        Self {
            batch,
            rows,
            cols,
            w: vec![0.0; batch * rows * cols],
            x: vec![0.0; batch * rows],
            z: vec![0.0; batch * 3 * rows * cols],
        }
    }

    /// Weight sub-slice of sample `b`.
    pub fn w_of(&self, b: usize) -> &[f32] {
        let n = self.rows * self.cols;
        &self.w[b * n..(b + 1) * n]
    }

    /// Input sub-slice of sample `b`.
    pub fn x_of(&self, b: usize) -> &[f32] {
        &self.x[b * self.rows..(b + 1) * self.rows]
    }

    /// Noise sub-slice of sample `b`, channel `c`.
    pub fn z_of(&self, b: usize, c: usize) -> &[f32] {
        let n = self.rows * self.cols;
        let base = (b * 3 + c) * n;
        &self.z[base..base + n]
    }

    /// Validate internal consistency.
    pub fn check(&self) -> Result<()> {
        use crate::error::Error;
        let (b, r, c) = (self.batch, self.rows, self.cols);
        if self.w.len() != b * r * c {
            return Err(Error::Shape(format!("w: {} != {}", self.w.len(), b * r * c)));
        }
        if self.x.len() != b * r {
            return Err(Error::Shape(format!("x: {} != {}", self.x.len(), b * r)));
        }
        if self.z.len() != b * 3 * r * c {
            return Err(Error::Shape(format!(
                "z: {} != {}",
                self.z.len(),
                b * 3 * r * c
            )));
        }
        Ok(())
    }
}

/// Engine outputs: decoded hardware result and exact software result,
/// both `(batch, cols)` row-major.
#[derive(Debug, Clone)]
pub struct VmmOutput {
    pub y_hw: Vec<f32>,
    pub y_sw: Vec<f32>,
}

impl VmmOutput {
    /// Per-element errors `y_hw - y_sw` as f64.
    pub fn errors(&self) -> Vec<f64> {
        self.y_hw
            .iter()
            .zip(&self.y_sw)
            .map(|(&h, &s)| h as f64 - s as f64)
            .collect()
    }
}

/// Type-erased engine handle: a cheaply cloneable [`VmmEngine`] shared
/// by the experiments, the layered inference pipeline, and anything
/// else that composes engines dynamically (e.g. wrapping one in a
/// [`crate::mitigation::MitigatedEngine`] per network layer).
#[derive(Clone)]
pub struct DynEngine(std::sync::Arc<dyn VmmEngine>);

impl DynEngine {
    pub fn new<E: VmmEngine + 'static>(e: E) -> Self {
        Self(std::sync::Arc::new(e))
    }
}

impl VmmEngine for DynEngine {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput> {
        self.0.forward(batch, params)
    }

    fn preferred_batches(&self) -> Vec<usize> {
        self.0.preferred_batches()
    }

    fn internal_parallelism(&self) -> usize {
        self.0.internal_parallelism()
    }

    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        self.0.program(spec, params)
    }

    fn cache_config(&self) -> String {
        self.0.cache_config()
    }

    fn program_read(
        &self,
        spec: &ProgramSpec,
        params: &DeviceParams,
        x: &[f32],
        batch: usize,
    ) -> Result<(ProgrammedVmm, Vec<f32>)> {
        self.0.program_read(spec, params, x, batch)
    }

    fn shard_counts(&self) -> Option<super::ShardCounts> {
        self.0.shard_counts()
    }
}

/// A MELISO compute backend.
pub trait VmmEngine: Send + Sync {
    /// Engine name for reports.
    fn name(&self) -> &'static str;

    /// Run one batch of forward passes under the given device.
    fn forward(&self, batch: &VmmBatch, params: &DeviceParams) -> Result<VmmOutput>;

    /// Preferred batch sizes, descending (the coordinator chunks the
    /// population to these).  Engines that accept any batch return an
    /// empty slice.
    fn preferred_batches(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Worker threads this engine fans one `forward` call across.
    /// The coordinator divides its chunk-level parallelism by this so
    /// chunk- and engine-level parallelism compose instead of
    /// oversubscribing the host.  Engines that run a batch on the
    /// calling thread report 1.
    fn internal_parallelism(&self) -> usize {
        1
    }

    /// Program `spec`'s weights once under `params` and return a
    /// read-many handle whose reads are **bit-identical** to `forward`
    /// on a batch carrying the same `(w, z)` per sample.  Every
    /// shipped engine overrides this — with materialized arrays
    /// (native/tiled/sharded/software) or a replay adapter
    /// ([`super::program::ReplayProgrammed`]; XLA, mitigation).  The
    /// default is an explicit unsupported error so a new engine cannot
    /// silently serve nothing.
    fn program(&self, spec: &ProgramSpec, params: &DeviceParams) -> Result<ProgrammedVmm> {
        let _ = (spec, params);
        Err(crate::error::Error::Unsupported(format!(
            "engine '{}' has no program-once path (VmmEngine::program)",
            self.name()
        )))
    }

    /// Configuration identity for the serving program cache: two
    /// engines with the same `cache_config` must program bit-identical
    /// arrays from the same [`ProgramSpec`].  Parallelism knobs are
    /// deliberately excluded — results are bit-identical for any
    /// thread count, so differently-fanned clones share cache entries.
    fn cache_config(&self) -> String {
        self.name().to_string()
    }

    /// Fused program+read: program `spec` once and answer the first
    /// request batch against the fresh arrays in one pass, returning
    /// both the read-many handle and the batch's outputs.  The serving
    /// layer uses this on a cache miss so a cold model's first batch
    /// never goes back through the cache lock between programming and
    /// reading.  The returned `y` is bit-identical to
    /// `handle.read(x, batch)` — the default is exactly that call, and
    /// overrides must preserve it.
    fn program_read(
        &self,
        spec: &ProgramSpec,
        params: &DeviceParams,
        x: &[f32],
        batch: usize,
    ) -> Result<(ProgrammedVmm, Vec<f32>)> {
        let handle = self.program(spec, params)?;
        let y = handle.read(x, batch)?;
        Ok((handle, y))
    }

    /// ABFT checksum telemetry of this engine, when it maintains any —
    /// the sharded engine snapshots its [`super::ShardStats`]; engines
    /// without shard correction report `None`.  The fleet fabric rolls
    /// these up per node and fleet-wide.
    fn shard_counts(&self) -> Option<super::ShardCounts> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_layout_slices() {
        let mut b = VmmBatch::zeros(2, 4, 4);
        b.w[16] = 7.0; // sample 1, first weight
        b.x[4] = 3.0; // sample 1, first input
        b.z[(1 * 3 + 2) * 16] = 9.0; // sample 1, channel 2, first cell
        assert_eq!(b.w_of(1)[0], 7.0);
        assert_eq!(b.w_of(0)[0], 0.0);
        assert_eq!(b.x_of(1)[0], 3.0);
        assert_eq!(b.z_of(1, 2)[0], 9.0);
        assert_eq!(b.z_of(1, 1)[0], 0.0);
        assert!(b.check().is_ok());
    }

    #[test]
    fn check_catches_bad_sizes() {
        let mut b = VmmBatch::zeros(2, 4, 4);
        b.w.pop();
        assert!(b.check().is_err());
    }

    #[test]
    fn errors_are_differences() {
        let out = VmmOutput {
            y_hw: vec![1.5, 2.0],
            y_sw: vec![1.0, 2.5],
        };
        let e = out.errors();
        assert!((e[0] - 0.5).abs() < 1e-12);
        assert!((e[1] + 0.5).abs() < 1e-12);
    }
}
