//! Exact software VMM — the reference side of every error measurement
//! (the paper's "software-calculated dot product" at FP precision).

use crate::device::params::DeviceParams;
use crate::error::Result;

use super::engine::{VmmBatch, VmmEngine, VmmOutput};
use super::program::{ProgramSpec, ProgrammedRead, ProgrammedVmm};

/// Computes `y[b, j] = sum_i x[b, i] * w[b, i, j]` in f64, returned as
/// f32 (the common output type); `y_hw == y_sw` by construction.
#[derive(Debug, Default, Clone)]
pub struct SoftwareEngine;

/// Program-once handle of the exact engine: "programming" stores the
/// weights losslessly, reads are the exact product (the same kernel as
/// the software reference, so `y_hw == y_sw` stays bitwise true).
struct ProgrammedExact {
    rows: usize,
    cols: usize,
    w: Vec<f32>,
}

impl ProgrammedRead for ProgrammedExact {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn read_batch(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut y = vec![0.0f32; batch * self.cols];
        let mut acc = vec![0.0f64; self.cols];
        for s in 0..batch {
            software_vmm_single(
                &self.w,
                &x[s * self.rows..(s + 1) * self.rows],
                self.rows,
                self.cols,
                &mut acc,
                &mut y[s * self.cols..(s + 1) * self.cols],
            );
        }
        Ok(y)
    }
}

/// One exact sample `y[j] = sum_i x[i] * w[i, j]` in f64 accumulation,
/// written into `out` (f32).  `acc` is caller-provided scratch of
/// `cols` elements.  This is the single source of truth for the exact
/// reference arithmetic — the batched reference below and the layered
/// pipeline's software chain both call it, so they stay bit-identical
/// by construction.
pub fn software_vmm_single(
    w: &[f32],
    x: &[f32],
    rows: usize,
    cols: usize,
    acc: &mut [f64],
    out: &mut [f32],
) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(x.len(), rows);
    debug_assert_eq!(acc.len(), cols);
    debug_assert_eq!(out.len(), cols);
    acc.fill(0.0);
    for i in 0..rows {
        let xi = x[i] as f64;
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * cols..(i + 1) * cols];
        for j in 0..cols {
            acc[j] += xi * row[j] as f64;
        }
    }
    for j in 0..cols {
        out[j] = acc[j] as f32;
    }
}

/// Standalone batched software VMM in f64 accumulation.
pub fn software_vmm_batch(batch: &VmmBatch) -> Vec<f32> {
    let (b, r, c) = (batch.batch, batch.rows, batch.cols);
    let mut y = vec![0.0f32; b * c];
    let mut acc = vec![0.0f64; c];
    for s in 0..b {
        software_vmm_single(
            batch.w_of(s),
            batch.x_of(s),
            r,
            c,
            &mut acc,
            &mut y[s * c..(s + 1) * c],
        );
    }
    y
}

impl VmmEngine for SoftwareEngine {
    fn name(&self) -> &'static str {
        "software"
    }

    fn forward(&self, batch: &VmmBatch, _params: &DeviceParams) -> Result<VmmOutput> {
        batch.check()?;
        let y = software_vmm_batch(batch);
        Ok(VmmOutput { y_hw: y.clone(), y_sw: y })
    }

    fn program(&self, spec: &ProgramSpec, _params: &DeviceParams) -> Result<ProgrammedVmm> {
        spec.check()?;
        Ok(ProgrammedVmm::new(
            spec,
            ProgrammedExact {
                rows: spec.rows,
                cols: spec.cols,
                w: spec.w.clone(),
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn known_small_case() {
        let mut b = VmmBatch::zeros(1, 2, 2);
        // w = [[1, 2], [3, 4]], x = [1, 1] -> y = [4, 6]
        b.w.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.x.copy_from_slice(&[1.0, 1.0]);
        let y = software_vmm_batch(&b);
        assert_eq!(y, vec![4.0, 6.0]);
    }

    #[test]
    fn engine_has_zero_error() {
        let mut rng = Xoshiro256::seed_from_u64(131);
        let mut b = VmmBatch::zeros(4, 8, 8);
        rng.fill_uniform_f32(&mut b.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut b.x, -1.0, 1.0);
        let out = SoftwareEngine.forward(&b, &DeviceParams::ideal()).unwrap();
        assert!(out.errors().iter().all(|&e| e == 0.0));
    }

    #[test]
    fn batch_samples_independent() {
        let mut rng = Xoshiro256::seed_from_u64(132);
        let mut big = VmmBatch::zeros(3, 4, 4);
        rng.fill_uniform_f32(&mut big.w, -1.0, 1.0);
        rng.fill_uniform_f32(&mut big.x, -1.0, 1.0);
        let y_all = software_vmm_batch(&big);
        // Each sample alone gives the same answer.
        for s in 0..3 {
            let mut one = VmmBatch::zeros(1, 4, 4);
            one.w.copy_from_slice(big.w_of(s));
            one.x.copy_from_slice(big.x_of(s));
            let y = software_vmm_batch(&one);
            assert_eq!(&y_all[s * 4..(s + 1) * 4], &y[..]);
        }
    }
}
