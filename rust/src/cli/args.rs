//! Argument parsing for the `meliso` binary.
//!
//! ```text
//! meliso list
//! meliso devices
//! meliso run <experiment|all> [--engine native|tiled|sharded|xla|software]
//!            [--population N] [--seed N] [--out DIR] [--threads N]
//!            [--engine-threads N] [--size N] [--tile N] [--shards RxC]
//!            [--mitigation SPEC] [--config FILE] [--quiet]
//! meliso bench [--filter SUBSTR] [--baseline FILE] [--out DIR]
//! meliso fit --input FILE.csv [--column K]
//! meliso solve [--device ID] [--n N] [--solver cg|jacobi|richardson]
//!              [--mitigation SPEC]
//! meliso infer [--device ID] [--depth N] [--layers DIMS]
//!              [--activation A] [--mitigation SPEC] [--deploy]
//! meliso serve-bench [--device ID] [--clients N] [--requests N]
//!              [--models N] [--window-us N] [--batch-max N]
//!              [--queue-cap N] [--serve-workers N] [--serve-cache on|off]
//!              [--overload F]
//! meliso fleet-bench [--device ID] [--fleet-nodes N] [--replication N]
//!              [--fail-rate F] [--fail-seed N] [--transport in-process|socket]
//!              [+ serve-bench flags]
//! meliso metrics [--device ID]                     # telemetry snapshot demo
//! meliso warmup                                    # precompile artifacts
//! ```

use crate::config::{EngineKind, RunConfig};
use crate::error::{Error, Result};
use crate::mitigation::MitigationConfig;
use crate::pipeline::{parse_dims, Activation};
use crate::shard::parse_grid;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: Command,
    pub config: RunConfig,
}

/// Subcommands.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    List,
    Devices,
    Run { experiment: String },
    Bench {
        filter: Option<String>,
        baseline: Option<String>,
        delta_md: Option<String>,
    },
    Fit { input: String, column: usize },
    Solve { device: String, n: usize, solver: String },
    Infer { device: String },
    ServeBench { device: String },
    FleetBench { device: String },
    Metrics { device: String },
    Warmup,
    Help,
    Version,
}

pub const USAGE: &str = "\
meliso — MELISO-RS: VMM benchmarking framework for RRAM crossbars

USAGE:
  meliso <COMMAND> [OPTIONS]

COMMANDS:
  list                       List available experiments
  devices                    Print Table I device presets
  run <id|all|paper>         Run one experiment, or the full paper set
  bench                      Run the hotpath bench suite in quick mode and
                             write machine-readable <out>/BENCH.json
                             (e.g. `meliso bench --filter native --out perf`,
                             `meliso bench --baseline rust/benches/baseline.json`)
  fit --input F [--column K] Fit distributions to a CSV error column
  solve [--device ID] [--n N] [--solver S]
                             In-memory linear solve demo (cg|jacobi|richardson)
  infer [--device ID]        Layered inference: chain VMMs through a seeded
                             deep network and report per-layer error propagation
                             (e.g. `meliso infer --depth 4 --activation relu`,
                             `meliso infer --layers 32x48x10 --mitigation diff`)
  serve-bench [--device ID]  Concurrent request serving: simulated clients ->
                             bounded queue -> batched scheduler over the
                             programmed-crossbar cache; reports p50/p95/p99
                             latency, throughput, and cache hits, and writes
                             <out>/serve-bench/{summary,BENCH}.json
                             (e.g. `meliso serve-bench --clients 16 --models 4`);
                             with --overload F, first calibrates capacity
                             closed-loop, then offers F x capacity open-loop
                             with load shedding and reports goodput/shed rate
                             (e.g. `meliso serve-bench --overload 2`)
  fleet-bench [--device ID]  Node/router fleet serving: clients -> router
                             (consistent-hash placement, replication,
                             failure recovery) -> serialized frames -> N
                             serving nodes; reports per-node and fleet-wide
                             telemetry and writes
                             <out>/fleet-bench/{summary,BENCH}.json
                             (e.g. `meliso fleet-bench --fleet-nodes 3
                             --replication 2 --fail-rate 0.5`)
  metrics [--device ID]      Run a small instrumented serving workload and
                             print the unified telemetry snapshot (counter
                             table + per-stage latency breakdown); writes
                             <out>/metrics/METRICS.{json,melb}
  warmup                     Precompile all XLA artifacts
  help, version

OPTIONS:
  --engine <native|tiled|sharded|xla|software>
                                   Compute backend [default: native]
  --population <N>                 VMM samples per configuration [default: 1000]
  --seed <N>                       Workload seed
  --out <DIR>                      Output directory [default: out]
  --threads <N>                    Total worker budget (0 = auto)
  --engine-threads <N>             Engine-level fan-out for native/tiled/sharded
                                   (0 = auto, 1 = sequential engine)
  --size <N>                       Workload geometry (rows = cols)
                                   [default: 32]
  --tile <N>                       Physical tile size of the tiled engine
                                   [default: 32]
  --shards <RxC>                   Shard grid of the sharded engine
                                   [default: 2x2]
  --filter <SUBSTR>                bench: run only benchmarks whose name
                                   contains SUBSTR (errors if none match)
  --baseline <FILE>                bench: warn (never fail) when a median
                                   regresses >2x against this BENCH.json
  --delta-md <FILE>                bench: write an old-vs-new median delta
                                   table (GitHub markdown) against --baseline
  --mitigation <SPEC>              Error-mitigation pipeline, a comma list of
                                   diff | slice:K | avg:R | cal[:P]
                                   (e.g. diff,slice:2,avg:4) [default: none]
  --depth <N>                      Layers in a uniform-width inference network
                                   (width = --size) [default: 4]
  --layers <DIMS>                  Explicit layer dimension chain, e.g. 32x48x10
                                   (overrides --depth/--size)
  --activation <A>                 Per-layer nonlinearity:
                                   identity | relu | tanh | hardtanh
                                   [default: relu]
  --deploy                         infer: program each layer once through the
                                   serving cache (deployed-instance statistics)
                                   instead of per-sample reprogramming
  --clients <N>                    serve-bench: simulated client threads
                                   [default: 8]
  --requests <N>                   serve-bench: requests per client [default: 64]
  --models <N>                     serve-bench: distinct deployed models
                                   [default: 4]
  --window-us <N>                  serve-bench: batching window in microseconds
                                   (0 = serve whatever is queued) [default: 200]
  --batch-max <N>                  serve-bench: largest coalesced batch
                                   [default: 32]
  --queue-cap <N>                  serve-bench: bounded-queue capacity
                                   (backpressure bound) [default: 256]
  --serve-workers <N>              serve-bench: scheduler worker threads
                                   [default: 2]
  --serve-cache <on|off>           serve-bench: programmed-crossbar cache
                                   [default: on]
  --overload <F>                   serve-bench: offered load as a multiple of
                                   calibrated capacity (calibrate closed-loop,
                                   then pace arrivals at F x capacity with
                                   shedding; 0 = closed loop) [default: 0]
  --fleet-nodes <N>                fleet-bench: serving nodes behind the
                                   router [default: 2]
  --replication <N>                fleet-bench: replicas per model digest
                                   (clamped to the fleet size) [default: 1]
  --fail-rate <F>                  fleet-bench: failure-injection intensity
                                   in [0, 1] (0 = off) [default: 0]
  --fail-seed <N>                  fleet-bench: failure-point seed
  --transport <WIRE>               fleet-bench: 'in-process' channels or
                                   loopback 'socket' TCP (timeouts/retries via
                                   the [fleet] TOML keys) [default: in-process]
  --obs                            Enable the unified telemetry registry for
                                   the run: serve-bench/fleet-bench print a
                                   per-stage latency breakdown and write
                                   METRICS.{json,melb} next to their summaries
  --config <FILE>                  TOML config file (CLI flags override)
  --quiet                          Suppress terminal tables
";

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let cmd_word = it.next().unwrap_or_else(|| "help".to_string());

        // Collect flags first (subcommand-specific positionals handled
        // per command).
        let mut positionals: Vec<String> = Vec::new();
        let mut flags: Vec<(String, Option<String>)> = Vec::new();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let needs_value = !matches!(name, "quiet" | "deploy" | "obs");
                let value = if needs_value {
                    Some(it.next().ok_or_else(|| {
                        Error::Config(format!("flag --{name} needs a value"))
                    })?)
                } else {
                    None
                };
                flags.push((name.to_string(), value));
            } else {
                positionals.push(tok);
            }
        }

        // Start from --config file if given, then apply flag overrides.
        let mut config = RunConfig::default();
        if let Some((_, Some(path))) = flags.iter().find(|(n, _)| n == "config") {
            config = RunConfig::from_file(std::path::Path::new(path))?;
        }
        for (name, value) in &flags {
            let v = value.as_deref();
            match name.as_str() {
                "engine" => config.engine = EngineKind::parse(req(name, v)?)?,
                "population" => {
                    config.population = parse_num(name, req(name, v)?)?;
                    if config.population == 0 {
                        return Err(Error::Config("population must be > 0".into()));
                    }
                }
                "seed" => config.seed = parse_num::<u64>(name, req(name, v)?)?,
                "out" => config.out_dir = req(name, v)?.into(),
                "threads" => config.threads = parse_num(name, req(name, v)?)?,
                "engine-threads" => {
                    config.engine_threads = parse_num(name, req(name, v)?)?;
                }
                "size" => {
                    config.size = parse_num(name, req(name, v)?)?;
                    if config.size == 0 {
                        return Err(Error::Config("size must be > 0".into()));
                    }
                }
                "tile" => {
                    config.tile = parse_num(name, req(name, v)?)?;
                    if config.tile == 0 {
                        return Err(Error::Config("tile must be > 0".into()));
                    }
                }
                "shards" => {
                    let (r, c) = parse_grid(req(name, v)?)?;
                    config.shard.grid_r = r;
                    config.shard.grid_c = c;
                }
                "mitigation" => {
                    config.mitigation = MitigationConfig::parse(req(name, v)?)?;
                }
                "depth" => {
                    config.pipeline.depth = parse_num(name, req(name, v)?)?;
                    if config.pipeline.depth == 0 {
                        return Err(Error::Config("depth must be > 0".into()));
                    }
                }
                "activation" => {
                    config.pipeline.activation = Activation::parse(req(name, v)?)?;
                }
                "layers" => {
                    config.pipeline.dims = Some(parse_dims(req(name, v)?)?);
                }
                "quiet" => config.quiet = true,
                "deploy" => config.pipeline.deploy = true,
                "obs" => config.obs.enabled = true,
                "clients" => {
                    config.serve.clients = parse_positive(name, req(name, v)?)?;
                }
                "requests" => {
                    config.serve.requests = parse_positive(name, req(name, v)?)?;
                }
                "models" => {
                    config.serve.models = parse_positive(name, req(name, v)?)?;
                }
                "window-us" => {
                    config.serve.window_us = parse_num(name, req(name, v)?)?;
                }
                "batch-max" => {
                    config.serve.batch_max = parse_positive(name, req(name, v)?)?;
                }
                "queue-cap" => {
                    config.serve.queue = parse_positive(name, req(name, v)?)?;
                }
                "serve-workers" => {
                    config.serve.workers = parse_positive(name, req(name, v)?)?;
                }
                "serve-cache" => {
                    config.serve.cache = match req(name, v)?.to_ascii_lowercase().as_str() {
                        "on" | "true" | "1" => true,
                        "off" | "false" | "0" => false,
                        other => {
                            return Err(Error::Config(format!(
                                "--serve-cache must be on|off, got '{other}'"
                            )))
                        }
                    };
                }
                "overload" => {
                    let f: f64 = parse_num(name, req(name, v)?)?;
                    if !f.is_finite() || f < 0.0 {
                        return Err(Error::Config(
                            "--overload must be a non-negative factor".into(),
                        ));
                    }
                    config.overload.factor = f;
                }
                "fleet-nodes" => {
                    config.fleet.nodes = parse_positive(name, req(name, v)?)?;
                }
                "replication" => {
                    config.fleet.replication = parse_positive(name, req(name, v)?)?;
                }
                "fail-rate" => {
                    let r: f64 = parse_num(name, req(name, v)?)?;
                    if !(0.0..=1.0).contains(&r) {
                        return Err(Error::Config(
                            "--fail-rate must be in [0, 1]".into(),
                        ));
                    }
                    config.fleet.fail_rate = r;
                }
                "fail-seed" => {
                    config.fleet.fail_seed = parse_num::<u64>(name, req(name, v)?)?;
                }
                "transport" => {
                    config.fleet.transport =
                        crate::config::FleetTransport::parse(req(name, v)?)?;
                }
                "config" | "input" | "column" | "device" | "n" | "solver" | "filter"
                | "baseline" | "delta-md" => {}
                other => {
                    return Err(Error::Config(format!("unknown flag --{other}")));
                }
            }
        }

        let flag = |name: &str| -> Option<String> {
            flags
                .iter()
                .find(|(n, _)| n == name)
                .and_then(|(_, v)| v.clone())
        };

        let command = match cmd_word.as_str() {
            "list" => Command::List,
            "devices" => Command::Devices,
            "run" => Command::Run {
                experiment: positionals
                    .first()
                    .cloned()
                    .ok_or_else(|| Error::Config("run needs an experiment id".into()))?,
            },
            "bench" => Command::Bench {
                filter: flag("filter"),
                baseline: flag("baseline"),
                delta_md: flag("delta-md"),
            },
            "fit" => Command::Fit {
                input: flag("input")
                    .ok_or_else(|| Error::Config("fit needs --input FILE".into()))?,
                column: match flag("column") {
                    Some(c) => parse_num("column", &c)?,
                    None => 0,
                },
            },
            "solve" => Command::Solve {
                device: flag("device").unwrap_or_else(|| "epiram".into()),
                n: match flag("n") {
                    Some(c) => parse_num("n", &c)?,
                    None => 64,
                },
                solver: flag("solver").unwrap_or_else(|| "cg".into()),
            },
            "infer" => Command::Infer {
                device: flag("device").unwrap_or_else(|| "ag-si".into()),
            },
            "serve-bench" => Command::ServeBench {
                device: flag("device").unwrap_or_else(|| "ag-si".into()),
            },
            "fleet-bench" => Command::FleetBench {
                device: flag("device").unwrap_or_else(|| "ag-si".into()),
            },
            "metrics" => Command::Metrics {
                device: flag("device").unwrap_or_else(|| "ag-si".into()),
            },
            "warmup" => Command::Warmup,
            "help" | "--help" | "-h" => Command::Help,
            "version" | "--version" | "-V" => Command::Version,
            other => {
                return Err(Error::Config(format!(
                    "unknown command '{other}' (try `meliso help`)"
                )))
            }
        };
        Ok(Args { command, config })
    }
}

fn req<'a>(name: &str, v: Option<&'a str>) -> Result<&'a str> {
    v.ok_or_else(|| Error::Config(format!("flag --{name} needs a value")))
}

fn parse_num<T: std::str::FromStr>(name: &str, v: &str) -> Result<T> {
    v.parse()
        .map_err(|_| Error::Config(format!("flag --{name}: bad number '{v}'")))
}

fn parse_positive(name: &str, v: &str) -> Result<usize> {
    let n: usize = parse_num(name, v)?;
    if n == 0 {
        return Err(Error::Config(format!("flag --{name} must be > 0")));
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_run_with_flags() {
        let a = parse("run fig2a --engine software --population 50 --seed 9 --quiet")
            .unwrap();
        assert_eq!(a.command, Command::Run { experiment: "fig2a".into() });
        assert_eq!(a.config.engine, EngineKind::Software);
        assert_eq!(a.config.population, 50);
        assert_eq!(a.config.seed, 9);
        assert!(a.config.quiet);
    }

    #[test]
    fn parses_simple_commands() {
        assert_eq!(parse("list").unwrap().command, Command::List);
        assert_eq!(parse("devices").unwrap().command, Command::Devices);
        assert_eq!(parse("warmup").unwrap().command, Command::Warmup);
        assert_eq!(parse("help").unwrap().command, Command::Help);
        assert_eq!(parse("").unwrap().command, Command::Help);
    }

    #[test]
    fn fit_and_solve_flags() {
        let a = parse("fit --input errs.csv --column 2").unwrap();
        assert_eq!(a.command, Command::Fit { input: "errs.csv".into(), column: 2 });
        let a = parse("solve --device ag-si --n 96 --solver jacobi").unwrap();
        assert_eq!(
            a.command,
            Command::Solve { device: "ag-si".into(), n: 96, solver: "jacobi".into() }
        );
        // Defaults.
        let a = parse("solve").unwrap();
        assert_eq!(
            a.command,
            Command::Solve { device: "epiram".into(), n: 64, solver: "cg".into() }
        );
    }

    #[test]
    fn parses_tiled_flags() {
        let a = parse("run fig3 --engine tiled --size 128 --tile 64 --engine-threads 4")
            .unwrap();
        assert_eq!(a.config.engine, crate::config::EngineKind::Tiled);
        assert_eq!(a.config.size, 128);
        assert_eq!(a.config.tile, 64);
        assert_eq!(a.config.engine_threads, 4);
    }

    #[test]
    fn parses_sharded_flags() {
        let a = parse("run shard-sweep --engine sharded --shards 4x2").unwrap();
        assert_eq!(a.config.engine, crate::config::EngineKind::Sharded);
        assert_eq!((a.config.shard.grid_r, a.config.shard.grid_c), (4, 2));
        // Default grid without the flag.
        let a = parse("run shard-sweep --engine sharded").unwrap();
        assert_eq!((a.config.shard.grid_r, a.config.shard.grid_c), (2, 2));
        // Rejections.
        assert!(parse("run x --shards 4").is_err());
        assert!(parse("run x --shards 0x2").is_err());
        assert!(parse("run x --shards").is_err());
    }

    #[test]
    fn parses_bench_flags() {
        let a = parse("bench").unwrap();
        assert_eq!(
            a.command,
            Command::Bench { filter: None, baseline: None, delta_md: None }
        );
        let a = parse(
            "bench --filter native --baseline benches/baseline.json \
             --delta-md perf/delta.md --out perf",
        )
        .unwrap();
        assert_eq!(
            a.command,
            Command::Bench {
                filter: Some("native".into()),
                baseline: Some("benches/baseline.json".into()),
                delta_md: Some("perf/delta.md".into()),
            }
        );
        assert_eq!(a.config.out_dir, std::path::PathBuf::from("perf"));
        assert!(parse("bench --filter").is_err());
        assert!(parse("bench --delta-md").is_err());
    }

    #[test]
    fn unknown_engine_error_names_every_engine() {
        let msg = parse("run fig3 --engine warp").unwrap_err().to_string();
        for name in ["native", "tiled", "sharded", "xla", "software"] {
            assert!(msg.contains(name), "missing '{name}' in: {msg}");
        }
    }

    #[test]
    fn parses_mitigation_flag() {
        let a = parse("run mitigation-sweep --mitigation diff,slice:2,avg:4,cal").unwrap();
        assert!(a.config.mitigation.differential);
        assert_eq!(a.config.mitigation.slices, 2);
        assert_eq!(a.config.mitigation.replicas, 4);
        assert!(a.config.mitigation.calibrate);
        let a = parse("solve --mitigation avg:2").unwrap();
        assert_eq!(a.config.mitigation.replicas, 2);
        // Default is the identity pipeline.
        assert!(parse("run fig3").unwrap().config.mitigation.is_noop());
        assert!(parse("run fig3 --mitigation bogus").is_err());
        assert!(parse("run fig3 --mitigation").is_err());
    }

    #[test]
    fn parses_infer_flags() {
        let a = parse("infer --device epiram --depth 6 --activation tanh --population 32")
            .unwrap();
        assert_eq!(a.command, Command::Infer { device: "epiram".into() });
        assert_eq!(a.config.pipeline.depth, 6);
        assert_eq!(a.config.pipeline.activation, crate::pipeline::Activation::Tanh);
        assert_eq!(a.config.population, 32);
        // Explicit layer chain.
        let a = parse("infer --layers 32x48x10").unwrap();
        assert_eq!(a.config.pipeline.dims, Some(vec![32, 48, 10]));
        // Defaults.
        let a = parse("infer").unwrap();
        assert_eq!(a.command, Command::Infer { device: "ag-si".into() });
        assert_eq!(a.config.pipeline.depth, 4);
        assert!(a.config.pipeline.dims.is_none());
        // Rejections.
        assert!(parse("infer --depth 0").is_err());
        assert!(parse("infer --depth two").is_err());
        assert!(parse("infer --activation softmax").is_err());
        assert!(parse("infer --layers 32").is_err());
    }

    #[test]
    fn parses_serve_bench_flags() {
        let a = parse(
            "serve-bench --device epiram --clients 16 --requests 32 --models 3 \
             --window-us 0 --batch-max 8 --queue-cap 64 --serve-workers 4 \
             --serve-cache off --size 64",
        )
        .unwrap();
        assert_eq!(a.command, Command::ServeBench { device: "epiram".into() });
        assert_eq!(a.config.serve.clients, 16);
        assert_eq!(a.config.serve.requests, 32);
        assert_eq!(a.config.serve.models, 3);
        assert_eq!(a.config.serve.window_us, 0);
        assert_eq!(a.config.serve.batch_max, 8);
        assert_eq!(a.config.serve.queue, 64);
        assert_eq!(a.config.serve.workers, 4);
        assert!(!a.config.serve.cache);
        assert_eq!(a.config.size, 64);
        // Defaults.
        let a = parse("serve-bench").unwrap();
        assert_eq!(a.command, Command::ServeBench { device: "ag-si".into() });
        assert_eq!(a.config.serve.clients, 8);
        assert!(a.config.serve.cache);
        // Rejections.
        assert!(parse("serve-bench --clients 0").is_err());
        assert!(parse("serve-bench --batch-max 0").is_err());
        assert!(parse("serve-bench --serve-cache maybe").is_err());
        assert!(parse("serve-bench --window-us minus").is_err());
    }

    #[test]
    fn parses_overload_flag() {
        let a = parse("serve-bench --overload 2.5 --clients 4").unwrap();
        assert_eq!(a.config.overload.factor, 2.5);
        assert_eq!(a.config.serve.clients, 4);
        // Default: closed loop, no overload leg.
        assert_eq!(parse("serve-bench").unwrap().config.overload.factor, 0.0);
        // Rejections.
        assert!(parse("serve-bench --overload -1").is_err());
        assert!(parse("serve-bench --overload lots").is_err());
        assert!(parse("serve-bench --overload").is_err());
    }

    #[test]
    fn parses_fleet_bench_flags() {
        let a = parse(
            "fleet-bench --device epiram --fleet-nodes 3 --replication 2 \
             --fail-rate 0.5 --fail-seed 13 --transport socket --clients 6 --models 4",
        )
        .unwrap();
        assert_eq!(a.command, Command::FleetBench { device: "epiram".into() });
        assert_eq!(a.config.fleet.nodes, 3);
        assert_eq!(a.config.fleet.replication, 2);
        assert_eq!(a.config.fleet.fail_rate, 0.5);
        assert_eq!(a.config.fleet.fail_seed, 13);
        assert_eq!(
            a.config.fleet.transport,
            crate::config::FleetTransport::Socket
        );
        assert_eq!(a.config.serve.clients, 6);
        assert_eq!(a.config.serve.models, 4);
        // Defaults.
        let a = parse("fleet-bench").unwrap();
        assert_eq!(a.command, Command::FleetBench { device: "ag-si".into() });
        assert_eq!(a.config.fleet.nodes, 2);
        assert_eq!(a.config.fleet.replication, 1);
        assert_eq!(a.config.fleet.fail_rate, 0.0);
        assert_eq!(
            a.config.fleet.transport,
            crate::config::FleetTransport::InProcess
        );
        assert!(parse("fleet-bench --transport avian").is_err());
        // Rejections.
        assert!(parse("fleet-bench --fleet-nodes 0").is_err());
        assert!(parse("fleet-bench --replication 0").is_err());
        assert!(parse("fleet-bench --fail-rate 1.5").is_err());
        assert!(parse("fleet-bench --fail-rate often").is_err());
    }

    #[test]
    fn parses_metrics_and_obs_flag() {
        let a = parse("metrics").unwrap();
        assert_eq!(a.command, Command::Metrics { device: "ag-si".into() });
        assert!(!a.config.obs.enabled, "metrics enables obs itself at run time");
        let a = parse("metrics --device epiram --out tele").unwrap();
        assert_eq!(a.command, Command::Metrics { device: "epiram".into() });
        assert_eq!(a.config.out_dir, std::path::PathBuf::from("tele"));
        // --obs is a boolean flag on any command.
        let a = parse("serve-bench --obs --clients 2").unwrap();
        assert!(a.config.obs.enabled);
        assert_eq!(a.config.serve.clients, 2);
        let a = parse("fleet-bench --obs").unwrap();
        assert!(a.config.obs.enabled);
        assert!(!parse("serve-bench").unwrap().config.obs.enabled);
    }

    #[test]
    fn parses_deploy_flag() {
        let a = parse("infer --deploy --depth 3").unwrap();
        assert!(a.config.pipeline.deploy);
        assert_eq!(a.config.pipeline.depth, 3);
        assert!(!parse("infer").unwrap().config.pipeline.deploy);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse("run").is_err());
        assert!(parse("frobnicate").is_err());
        assert!(parse("run fig3 --engine warp").is_err());
        assert!(parse("run fig3 --population zero").is_err());
        assert!(parse("run fig3 --population 0").is_err());
        assert!(parse("run fig3 --size 0").is_err());
        assert!(parse("bench --tile 0").is_err());
        assert!(parse("fit").is_err());
        assert!(parse("run fig3 --bogus 1").is_err());
        assert!(parse("run fig3 --engine").is_err());
    }
}
