//! Subcommand implementations.


use crate::device::params::NonIdealities;
use crate::device::presets;
use crate::error::{Error, Result};
use crate::experiments::{registry, Ctx};
use crate::obs::{self, CounterId, GaugeId, MetricsSnapshot, Stage};
use crate::perf;
use crate::pipeline::{NetworkSpec, PipelineOptions, PipelineRunner};
use crate::report::table::{fnum, TextTable};
use crate::runtime::XlaRuntime;
use crate::serve::{
    run_fleet, run_serve, FleetOptions, ProgramCache, ServeOptions, SocketOptions, Transport,
};
use crate::util::bench::{read_bench_json, write_bench_json, BenchResult};
use crate::util::csv::CsvTable;
use crate::util::json::{obj, Json};
use crate::solver::{
    conjugate_gradient, jacobi, richardson, CrossbarOperator, ExactOperator,
    SolveOpts,
};
use crate::util::progress::Stopwatch;
use crate::util::rng::Xoshiro256;

use super::args::{Args, Command, USAGE};

/// Execute a parsed command; returns the process exit code.
pub fn dispatch(args: &Args) -> Result<i32> {
    match &args.command {
        Command::Help => {
            println!("{USAGE}");
            Ok(0)
        }
        Command::Version => {
            println!("meliso {}", crate::VERSION);
            Ok(0)
        }
        Command::List => {
            let mut t = TextTable::new(["id", "set", "title"]).with_title("Experiments");
            for (id, title, paper) in registry::describe() {
                t.push([id, if paper { "paper" } else { "extension" }, title]);
            }
            println!("{}", t.render());
            Ok(0)
        }
        Command::Devices => {
            let ctx = Ctx::from_config(&args.config)?;
            crate::experiments::table1::run(&ctx)?;
            Ok(0)
        }
        Command::Run { experiment } => run_experiments(args, experiment),
        Command::Bench { filter, baseline, delta_md } => {
            bench(args, filter, baseline, delta_md)
        }
        Command::Fit { input, column } => fit_csv(input, *column),
        Command::Solve { device, n, solver } => solve(args, device, *n, solver),
        Command::Infer { device } => infer(args, device),
        Command::ServeBench { device } => serve_bench(args, device),
        Command::FleetBench { device } => fleet_bench(args, device),
        Command::Metrics { device } => metrics(args, device),
        Command::Warmup => warmup(),
    }
}

fn run_experiments(args: &Args, which: &str) -> Result<i32> {
    let ctx = Ctx::from_config(&args.config)?;
    let ids: Vec<String> = match which {
        "all" => registry::all_ids().iter().map(|s| s.to_string()).collect(),
        "paper" => registry::paper_ids().iter().map(|s| s.to_string()).collect(),
        one => vec![one.to_string()],
    };
    let sw = Stopwatch::start();
    for id in &ids {
        if !args.config.quiet {
            eprintln!("== running {id} (engine={}, population={}) ==",
                ctx.engine_name(), ctx.population);
        }
        registry::run_by_id(id, &ctx)?;
    }
    if !args.config.quiet {
        eprintln!("done: {} experiment(s) in {}", ids.len(), sw.pretty());
    }
    Ok(0)
}

/// `meliso bench`: run the hotpath suite in quick mode, write
/// machine-readable `<out>/BENCH.json` (plus a binary `BENCH.melb`
/// twin — same document, codec framing), and (with `--baseline`)
/// soft-gate medians against a committed baseline document — warnings
/// only, never a failing exit, because absolute timings are machine
/// dependent.  `--delta-md FILE` additionally writes the full
/// old-vs-new median table as GitHub markdown (the `perf-smoke` job
/// appends it to `$GITHUB_STEP_SUMMARY`).  An unmatched `--filter` is
/// an error: an empty `BENCH.json` would read as "no regressions" in
/// CI.
fn bench(
    args: &Args,
    filter: &Option<String>,
    baseline: &Option<String>,
    delta_md: &Option<String>,
) -> Result<i32> {
    if delta_md.is_some() && baseline.is_none() {
        return Err(Error::Config(
            "--delta-md needs --baseline to diff against".into(),
        ));
    }
    // The pre-BENCH.json `bench` took workload/engine flags; the suite
    // pins its own workloads, so a caller still passing any of them
    // must hear that they no longer steer the measurement.
    let defaults = crate::config::RunConfig::default();
    let stale_flags = args.config.engine != defaults.engine
        || args.config.size != defaults.size
        || args.config.population != defaults.population
        || args.config.tile != defaults.tile
        || args.config.threads != defaults.threads
        || args.config.engine_threads != defaults.engine_threads
        || args.config.seed != defaults.seed
        || args.config.shard != defaults.shard
        || !args.config.mitigation.is_noop();
    if stale_flags && !args.config.quiet {
        eprintln!(
            "note: `meliso bench` runs the fixed hotpath suite; workload and \
             engine flags (--engine/--size/--population/--tile/--threads/\
             --engine-threads/--seed/--shards/--mitigation) do not affect it \
             (use --filter to select benchmarks, `meliso run` to measure a \
             specific configuration)"
        );
    }
    let results = perf::run_suite(&perf::SuiteOpts { quick: true, filter: filter.clone() });
    if results.is_empty() {
        return Err(Error::Config(format!(
            "--filter '{}' matched no benchmarks (run `meliso bench` without \
             --filter and check the names in BENCH.json)",
            filter.as_deref().unwrap_or("")
        )));
    }
    let path = args.config.out_dir.join("BENCH.json");
    write_bench_json(&results, &path)?;
    write_bench_json(&results, &args.config.out_dir.join("BENCH.melb"))?;
    if !args.config.quiet {
        eprintln!(
            "wrote {} bench results to {} (+ binary twin BENCH.melb)",
            results.len(),
            path.display()
        );
    }
    if let Some(baseline_path) = baseline {
        let base = read_bench_json(std::path::Path::new(baseline_path))?;
        let regressions = perf::compare_to_baseline(&results, &base, 2.0);
        for r in &regressions {
            // `::warning::` renders as an annotation on GitHub Actions
            // and is harmless plain text everywhere else.
            println!(
                "::warning::bench '{}' median regressed {:.2}x vs baseline \
                 ({:.6}s -> {:.6}s)",
                r.name, r.ratio, r.baseline_median, r.current_median
            );
        }
        if regressions.is_empty() && !args.config.quiet {
            eprintln!(
                "no >2x median regressions against {baseline_path} \
                 ({} comparable benchmarks)",
                results.len()
            );
        }
        if let Some(md_path) = delta_md {
            let md_path = std::path::Path::new(md_path);
            if let Some(parent) = md_path.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            std::fs::write(md_path, perf::delta_table_md(&results, &base))?;
            if !args.config.quiet {
                eprintln!("wrote median delta table to {}", md_path.display());
            }
        }
    }
    Ok(0)
}

fn fit_csv(input: &str, column: usize) -> Result<i32> {
    let text = std::fs::read_to_string(input)?;
    let mut data = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 && line.parse::<f64>().is_err() && !line.contains(|c: char| c.is_ascii_digit())
        {
            continue; // header
        }
        let cell = line.split(',').nth(column).ok_or_else(|| {
            Error::Config(format!("line {} has no column {column}", i + 1))
        })?;
        match cell.trim().parse::<f64>() {
            Ok(v) => data.push(v),
            Err(_) if i == 0 => continue, // header row
            Err(e) => {
                return Err(Error::Parse(format!("line {}: {e}", i + 1)));
            }
        }
    }
    let reports = crate::stats::fit::fit_all(&data)?;
    let mut t = TextTable::new(["family", "loglik", "AIC", "BIC", "KS", "params"])
        .with_title(format!("Distribution fits for {input} ({} samples)", data.len()));
    for r in &reports {
        t.push([
            r.model.name(),
            fnum(r.loglik),
            fnum(r.aic),
            fnum(r.bic),
            fnum(r.ks),
            r.model.params_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(0)
}

fn solve(args: &Args, device_id: &str, n: usize, solver: &str) -> Result<i32> {
    let preset = presets::by_id(device_id)
        .ok_or_else(|| Error::Config(format!("unknown device '{device_id}'")))?;
    let device = preset.params.masked(NonIdealities::FULL);
    let mut rng = Xoshiro256::seed_from_u64(args.config.seed);

    // SPD system A = M^T M / n + I.
    let m: Vec<f64> = (0..n * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let mut a = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += m[k * n + i] * m[k * n + j];
            }
            a[i * n + j] = s / n as f64 + if i == j { 1.0 } else { 0.0 };
        }
    }
    let b: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let exact = ExactOperator::new(n, n, a.clone());
    let op = CrossbarOperator::program_mitigated(
        n,
        n,
        &a,
        &device,
        &mut rng,
        &args.config.mitigation,
    );
    let opts = SolveOpts { max_iters: 300, tol: 1e-8 };

    let result = match solver {
        "cg" => conjugate_gradient(&op, &exact, &b, &opts)?,
        "jacobi" => {
            let diag: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
            jacobi(&op, &exact, &diag, &b, &opts)?
        }
        "richardson" => richardson(&op, &exact, &b, 0.3, &opts)?,
        other => {
            return Err(Error::Config(format!(
                "unknown solver '{other}' (cg|jacobi|richardson)"
            )))
        }
    };

    let mut t = TextTable::new(["metric", "value"])
        .with_title(format!("In-memory {solver} on {}x{n} ({})", n, preset.name));
    t.push(["mitigation", &args.config.mitigation.label()]);
    t.push(["crossbar arrays", &op.array_count().to_string()]);
    t.push(["iterations", &result.iterations.to_string()]);
    t.push(["converged", &result.converged.to_string()]);
    t.push([
        "final rel. residual",
        &fnum(*result.residual_history.last().unwrap_or(&f64::NAN)),
    ]);
    t.push([
        "best rel. residual",
        &fnum(result
            .residual_history
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)),
    ]);
    println!("{}", t.render());
    Ok(0)
}

/// `meliso infer`: run a seeded deep network through the crossbar
/// chain and report per-layer error propagation (CSV + JSON under
/// `<out>/infer/`).
fn infer(args: &Args, device_id: &str) -> Result<i32> {
    let ctx = Ctx::from_config(&args.config)?;
    let (device, device_label) = match args.config.custom_device {
        Some(d) => (d, "custom".to_string()),
        None => {
            let preset = presets::by_id(device_id)
                .ok_or_else(|| Error::Config(format!("unknown device '{device_id}'")))?;
            (preset.params.masked(NonIdealities::FULL), preset.id.to_string())
        }
    };
    let p = &args.config.pipeline;
    let dims = match &p.dims {
        Some(d) => d.clone(),
        None => vec![args.config.size; p.depth + 1],
    };
    let mut net = NetworkSpec::from_dims(&dims, p.activation, args.config.seed)?
        .with_population(args.config.population);
    if !args.config.mitigation.is_noop() {
        net = net.with_mitigation(args.config.mitigation);
    }
    // Per-layer mitigation lives in the network spec, so the runner
    // gets the *unwrapped* engine — a globally mitigated engine would
    // run every layer through the pipeline twice.
    let runner = PipelineRunner::new(ctx.base_engine.clone());
    // Deployed mode: program each layer once through the serving cache
    // and read every sample against that instance (the cache outlives
    // this run, so repeated `runner.run` calls in one process share
    // layer programs).
    let deploy_cache = args
        .config
        .pipeline
        .deploy
        .then(|| std::sync::Arc::new(ProgramCache::new(64)));
    let opts = PipelineOptions {
        chunk: 64,
        parallelism: ctx.parallelism,
        deploy: deploy_cache.clone(),
    };
    let report = runner.run(&net, &device, &opts)?;

    let mut t = TextTable::new([
        "layer", "shape", "activation", "mitigation", "injected |e|", "accum |e|", "accum std",
    ])
    .with_title(format!(
        "Layered inference: {} on {} ({} samples, engine={})",
        net.dims_label(),
        device_label,
        report.samples,
        report.engine,
    ));
    let mut csv = CsvTable::new([
        "layer",
        "rows",
        "cols",
        "activation",
        "mitigation",
        "requant",
        "injected_mean_abs",
        "injected_var",
        "accum_mean_abs",
        "accum_var",
    ]);
    let mut layer_rows = Vec::new();
    for l in &report.layers {
        t.push([
            (l.index + 1).to_string(),
            format!("{}x{}", l.rows, l.cols),
            l.activation.to_string(),
            l.mitigation.clone(),
            fnum(l.injected_mean_abs()),
            fnum(l.accumulated_mean_abs()),
            fnum(l.accumulated.stats().std_dev()),
        ]);
        csv.push([
            (l.index + 1).to_string(),
            l.rows.to_string(),
            l.cols.to_string(),
            l.activation.to_string(),
            l.mitigation.clone(),
            l.requant.to_string(),
            l.injected_mean_abs().to_string(),
            l.injected.stats().variance().to_string(),
            l.accumulated_mean_abs().to_string(),
            l.accumulated.stats().variance().to_string(),
        ]);
        layer_rows.push(obj([
            ("layer", Json::Num((l.index + 1) as f64)),
            ("rows", Json::Num(l.rows as f64)),
            ("cols", Json::Num(l.cols as f64)),
            ("activation", Json::Str(l.activation.to_string())),
            ("mitigation", Json::Str(l.mitigation.clone())),
            ("injected_mean_abs", Json::Num(l.injected_mean_abs())),
            ("accum_mean_abs", Json::Num(l.accumulated_mean_abs())),
            ("accum_var", Json::Num(l.accumulated.stats().variance())),
        ]));
    }
    let w = ctx.writer("infer");
    w.echo(&t.render());
    w.echo(&format!(
        "argmax agreement: {:.3}   end-to-end mean |e|: {}   {:.0} VMM/s",
        report.argmax_agreement,
        fnum(report.layers.last().map(|l| l.accumulated_mean_abs()).unwrap_or(f64::NAN)),
        report.vmm_per_sec(),
    ));
    if let Some(cache) = &deploy_cache {
        let c = cache.counts();
        w.echo(&format!(
            "deployed: {} layer programs cached ({} hits, {} misses)",
            c.entries, c.hits, c.misses
        ));
    }
    w.csv("layers", &csv)?;
    w.json(
        "summary",
        &obj([
            ("id", Json::Str("infer".into())),
            ("network", Json::Str(net.dims_label())),
            ("activation", Json::Str(p.activation.name().into())),
            ("device", Json::Str(device_label)),
            ("engine", Json::Str(report.engine.into())),
            ("mitigation", Json::Str(args.config.mitigation.label())),
            ("deployed", Json::Bool(args.config.pipeline.deploy)),
            ("samples", Json::Num(report.samples as f64)),
            ("argmax_agreement", Json::Num(report.argmax_agreement)),
            ("wall_secs", Json::Num(report.wall_secs)),
            ("vmm_per_s", Json::Num(report.vmm_per_sec())),
            ("layers", Json::Arr(layer_rows)),
        ]),
    )?;
    Ok(0)
}

/// RAII capture of the global metrics registry for one instrumented
/// command run: reset + enable on construction, disable + reset on
/// drop (so an error path never leaks an enabled gate into later
/// work), [`ObsCapture::finish`] to take the snapshot.  Holds the
/// registry serialization lock for the duration — uncontended in the
/// CLI binary, and inside the library's test binary it keeps
/// dispatch-level tests from interleaving with other gate-flipping
/// tests (which is also why those tests must *not* take
/// `obs::test_lock` themselves around `dispatch`).
struct ObsCapture {
    _guard: std::sync::MutexGuard<'static, ()>,
}

impl ObsCapture {
    fn start() -> Self {
        let guard = obs::test_lock();
        obs::registry().reset();
        obs::set_enabled(true);
        Self { _guard: guard }
    }

    /// Stop collection and return everything recorded since `start`.
    fn finish(self) -> MetricsSnapshot {
        obs::set_enabled(false);
        obs::registry().snapshot()
    }
}

impl Drop for ObsCapture {
    fn drop(&mut self) {
        obs::set_enabled(false);
        obs::registry().reset();
    }
}

/// Render the per-stage latency breakdown from a metrics snapshot:
/// count, exact mean, bucketed p50/p95/p99 (log2 semantics, DESIGN.md
/// §17), exact total, and each stage's share of all recorded stage
/// time.  Empty stages are omitted — a serve run has no transport hop,
/// a fleet run no pipeline layers.
fn stage_breakdown_table(snap: &MetricsSnapshot) -> TextTable {
    let total = snap.stage_sum_ns() as f64;
    let mut t = TextTable::new([
        "stage", "count", "mean ms", "p50 ms", "p95 ms", "p99 ms", "total ms", "share",
    ])
    .with_title("Per-stage latency breakdown");
    for stage in Stage::ALL {
        let h = snap.stage(stage);
        if h.is_empty() {
            continue;
        }
        t.push([
            stage.name().to_string(),
            h.count.to_string(),
            fnum(h.mean_ns() / 1e6),
            fnum(h.percentile_ms(50.0)),
            fnum(h.percentile_ms(95.0)),
            fnum(h.percentile_ms(99.0)),
            fnum(h.sum as f64 / 1e6),
            format!("{:.1}%", 100.0 * h.sum as f64 / total),
        ]);
    }
    t
}

/// Write a snapshot in both artifact framings next to a command's
/// other outputs: pretty `METRICS.json` plus the single-frame MELB
/// twin under the metrics envelope tag.
fn write_metrics_artifacts(snap: &MetricsSnapshot, dir: &std::path::Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join("METRICS.json"), snap.to_json().to_string_pretty())?;
    std::fs::write(dir.join("METRICS.melb"), snap.encode_melb()?)?;
    Ok(())
}

/// `meliso metrics`: run a small instrumented serving workload and
/// print the unified telemetry snapshot — every registry counter and
/// gauge plus the per-stage latency breakdown — then export it through
/// the artifact codec as `<out>/metrics/METRICS.{json,melb}`.  One
/// command exercises queue-wait, coalesce, cache lookup, program, and
/// read, so CI can smoke the whole observability spine.
fn metrics(args: &Args, device_id: &str) -> Result<i32> {
    let ctx = Ctx::from_config(&args.config)?;
    let (device, device_label) = match args.config.custom_device {
        Some(d) => (d, "custom".to_string()),
        None => {
            let preset = presets::by_id(device_id)
                .ok_or_else(|| Error::Config(format!("unknown device '{device_id}'")))?;
            (preset.params.masked(NonIdealities::FULL), preset.id.to_string())
        }
    };
    // A pinned small workload (not the serve flags): the command is a
    // telemetry smoke, so its cost must stay trivial and its counter
    // deltas predictable.
    let opts = ServeOptions {
        clients: 4,
        requests_per_client: 16,
        models: 3,
        rows: 32,
        cols: 32,
        queue_capacity: 16,
        batch_max: 8,
        window: std::time::Duration::from_micros(200),
        workers: 2,
        cache: true,
        cache_capacity: 8,
        measure_error: true,
        seed: args.config.seed,
        ..ServeOptions::default()
    };
    let capture = ObsCapture::start();
    let report = run_serve(&ctx.engine, &device, &opts)?;
    let snap = capture.finish();

    let w = ctx.writer("metrics");
    let mut t = TextTable::new(["metric", "value"]).with_title(format!(
        "Telemetry counters: {} models of {}x{} on {} (engine={})",
        opts.models,
        opts.rows,
        opts.cols,
        device_label,
        ctx.engine_name(),
    ));
    for id in CounterId::ALL {
        t.push([id.name().to_string(), snap.counter(id).to_string()]);
    }
    for id in GaugeId::ALL {
        t.push([format!("{} (gauge)", id.name()), snap.gauge(id).to_string()]);
    }
    w.echo(&t.render());
    w.echo(&stage_breakdown_table(&snap).render());
    w.echo(&format!(
        "end-to-end: {} requests in {:.3}s ({:.0} req/s); stage-accounted {:.3}s",
        report.requests,
        report.wall_secs,
        report.throughput,
        snap.stage_sum_ns() as f64 / 1e9,
    ));
    write_metrics_artifacts(&snap, w.dir())?;
    if !args.config.quiet {
        eprintln!(
            "wrote telemetry snapshot to {}/METRICS.json (+ binary twin METRICS.melb)",
            w.dir().display()
        );
    }
    Ok(0)
}

/// `meliso serve-bench`: run the request-serving simulation (simulated
/// clients -> bounded queue -> batched scheduler over the programmed-
/// crossbar cache) on the configured engine and report latency,
/// throughput, cache, and error telemetry.  Writes
/// `<out>/serve-bench/summary.json` and a bench-schema
/// `<out>/serve-bench/BENCH.json` (its own directory, so sharing
/// `--out` with `meliso bench` never clobbers the hotpath document)
/// for CI to archive next to the hotpath suite's.
fn serve_bench(args: &Args, device_id: &str) -> Result<i32> {
    let ctx = Ctx::from_config(&args.config)?;
    let (device, device_label) = match args.config.custom_device {
        Some(d) => (d, "custom".to_string()),
        None => {
            let preset = presets::by_id(device_id)
                .ok_or_else(|| Error::Config(format!("unknown device '{device_id}'")))?;
            (preset.params.masked(NonIdealities::FULL), preset.id.to_string())
        }
    };
    let s = &args.config.serve;
    let opts = ServeOptions {
        clients: s.clients,
        requests_per_client: s.requests,
        models: s.models,
        rows: args.config.size,
        cols: args.config.size,
        queue_capacity: s.queue,
        batch_max: s.batch_max,
        window: std::time::Duration::from_micros(s.window_us),
        workers: s.workers,
        cache: s.cache,
        cache_capacity: s.cache_capacity,
        measure_error: true,
        seed: args.config.seed,
        ..ServeOptions::default()
    };
    // `--obs`: bracket the run with the registry capture so the
    // exported snapshot holds exactly this run's activity.
    let capture = args.config.obs.enabled.then(ObsCapture::start);
    let report = run_serve(&ctx.engine, &device, &opts)?;
    let telemetry = capture.map(ObsCapture::finish);

    // `--overload F`: the closed-loop run above doubles as the
    // calibration leg — its fitted rate is the capacity estimate.  The
    // overload leg then offers F x capacity open-loop with shedding
    // (plus the `[overload]` deadline, if any) and reports goodput and
    // shed rate alongside the base summary.
    let ov = &args.config.overload;
    let overload = if ov.factor > 0.0 {
        let capacity = if report.fitted_rps.is_finite() && report.fitted_rps > 0.0 {
            report.fitted_rps
        } else {
            report.throughput
        };
        let offered_rps = ov.factor * capacity;
        let oopts = ServeOptions {
            arrival_rps: Some(offered_rps),
            shed_on_full: true,
            deadline: (ov.deadline_us > 0)
                .then(|| std::time::Duration::from_micros(ov.deadline_us)),
            measure_error: false,
            ..opts.clone()
        };
        let oreport = run_serve(&ctx.engine, &device, &oopts)?;
        Some((oreport, offered_rps))
    } else {
        None
    };

    let mut t = TextTable::new(["metric", "value"]).with_title(format!(
        "Request serving: {} models of {}x{} on {} (engine={}, cache={})",
        opts.models,
        opts.rows,
        opts.cols,
        device_label,
        ctx.engine_name(),
        if opts.cache { "on" } else { "off" },
    ));
    t.push(["clients x requests", &format!("{} x {}", opts.clients, opts.requests_per_client)]);
    t.push(["requests served", &report.requests.to_string()]);
    t.push(["throughput (req/s)", &fnum(report.throughput)]);
    t.push(["p50 latency (ms)", &fnum(report.p50_ms)]);
    t.push(["p95 latency (ms)", &fnum(report.p95_ms)]);
    t.push(["p99 latency (ms)", &fnum(report.p99_ms)]);
    t.push(["mean batch", &fnum(report.mean_batch)]);
    t.push(["batches", &report.batches.to_string()]);
    t.push(["programs", &report.programs.to_string()]);
    t.push([
        "cache hits/misses",
        &format!("{}/{}", report.cache.hits, report.cache.misses),
    ]);
    t.push(["mean |e|", &fnum(report.mean_abs_error)]);
    t.push(["fitted rate (req/s)", &fnum(report.fitted_rps)]);
    t.push([
        "nodes @ 1e8 req/day",
        &report.nodes_for_1e8_per_day.to_string(),
    ]);
    let w = ctx.writer("serve-bench");
    w.echo(&t.render());
    if let Some(snap) = &telemetry {
        w.echo(&stage_breakdown_table(snap).render());
        write_metrics_artifacts(snap, w.dir())?;
    }
    if let Some((o, offered_rps)) = &overload {
        let shed_rate = o.shed as f64 / o.offered.max(1) as f64;
        let mut ot = TextTable::new(["metric", "value"]).with_title(format!(
            "Overload leg: {:.2}x capacity ({:.0} req/s offered)",
            ov.factor, offered_rps,
        ));
        ot.push(["offered", &o.offered.to_string()]);
        ot.push(["served (goodput)", &o.requests.to_string()]);
        ot.push(["shed", &o.shed.to_string()]);
        ot.push(["shed rate", &format!("{shed_rate:.3}")]);
        ot.push(["goodput (req/s)", &fnum(o.throughput)]);
        ot.push(["p99 latency (ms)", &fnum(o.p99_ms)]);
        w.echo(&ot.render());
    }
    let mut summary = vec![
        ("id", Json::Str("serve-bench".into())),
        ("engine", Json::Str(ctx.engine_name().into())),
        ("device", Json::Str(device_label)),
        ("rows", Json::Num(opts.rows as f64)),
        ("cols", Json::Num(opts.cols as f64)),
        ("clients", Json::Num(opts.clients as f64)),
        ("requests_per_client", Json::Num(opts.requests_per_client as f64)),
        ("models", Json::Num(opts.models as f64)),
        ("window_us", Json::Num(s.window_us as f64)),
        ("batch_max", Json::Num(opts.batch_max as f64)),
        ("queue_capacity", Json::Num(opts.queue_capacity as f64)),
        ("workers", Json::Num(opts.workers as f64)),
        ("cache", Json::Bool(opts.cache)),
        ("requests", Json::Num(report.requests as f64)),
        ("batches", Json::Num(report.batches as f64)),
        ("mean_batch", Json::Num(report.mean_batch)),
        ("wall_secs", Json::Num(report.wall_secs)),
        ("throughput_req_s", Json::Num(report.throughput)),
        ("p50_ms", Json::Num(report.p50_ms)),
        ("p95_ms", Json::Num(report.p95_ms)),
        ("p99_ms", Json::Num(report.p99_ms)),
        ("programs", Json::Num(report.programs as f64)),
        ("cache_hits", Json::Num(report.cache.hits as f64)),
        ("cache_misses", Json::Num(report.cache.misses as f64)),
        ("cache_evictions", Json::Num(report.cache.evictions as f64)),
        ("mean_abs_error", Json::Num(report.mean_abs_error)),
        ("fitted_req_s", Json::Num(report.fitted_rps)),
        (
            "nodes_for_1e8_per_day",
            Json::Num(report.nodes_for_1e8_per_day as f64),
        ),
    ];
    if let Some((o, offered_rps)) = &overload {
        summary.extend([
            ("overload_factor", Json::Num(ov.factor)),
            ("overload_offered_req_s", Json::Num(*offered_rps)),
            ("overload_offered", Json::Num(o.offered as f64)),
            ("overload_served", Json::Num(o.requests as f64)),
            ("overload_shed", Json::Num(o.shed as f64)),
            (
                "overload_shed_rate",
                Json::Num(o.shed as f64 / o.offered.max(1) as f64),
            ),
            ("overload_goodput_req_s", Json::Num(o.throughput)),
            ("overload_p99_ms", Json::Num(o.p99_ms)),
        ]);
    }
    w.json("summary", &obj(summary))?;
    w.echo(&format!(
        "capacity: at 1e8 requests/day this fabric needs {} node(s) \
         (fitted {:.0} req/s/node)",
        report.nodes_for_1e8_per_day, report.fitted_rps,
    ));
    // Bench-schema document for CI artifact upload, named like a perf
    // slug so baselines can track it.
    let slug = format!(
        "serve-bench-{}-{}",
        ctx.engine_name(),
        if opts.cache { "cached" } else { "uncached" }
    );
    let bench = vec![BenchResult {
        name: slug,
        median: report.wall_secs,
        mean: report.wall_secs,
        min: report.wall_secs,
        max: report.wall_secs,
        samples: 1,
        items_per_iter: Some(report.requests as f64),
    }];
    write_bench_json(&bench, &args.config.out_dir.join("serve-bench/BENCH.json"))?;
    Ok(0)
}

/// `meliso fleet-bench`: run the node/router fleet simulation (clients
/// -> consistent-hash router -> serialized frames -> N serving nodes,
/// each with its own programmed-crossbar cache, queue, and worker
/// pool) and report fleet-wide plus per-node telemetry.  Writes
/// `<out>/fleet-bench/summary.json` and a bench-schema
/// `<out>/fleet-bench/{BENCH.json,BENCH.melb}` for CI to archive next
/// to the serve-bench documents.
fn fleet_bench(args: &Args, device_id: &str) -> Result<i32> {
    let ctx = Ctx::from_config(&args.config)?;
    let (device, device_label) = match args.config.custom_device {
        Some(d) => (d, "custom".to_string()),
        None => {
            let preset = presets::by_id(device_id)
                .ok_or_else(|| Error::Config(format!("unknown device '{device_id}'")))?;
            (preset.params.masked(NonIdealities::FULL), preset.id.to_string())
        }
    };
    let s = &args.config.serve;
    let f = &args.config.fleet;
    let transport = match f.transport {
        crate::config::FleetTransport::InProcess => Transport::InProcess,
        crate::config::FleetTransport::Socket => Transport::Socket(SocketOptions {
            connect_timeout: std::time::Duration::from_millis(f.connect_timeout_ms),
            read_timeout: std::time::Duration::from_millis(f.read_timeout_ms),
            retries: f.retries,
        }),
    };
    let opts = FleetOptions {
        serve: ServeOptions {
            clients: s.clients,
            requests_per_client: s.requests,
            models: s.models,
            rows: args.config.size,
            cols: args.config.size,
            queue_capacity: s.queue,
            batch_max: s.batch_max,
            window: std::time::Duration::from_micros(s.window_us),
            workers: s.workers,
            cache: s.cache,
            cache_capacity: s.cache_capacity,
            measure_error: true,
            seed: args.config.seed,
            ..ServeOptions::default()
        },
        nodes: f.nodes,
        replication: f.replication,
        fail_rate: f.fail_rate,
        fail_seed: f.fail_seed,
        collect_responses: false,
        transport,
    };
    // `--obs`: the fleet path additionally exercises the transport
    // encode/decode stages, so its breakdown shows the full taxonomy.
    let capture = args.config.obs.enabled.then(ObsCapture::start);
    let report = run_fleet(&ctx.engine, &device, &opts)?;
    let telemetry = capture.map(ObsCapture::finish);
    let agg = &report.aggregate;

    let mut t = TextTable::new(["metric", "value"]).with_title(format!(
        "Fleet serving: {} nodes x{} repl, {} models of {}x{} on {} (engine={})",
        opts.nodes,
        report.replication,
        opts.serve.models,
        opts.serve.rows,
        opts.serve.cols,
        device_label,
        ctx.engine_name(),
    ));
    t.push([
        "clients x requests",
        &format!("{} x {}", opts.serve.clients, opts.serve.requests_per_client),
    ]);
    t.push(["transport", f.transport.name()]);
    t.push(["requests served", &agg.requests.to_string()]);
    t.push(["throughput (req/s)", &fnum(agg.throughput)]);
    t.push(["p50 latency (ms)", &fnum(agg.p50_ms)]);
    t.push(["p95 latency (ms)", &fnum(agg.p95_ms)]);
    t.push(["p99 latency (ms)", &fnum(agg.p99_ms)]);
    t.push(["mean batch", &fnum(agg.mean_batch)]);
    t.push(["programs", &agg.programs.to_string()]);
    t.push([
        "cache hits/misses",
        &format!("{}/{}", agg.cache.hits, agg.cache.misses),
    ]);
    t.push(["mean |e|", &fnum(agg.mean_abs_error)]);
    t.push(["shed (re-routed)", &report.shed.to_string()]);
    t.push([
        "failed nodes",
        &format!("{:?}", report.failed_nodes),
    ]);
    t.push(["recovered models", &report.recovered_models.to_string()]);
    t.push(["transport bytes", &report.transport_bytes.to_string()]);
    t.push(["per-node rate (req/s)", &fnum(report.per_node_rps)]);
    t.push([
        "nodes @ 1e8 req/day",
        &agg.nodes_for_1e8_per_day.to_string(),
    ]);
    let w = ctx.writer("fleet-bench");
    w.echo(&t.render());
    if let Some(snap) = &telemetry {
        w.echo(&stage_breakdown_table(snap).render());
        write_metrics_artifacts(snap, w.dir())?;
    }
    let mut node_t = TextTable::new([
        "node", "alive", "requests", "batches", "programs", "p99 ms", "bytes in/out",
    ])
    .with_title("Per-node telemetry");
    let mut node_rows = Vec::new();
    for n in &report.nodes {
        node_t.push([
            n.id.to_string(),
            n.alive.to_string(),
            n.requests.to_string(),
            n.batches.to_string(),
            n.programs.to_string(),
            fnum(n.p99_ms),
            format!("{}/{}", n.bytes_in, n.bytes_out),
        ]);
        node_rows.push(obj([
            ("id", Json::Num(n.id as f64)),
            ("alive", Json::Bool(n.alive)),
            ("requests", Json::Num(n.requests as f64)),
            ("batches", Json::Num(n.batches as f64)),
            ("mean_batch", Json::Num(n.mean_batch)),
            ("programs", Json::Num(n.programs as f64)),
            ("cache_hits", Json::Num(n.cache.hits as f64)),
            ("cache_misses", Json::Num(n.cache.misses as f64)),
            ("p50_ms", Json::Num(n.p50_ms)),
            ("p95_ms", Json::Num(n.p95_ms)),
            ("p99_ms", Json::Num(n.p99_ms)),
            ("bytes_in", Json::Num(n.bytes_in as f64)),
            ("bytes_out", Json::Num(n.bytes_out as f64)),
        ]));
    }
    w.echo(&node_t.render());
    w.json(
        "summary",
        &obj([
            ("id", Json::Str("fleet-bench".into())),
            ("engine", Json::Str(ctx.engine_name().into())),
            ("device", Json::Str(device_label)),
            ("rows", Json::Num(opts.serve.rows as f64)),
            ("cols", Json::Num(opts.serve.cols as f64)),
            ("clients", Json::Num(opts.serve.clients as f64)),
            (
                "requests_per_client",
                Json::Num(opts.serve.requests_per_client as f64),
            ),
            ("models", Json::Num(opts.serve.models as f64)),
            ("fleet_nodes", Json::Num(opts.nodes as f64)),
            ("replication", Json::Num(report.replication as f64)),
            ("fail_rate", Json::Num(opts.fail_rate)),
            ("transport", Json::Str(f.transport.name().into())),
            ("requests", Json::Num(agg.requests as f64)),
            ("batches", Json::Num(agg.batches as f64)),
            ("mean_batch", Json::Num(agg.mean_batch)),
            ("wall_secs", Json::Num(agg.wall_secs)),
            ("throughput_req_s", Json::Num(agg.throughput)),
            ("p50_ms", Json::Num(agg.p50_ms)),
            ("p95_ms", Json::Num(agg.p95_ms)),
            ("p99_ms", Json::Num(agg.p99_ms)),
            ("programs", Json::Num(agg.programs as f64)),
            ("cache_hits", Json::Num(agg.cache.hits as f64)),
            ("cache_misses", Json::Num(agg.cache.misses as f64)),
            ("mean_abs_error", Json::Num(agg.mean_abs_error)),
            ("shed", Json::Num(report.shed as f64)),
            (
                "failed_nodes",
                Json::Arr(
                    report
                        .failed_nodes
                        .iter()
                        .map(|&n| Json::Num(n as f64))
                        .collect(),
                ),
            ),
            ("recovered_models", Json::Num(report.recovered_models as f64)),
            ("transport_bytes", Json::Num(report.transport_bytes as f64)),
            ("fitted_req_s", Json::Num(agg.fitted_rps)),
            ("per_node_req_s", Json::Num(report.per_node_rps)),
            (
                "nodes_for_1e8_per_day",
                Json::Num(agg.nodes_for_1e8_per_day as f64),
            ),
            ("per_node", Json::Arr(node_rows)),
        ]),
    )?;
    w.echo(&format!(
        "capacity: at 1e8 requests/day this fabric needs {} node(s) \
         (fitted {:.0} req/s/node across {} nodes)",
        agg.nodes_for_1e8_per_day, report.per_node_rps, opts.nodes,
    ));
    // Bench-schema document for CI artifact upload, named like a perf
    // slug so baselines can track capacity by node count.
    let wire = match f.transport {
        crate::config::FleetTransport::InProcess => "",
        crate::config::FleetTransport::Socket => "-sock",
    };
    let slug = format!("fleet-bench-{}-n{}{wire}", ctx.engine_name(), opts.nodes);
    let bench = vec![BenchResult {
        name: slug,
        median: agg.wall_secs,
        mean: agg.wall_secs,
        min: agg.wall_secs,
        max: agg.wall_secs,
        samples: 1,
        items_per_iter: Some(agg.requests as f64),
    }];
    write_bench_json(&bench, &args.config.out_dir.join("fleet-bench/BENCH.json"))?;
    write_bench_json(&bench, &args.config.out_dir.join("fleet-bench/BENCH.melb"))?;
    Ok(0)
}

fn warmup() -> Result<i32> {
    let sw = Stopwatch::start();
    let rt = XlaRuntime::new(&XlaRuntime::default_dir())?;
    let n = rt.warmup()?;
    println!(
        "compiled {n} artifacts on {} in {}",
        rt.platform_name(),
        sw.pretty()
    );
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::BenchResult;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn bench_with_unmatched_filter_errors_without_writing() {
        let dir = std::env::temp_dir().join("meliso_bench_cli_err_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "bench",
            "--filter",
            "no-such-bench-name",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        let err = dispatch(&args).unwrap_err();
        assert!(err.to_string().contains("no-such-bench-name"), "{err}");
        // No half-written document: an empty BENCH.json would read as
        // "no regressions" downstream.
        assert!(!dir.join("BENCH.json").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn serve_bench_writes_summary_and_bench_json() {
        let dir = std::env::temp_dir().join("meliso_serve_bench_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "serve-bench",
            "--device",
            "epiram",
            "--clients",
            "3",
            "--requests",
            "8",
            "--models",
            "2",
            "--size",
            "16",
            "--queue-cap",
            "8",
            "--batch-max",
            "4",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let summary = std::fs::read_to_string(dir.join("serve-bench/summary.json")).unwrap();
        let doc = crate::util::json::Json::parse(&summary).unwrap();
        assert_eq!(doc.get("requests").unwrap().as_f64(), Some(24.0));
        assert!(doc.get("throughput_req_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(doc.get("mean_abs_error").unwrap().as_f64().unwrap().is_finite());
        assert!(doc.get("fitted_req_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(doc.get("nodes_for_1e8_per_day").unwrap().as_f64().unwrap() >= 1.0);
        // Without --overload the overload keys stay out of the summary.
        assert!(doc.get("overload_factor").is_none());
        let bench = read_bench_json(&dir.join("serve-bench/BENCH.json")).unwrap();
        assert_eq!(bench.len(), 1);
        assert_eq!(bench[0].name, "serve-bench-native-cached");
        assert_eq!(bench[0].items_per_iter, Some(24.0));
        // Unknown device is a clean config error.
        let args = parse(&["serve-bench", "--device", "unobtainium", "--quiet"]);
        assert!(dispatch(&args).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn serve_bench_overload_leg_writes_shed_accounting() {
        let dir = std::env::temp_dir().join("meliso_serve_bench_overload_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "serve-bench",
            "--device",
            "epiram",
            "--overload",
            "2",
            "--clients",
            "3",
            "--requests",
            "8",
            "--models",
            "2",
            "--size",
            "16",
            "--queue-cap",
            "8",
            "--batch-max",
            "4",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let summary = std::fs::read_to_string(dir.join("serve-bench/summary.json")).unwrap();
        let doc = crate::util::json::Json::parse(&summary).unwrap();
        // The base (calibration) summary keys are untouched.
        assert_eq!(doc.get("requests").unwrap().as_f64(), Some(24.0));
        // The overload leg's ledger is exact: offered == served + shed.
        assert_eq!(doc.get("overload_factor").unwrap().as_f64(), Some(2.0));
        let offered = doc.get("overload_offered").unwrap().as_f64().unwrap();
        let served = doc.get("overload_served").unwrap().as_f64().unwrap();
        let shed = doc.get("overload_shed").unwrap().as_f64().unwrap();
        assert_eq!(offered, 24.0);
        assert_eq!(served + shed, offered);
        let rate = doc.get("overload_shed_rate").unwrap().as_f64().unwrap();
        assert!((rate - shed / offered).abs() < 1e-12);
        assert!(doc.get("overload_offered_req_s").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn fleet_bench_writes_summary_and_bench_json() {
        let dir = std::env::temp_dir().join("meliso_fleet_bench_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "fleet-bench",
            "--device",
            "epiram",
            "--fleet-nodes",
            "2",
            "--clients",
            "3",
            "--requests",
            "8",
            "--models",
            "2",
            "--size",
            "16",
            "--queue-cap",
            "8",
            "--batch-max",
            "4",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let summary = std::fs::read_to_string(dir.join("fleet-bench/summary.json")).unwrap();
        let doc = crate::util::json::Json::parse(&summary).unwrap();
        assert_eq!(doc.get("requests").unwrap().as_f64(), Some(24.0));
        assert_eq!(doc.get("fleet_nodes").unwrap().as_f64(), Some(2.0));
        assert_eq!(doc.get("transport").unwrap().as_str(), Some("in-process"));
        assert_eq!(doc.get("shed").unwrap().as_f64(), Some(0.0));
        assert!(doc.get("mean_abs_error").unwrap().as_f64().unwrap().is_finite());
        assert!(doc.get("transport_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(doc.get("per_node").unwrap().as_arr().unwrap().len(), 2);
        let bench = read_bench_json(&dir.join("fleet-bench/BENCH.json")).unwrap();
        assert_eq!(bench.len(), 1);
        assert_eq!(bench[0].name, "fleet-bench-native-n2");
        assert_eq!(bench[0].items_per_iter, Some(24.0));
        // The binary twin decodes to the same document.
        let twin = read_bench_json(&dir.join("fleet-bench/BENCH.melb")).unwrap();
        assert_eq!(twin[0].name, "fleet-bench-native-n2");
        // The socket transport serves the same traffic end to end and
        // gets its own bench slug so baselines track the wires apart.
        let args = parse(&[
            "fleet-bench",
            "--device",
            "epiram",
            "--transport",
            "socket",
            "--fleet-nodes",
            "2",
            "--clients",
            "3",
            "--requests",
            "8",
            "--models",
            "2",
            "--size",
            "16",
            "--queue-cap",
            "8",
            "--batch-max",
            "4",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let summary = std::fs::read_to_string(dir.join("fleet-bench/summary.json")).unwrap();
        let doc = crate::util::json::Json::parse(&summary).unwrap();
        assert_eq!(doc.get("transport").unwrap().as_str(), Some("socket"));
        assert_eq!(doc.get("requests").unwrap().as_f64(), Some(24.0));
        let bench = read_bench_json(&dir.join("fleet-bench/BENCH.json")).unwrap();
        assert_eq!(bench[0].name, "fleet-bench-native-n2-sock");
        // Unknown device is a clean config error.
        let args = parse(&["fleet-bench", "--device", "unobtainium", "--quiet"]);
        assert!(dispatch(&args).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn metrics_writes_snapshot_artifacts() {
        // NOTE: no `obs::test_lock` here — dispatch's ObsCapture takes
        // it; a second acquisition in the same thread would deadlock.
        let dir = std::env::temp_dir().join("meliso_metrics_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "metrics",
            "--device",
            "epiram",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let text = std::fs::read_to_string(dir.join("metrics/METRICS.json")).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        let snap = MetricsSnapshot::from_json(&doc).unwrap();
        // `>=`: while the capture gate is on, parallel tests traversing
        // instrumented paths may also record — exact accounting is
        // pinned in the isolated `integration_obs` binary.
        assert!(snap.counter(CounterId::RequestsServed) >= 64);
        assert!(snap.stage(Stage::QueueWait).count >= 64);
        assert!(snap.stage_sum_ns() > 0);
        // The MELB twin decodes to the very same snapshot.
        let melb = std::fs::read(dir.join("metrics/METRICS.melb")).unwrap();
        assert_eq!(MetricsSnapshot::decode_melb(&melb).unwrap(), snap);
        // Unknown device is a clean config error.
        let args = parse(&["metrics", "--device", "unobtainium", "--quiet"]);
        assert!(dispatch(&args).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn serve_bench_obs_writes_breakdown_artifacts() {
        let dir = std::env::temp_dir().join("meliso_serve_bench_obs_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "serve-bench",
            "--device",
            "epiram",
            "--obs",
            "--clients",
            "3",
            "--requests",
            "8",
            "--models",
            "2",
            "--size",
            "16",
            "--queue-cap",
            "8",
            "--batch-max",
            "4",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let text = std::fs::read_to_string(dir.join("serve-bench/METRICS.json")).unwrap();
        let snap =
            MetricsSnapshot::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert!(snap.counter(CounterId::RequestsServed) >= 24);
        assert!(snap.stage(Stage::Read).count >= 1);
        let melb = std::fs::read(dir.join("serve-bench/METRICS.melb")).unwrap();
        assert_eq!(MetricsSnapshot::decode_melb(&melb).unwrap(), snap);
        // Without --obs no artifact appears (zero-cost default).
        let plain = std::env::temp_dir().join("meliso_serve_bench_noobs_cli_test");
        let _ = std::fs::remove_dir_all(&plain);
        let args = parse(&[
            "serve-bench",
            "--device",
            "epiram",
            "--clients",
            "2",
            "--requests",
            "4",
            "--size",
            "16",
            "--quiet",
            "--out",
            plain.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        assert!(!plain.join("serve-bench/METRICS.json").exists());
        let _ = std::fs::remove_dir_all(plain);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bench_filtered_writes_bench_json_and_soft_gates() {
        let dir = std::env::temp_dir().join("meliso_bench_cli_ok_test");
        let _ = std::fs::remove_dir_all(&dir);
        let args = parse(&[
            "bench",
            "--filter",
            "stats-moments",
            "--quiet",
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        let results = read_bench_json(&dir.join("BENCH.json")).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].name, "stats-moments");
        assert!(results[0].median > 0.0);
        // The binary twin decodes to the same suite document.
        let twin = read_bench_json(&dir.join("BENCH.melb")).unwrap();
        assert_eq!(twin.len(), 1);
        assert_eq!(twin[0].name, "stats-moments");
        assert_eq!(twin[0].median, results[0].median);

        // Soft gate: even a guaranteed >2x "regression" against an
        // absurdly fast baseline must warn, not fail.
        let baseline = vec![BenchResult {
            name: "stats-moments".into(),
            median: 1e-12,
            mean: 1e-12,
            min: 1e-12,
            max: 1e-12,
            samples: 3,
            items_per_iter: None,
        }];
        let baseline_path = dir.join("baseline.json");
        write_bench_json(&baseline, &baseline_path).unwrap();
        let delta_path = dir.join("report/delta.md");
        let args = parse(&[
            "bench",
            "--filter",
            "stats-moments",
            "--quiet",
            "--baseline",
            baseline_path.to_str().unwrap(),
            "--delta-md",
            delta_path.to_str().unwrap(),
            "--out",
            dir.to_str().unwrap(),
        ]);
        assert_eq!(dispatch(&args).unwrap(), 0);
        // The delta table reports the matched benchmark as slower than
        // the absurdly fast baseline, in markdown table shape.
        let md = std::fs::read_to_string(&delta_path).unwrap();
        assert!(md.contains("| `stats-moments` |"), "{md}");
        assert!(md.contains("x slower"), "{md}");
        assert!(md.contains("1 benchmark(s) compared"), "{md}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn delta_md_without_baseline_is_a_config_error() {
        let args = parse(&["bench", "--delta-md", "delta.md", "--quiet"]);
        let err = dispatch(&args).unwrap_err();
        assert!(err.to_string().contains("--baseline"), "{err}");
    }
}
