//! Command-line interface (no `clap` in the offline registry; this is
//! the hand-rolled equivalent with subcommands, flags, and help).

pub mod args;
pub mod commands;

pub use args::{Args, Command};
pub use commands::dispatch;
