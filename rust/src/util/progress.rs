//! Lightweight timing and progress reporting for long benchmark runs.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Instant;

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Human-readable elapsed time, e.g. `1m23.4s` / `456ms`.
    pub fn pretty(&self) -> String {
        let s = self.elapsed_secs();
        if s < 1.0 {
            format!("{:.0}ms", s * 1e3)
        } else if s < 60.0 {
            format!("{s:.1}s")
        } else {
            format!("{}m{:.1}s", (s / 60.0) as u64, s % 60.0)
        }
    }
}

/// Shared progress counter for the coordinator's chunk loop.  Prints to
/// stderr at most every `report_every` completions when enabled;
/// completely silent otherwise (benches, tests).
#[derive(Debug)]
pub struct Progress {
    label: String,
    total: usize,
    done: AtomicUsize,
    enabled: AtomicBool,
    report_every: usize,
}

impl Progress {
    pub fn new(label: &str, total: usize) -> Self {
        Self {
            label: label.to_string(),
            total,
            done: AtomicUsize::new(0),
            enabled: AtomicBool::new(false),
            report_every: (total / 10).max(1),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    /// Record one completed unit; returns the new completion count.
    pub fn tick(&self) -> usize {
        let done = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        if self.enabled.load(Ordering::Relaxed)
            && (done % self.report_every == 0 || done == self.total)
        {
            eprintln!("[{}] {}/{}", self.label, done, self.total);
        }
        done
    }

    pub fn done(&self) -> usize {
        self.done.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
        assert!(!sw.pretty().is_empty());
    }

    #[test]
    fn progress_counts() {
        let p = Progress::new("t", 5);
        for _ in 0..5 {
            p.tick();
        }
        assert_eq!(p.done(), 5);
    }

    #[test]
    fn pretty_formats() {
        let sw = Stopwatch::start();
        let s = sw.pretty();
        assert!(s.ends_with("ms") || s.ends_with('s'));
    }
}
