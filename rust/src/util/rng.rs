//! Deterministic pseudo-random number generation.
//!
//! The benchmark protocol needs reproducible populations across engines
//! (native rust vs XLA artifacts) and across machines, so the stream is
//! fully specified here: xoshiro256++ for uniform bits, seeded through
//! SplitMix64 (the reference seeding procedure), Box–Muller for
//! normals.  Every experiment derives per-chunk child seeds with
//! [`Xoshiro256::child`] so chunk scheduling order cannot change the
//! sampled population.

/// SplitMix64 step — used for seeding and cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna), plus a Box–Muller normal
/// sampler with one-value caching.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, cached_normal: None }
    }

    /// Derive an independent child stream for chunk `index`.
    ///
    /// Children are keyed by (parent seed state, index) through
    /// SplitMix64 so they are stable regardless of how many values the
    /// parent has consumed in between.
    pub fn child(&self, index: u64) -> Self {
        let mut k = self.s[0] ^ self.s[2].rotate_left(17) ^ index.wrapping_mul(0xA24B_AED4_963E_E407);
        Self::seed_from_u64(splitmix64(&mut k))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free bound
    /// is overkill here; modulo bias at n << 2^64 is negligible but we
    /// still mask it away with rejection for exactness).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        loop {
            // Avoid u == 0 for the log.
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.cached_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill a slice with uniforms in `[lo, hi)` as f32.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo, hi) as f32;
        }
    }

    /// Fill a slice with standard normals as f32.
    ///
    /// Perf: generates Box–Muller pairs directly into the buffer,
    /// skipping the per-call cache branch of [`normal`](Self::normal) —
    /// the workload generator fills ~4k normals per VMM sample, making
    /// this one of the coordinator's hottest loops.  The stream is
    /// identical to repeated `normal()` calls on a fresh generator.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        // Flush a cached half-pair first to keep stream semantics.
        let mut idx = 0;
        if let Some(v) = self.cached_normal.take() {
            if out.is_empty() {
                self.cached_normal = Some(v);
                return;
            }
            out[0] = v as f32;
            idx = 1;
        }
        while idx < out.len() {
            let u = loop {
                let u = self.uniform();
                if u > f64::MIN_POSITIVE {
                    break u;
                }
            };
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            out[idx] = (r * c) as f32;
            idx += 1;
            if idx < out.len() {
                out[idx] = (r * s) as f32;
                idx += 1;
            } else {
                self.cached_normal = Some(r * s);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the SplitMix64 paper
        // implementation (checked against the C reference).
        let mut s = 1234567u64;
        let a = splitmix64(&mut s);
        let b = splitmix64(&mut s);
        assert_ne!(a, b);
        // Determinism.
        let mut s2 = 1234567u64;
        assert_eq!(a, splitmix64(&mut s2));
    }

    #[test]
    fn deterministic_stream() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn child_streams_are_stable_and_independent() {
        let parent = Xoshiro256::seed_from_u64(7);
        let mut c0 = parent.child(0);
        let mut c0_again = parent.child(0);
        let mut c1 = parent.child(1);
        assert_eq!(c0.next_u64(), c0_again.next_u64());
        assert_ne!(c0.next_u64(), c1.next_u64());
    }

    #[test]
    fn child_independent_of_parent_consumption() {
        let parent = Xoshiro256::seed_from_u64(9);
        let pristine_child: Vec<u64> = {
            let mut c = parent.child(3);
            (0..8).map(|_| c.next_u64()).collect()
        };
        let mut consumed = parent.clone();
        for _ in 0..100 {
            consumed.next_u64();
        }
        // child() keys off the seed state captured at construction; we
        // clone the parent before consuming, mirroring coordinator use.
        let mut c = parent.child(3);
        let again: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(pristine_child, again);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let n = 200_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
            s3 += z * z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s3 / nf).abs() < 0.05);
        assert!((s4 / nf - 3.0).abs() < 0.1);
    }

    #[test]
    fn fill_helpers() {
        let mut r = Xoshiro256::seed_from_u64(19);
        let mut buf = vec![0f32; 4096];
        r.fill_uniform_f32(&mut buf, -1.0, 1.0);
        assert!(buf.iter().all(|v| (-1.0..1.0).contains(v)));
        let mut buf2 = vec![0f32; 4096];
        r.fill_normal_f32(&mut buf2);
        let m: f32 = buf2.iter().sum::<f32>() / 4096.0;
        assert!(m.abs() < 0.1);
    }
}
