//! Minimal JSON codec (the `serde`/`serde_json` facade is not in the
//! offline registry).
//!
//! The parser accepts the full JSON grammar; the writer emits the
//! subset the crate produces (objects, arrays, strings, finite numbers,
//! bools, null).  Used for the artifact manifest and report emission.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Parse(format!(
                "trailing characters at offset {}",
                p.pos
            )));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access helper.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize back to compact JSON text.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; reports encode them as null.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder: `obj([("k", Json::Num(1.0))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(
        items
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T> {
        Err(Error::Parse(format!("json: {msg} at offset {}", self.pos)))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(&format!("expected literal {lit}"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("missing low surrogate");
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        match c {
                            Some(c) => s.push(c),
                            None => return self.err("invalid \\u escape"),
                        }
                    }
                    _ => return self.err("invalid escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return self.err("truncated utf-8");
                        }
                        match std::str::from_utf8(&self.bytes[start..start + len]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = start + len;
                            }
                            Err(_) => return self.err("invalid utf-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = match self.bump() {
                Some(c) => c,
                None => return self.err("truncated \\u escape"),
            };
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return self.err("bad hex digit"),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::Parse("json: bad number bytes".into()))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("json: bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e2").unwrap(), Json::Num(-1200.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo — wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — wörld"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"b":true}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string_compact();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn pretty_is_parseable() {
        let v = obj([
            ("x", Json::Num(1.0)),
            ("y", Json::Arr(vec![Json::Str("a".into())])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn nonfinite_becomes_null() {
        let v = Json::Num(f64::NAN);
        assert_eq!(v.to_string_compact(), "null");
    }

    #[test]
    fn integers_have_no_fraction() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
    }
}
