//! Pluggable artifact codec: one [`Json`] value model, two framings.
//!
//! * **Json** — the pretty text framing every report already uses;
//!   human-diffable, universally consumable.
//! * **Binary** — a length-prefixed tagged framing (`MELB` magic +
//!   version byte) for large machine-read artifacts (bench suites,
//!   sweep outputs, persisted program specs): no text re-parse on the
//!   read path, and `f64` payloads round-trip bit-exactly.
//!
//! Decoding always sniffs: [`Codec::decode`] accepts either framing,
//! so a reader never needs to know how an artifact was written.
//!
//! ## Binary framing (version 1)
//!
//! ```text
//! "MELB"  u8 version  value
//! value := tag u8 + payload
//!   0 null | 1 false | 2 true
//!   3 f64 (8 bytes LE)
//!   4 str (u32 LE byte length + UTF-8 bytes)
//!   5 arr (u32 LE count + count values)
//!   6 obj (u32 LE count + count (str key, value) pairs)
//! ```
//!
//! All integers little-endian; object keys are written in the
//! [`Json::Obj`] `BTreeMap` order, so encoding is deterministic.
//! Framing contract: `rust/DESIGN.md` §15.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Leading magic of the binary framing.
pub const BINARY_MAGIC: [u8; 4] = *b"MELB";
/// Current binary framing version.
pub const BINARY_VERSION: u8 = 1;
/// Nesting bound of the binary decoder (corrupt inputs must error, not
/// exhaust the stack).
const MAX_DEPTH: usize = 512;

/// Artifact framing selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// Pretty JSON text.
    #[default]
    Json,
    /// Length-prefixed tagged binary (`MELB`).
    Binary,
}

impl Codec {
    /// Framing convention by file extension: `.melb`/`.bin` is binary,
    /// anything else (`.json` included) is text.
    pub fn for_path(path: &Path) -> Codec {
        match path.extension().and_then(|e| e.to_str()) {
            Some("melb") | Some("bin") => Codec::Binary,
            _ => Codec::Json,
        }
    }

    /// Encode one value in this framing.  Binary encoding is fallible:
    /// a string, array, or object whose length exceeds the `u32` frame
    /// field is rejected with a typed [`Error::Parse`] instead of
    /// silently wrapping into a corrupt frame.
    pub fn encode(&self, v: &Json) -> Result<Vec<u8>> {
        match self {
            Codec::Json => Ok(v.to_string_pretty().into_bytes()),
            Codec::Binary => {
                let mut out = Vec::with_capacity(64);
                out.extend_from_slice(&BINARY_MAGIC);
                out.push(BINARY_VERSION);
                encode_value(v, &mut out)?;
                Ok(out)
            }
        }
    }

    /// Decode either framing: binary when the `MELB` magic leads, JSON
    /// text otherwise.
    pub fn decode(bytes: &[u8]) -> Result<Json> {
        if bytes.starts_with(&BINARY_MAGIC) {
            let version = *bytes
                .get(4)
                .ok_or_else(|| Error::Parse("melb: truncated header".into()))?;
            if version > BINARY_VERSION {
                return Err(Error::Parse(format!(
                    "melb: framing version {version} is newer than this \
                     binary ({BINARY_VERSION})"
                )));
            }
            let mut r = Reader { bytes, pos: 5 };
            let v = r.value(0)?;
            if r.pos != bytes.len() {
                return Err(Error::Parse(format!(
                    "melb: {} trailing bytes",
                    bytes.len() - r.pos
                )));
            }
            Ok(v)
        } else {
            let text = std::str::from_utf8(bytes)
                .map_err(|_| Error::Parse("artifact is neither melb nor UTF-8 text".into()))?;
            Json::parse(text)
        }
    }

    /// Write one value to `path` in this framing, creating parents.
    pub fn write(&self, path: &Path, v: &Json) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.encode(v)?)?;
        Ok(())
    }

    /// Read one value from `path`, sniffing the framing.
    pub fn read(path: &Path) -> Result<Json> {
        Self::decode(&std::fs::read(path)?)
    }
}

/// Envelope tag of a fleet transport *request* frame.
///
/// Envelope tags live in their own range (`>= 0x10`), disjoint from
/// the value tags of the plain document framing, so an envelope can
/// never be mistaken for an artifact (plain [`Codec::decode`] rejects
/// the tag) and vice versa.
pub const ENVELOPE_REQUEST: u8 = 0x10;
/// Envelope tag of a fleet transport *response* frame.
pub const ENVELOPE_RESPONSE: u8 = 0x11;
/// Envelope tag of a metrics-registry snapshot
/// ([`crate::obs::MetricsSnapshot`]) — same framing, same hardening,
/// own tag so a telemetry artifact can never be replayed as a wire
/// frame (or decoded as a plain document) by mistake.
pub const METRICS_SNAPSHOT: u8 = 0x12;

/// Encode one transport envelope: the `MELB` header, an envelope tag
/// byte, then the payload value.  Unlike the document framing,
/// envelope frames are designed to be concatenated on a stream —
/// [`decode_envelope`] consumes exactly one frame and reports how many
/// bytes it used.  Oversized payloads (any string/array/object past
/// the `u32` length field) are a typed [`Error::Parse`] at encode
/// time — a frame that cannot decode is never emitted.
pub fn encode_envelope(tag: u8, payload: &Json) -> Result<Vec<u8>> {
    debug_assert!(tag >= 0x10, "envelope tags start at 0x10");
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&BINARY_MAGIC);
    out.push(BINARY_VERSION);
    out.push(tag);
    encode_value(payload, &mut out)?;
    Ok(out)
}

/// Decode one envelope frame from the head of `bytes`, returning the
/// envelope tag, the payload, and the number of bytes consumed (the
/// next frame starts there).  Trailing bytes are *not* an error — this
/// is the mid-stream entry point — but a truncated or corrupt frame is
/// always a typed [`Error::Parse`]: the reader bounds every length
/// against the remaining buffer, so a prefix of a valid frame can
/// neither panic nor over-read.
pub fn decode_envelope(bytes: &[u8]) -> Result<(u8, Json, usize)> {
    if bytes.len() < 6 {
        return Err(Error::Parse("melb envelope: truncated header".into()));
    }
    if bytes[..4] != BINARY_MAGIC {
        return Err(Error::Parse("melb envelope: bad magic".into()));
    }
    let version = bytes[4];
    if version > BINARY_VERSION {
        return Err(Error::Parse(format!(
            "melb envelope: framing version {version} is newer than this \
             binary ({BINARY_VERSION})"
        )));
    }
    let tag = bytes[5];
    if tag < 0x10 {
        return Err(Error::Parse(format!(
            "melb envelope: value tag {tag} where an envelope tag (>= 0x10) \
             was expected"
        )));
    }
    let mut r = Reader { bytes, pos: 6 };
    let payload = r.value(0)?;
    Ok((tag, payload, r.pos))
}

/// Bound a declared length to the `u32` frame field.  `usize` lengths
/// past `u32::MAX` used to wrap silently (`len as u32`), emitting a
/// frame whose declared length disagrees with its payload — corrupt on
/// every reader.  Rejecting at encode time keeps the boundary honest.
pub(crate) fn frame_len(len: usize, what: &str) -> Result<[u8; 4]> {
    match u32::try_from(len) {
        Ok(n) => Ok(n.to_le_bytes()),
        Err(_) => Err(Error::Parse(format!(
            "melb: {what} length {len} exceeds the u32 frame field"
        ))),
    }
}

fn encode_value(v: &Json, out: &mut Vec<u8>) -> Result<()> {
    match v {
        Json::Null => out.push(0),
        Json::Bool(false) => out.push(1),
        Json::Bool(true) => out.push(2),
        Json::Num(n) => {
            out.push(3);
            out.extend_from_slice(&n.to_le_bytes());
        }
        Json::Str(s) => {
            out.push(4);
            encode_str(s, out)?;
        }
        Json::Arr(a) => {
            out.push(5);
            out.extend_from_slice(&frame_len(a.len(), "array")?);
            for item in a {
                encode_value(item, out)?;
            }
        }
        Json::Obj(o) => {
            out.push(6);
            out.extend_from_slice(&frame_len(o.len(), "object")?);
            for (k, item) in o {
                encode_str(k, out)?;
                encode_value(item, out)?;
            }
        }
    }
    Ok(())
}

fn encode_str(s: &str, out: &mut Vec<u8>) -> Result<()> {
    out.extend_from_slice(&frame_len(s.len(), "string")?);
    out.extend_from_slice(s.as_bytes());
    Ok(())
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn err<T>(&self, msg: &str) -> Result<T> {
        Err(Error::Parse(format!("melb: {msg} at offset {}", self.pos)))
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.bytes.len() - self.pos < n {
            return self.err("truncated value");
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// A declared element/byte count; every element costs at least one
    /// byte, so a count beyond the remaining buffer is corrupt (and
    /// must not drive a huge allocation).
    fn count(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        if n > self.bytes.len() - self.pos {
            return self.err("declared length exceeds buffer");
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.count()?;
        let raw = self.take(n)?;
        match std::str::from_utf8(raw) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => self.err("invalid UTF-8 string"),
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return self.err("nesting too deep");
        }
        let tag = self.take(1)?[0];
        match tag {
            0 => Ok(Json::Null),
            1 => Ok(Json::Bool(false)),
            2 => Ok(Json::Bool(true)),
            3 => {
                let b = self.take(8)?;
                let mut raw = [0u8; 8];
                raw.copy_from_slice(b);
                Ok(Json::Num(f64::from_le_bytes(raw)))
            }
            4 => Ok(Json::Str(self.string()?)),
            5 => {
                let n = self.count()?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(self.value(depth + 1)?);
                }
                Ok(Json::Arr(items))
            }
            6 => {
                let n = self.count()?;
                let mut map = BTreeMap::new();
                for _ in 0..n {
                    let k = self.string()?;
                    let v = self.value(depth + 1)?;
                    map.insert(k, v);
                }
                Ok(Json::Obj(map))
            }
            t => self.err(&format!("unknown tag {t}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use crate::util::rng::Xoshiro256;

    fn sample() -> Json {
        obj([
            ("name", Json::Str("native-par".into())),
            ("median_secs", Json::Num(0.012_345_678_901_234_5)),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Null])),
            (
                "nested",
                obj([("unicode", Json::Str("héllo — wörld 😀".into()))]),
            ),
            ("empty_arr", Json::Arr(vec![])),
            ("empty_obj", Json::Obj(Default::default())),
        ])
    }

    #[test]
    fn binary_roundtrip_is_exact() {
        let v = sample();
        let bytes = Codec::Binary.encode(&v).unwrap();
        assert_eq!(&bytes[..4], &BINARY_MAGIC);
        assert_eq!(bytes[4], BINARY_VERSION);
        assert_eq!(Codec::decode(&bytes).unwrap(), v);
    }

    #[test]
    fn sniffing_accepts_both_framings() {
        let v = sample();
        assert_eq!(Codec::decode(&Codec::Json.encode(&v).unwrap()).unwrap(), v);
        assert_eq!(Codec::decode(&Codec::Binary.encode(&v).unwrap()).unwrap(), v);
    }

    #[test]
    fn f64_bits_survive_binary() {
        // Values whose shortest decimal text could be mis-rounded by a
        // sloppy reader: binary carries raw bits.
        for &x in &[f64::MIN_POSITIVE, 1.0 + f64::EPSILON, -0.0, 1e-300, 0.1 + 0.2] {
            let v = Json::Num(x);
            let back = Codec::decode(&Codec::Binary.encode(&v).unwrap()).unwrap();
            assert_eq!(back.as_f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn path_convention_selects_framing() {
        assert_eq!(Codec::for_path(Path::new("a/BENCH.json")), Codec::Json);
        assert_eq!(Codec::for_path(Path::new("a/BENCH.melb")), Codec::Binary);
        assert_eq!(Codec::for_path(Path::new("a/dump.bin")), Codec::Binary);
        assert_eq!(Codec::for_path(Path::new("noext")), Codec::Json);
    }

    #[test]
    fn file_roundtrip_both_framings() {
        let dir = std::env::temp_dir().join("meliso_codec_file_test");
        let _ = std::fs::remove_dir_all(&dir);
        let v = sample();
        for name in ["artifact.json", "artifact.melb"] {
            let path = dir.join(name);
            Codec::for_path(&path).write(&path, &v).unwrap();
            assert_eq!(Codec::read(&path).unwrap(), v);
        }
        // The two files hold the same value in different framings.
        let j = std::fs::read(dir.join("artifact.json")).unwrap();
        let b = std::fs::read(dir.join("artifact.melb")).unwrap();
        assert_ne!(j, b);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn corrupt_binary_is_rejected_not_panicked() {
        let good = Codec::Binary.encode(&sample()).unwrap();
        // Truncations at every prefix length must error cleanly.
        for cut in 0..good.len() {
            assert!(Codec::decode(&good[..cut]).is_err() || cut == 0, "cut={cut}");
        }
        // Unknown tag.
        let mut bad = good.clone();
        bad[5] = 99;
        assert!(Codec::decode(&bad).is_err());
        // Future version.
        let mut newer = good.clone();
        newer[4] = BINARY_VERSION + 1;
        assert!(Codec::decode(&newer).is_err());
        // A declared length far beyond the buffer must not allocate.
        let mut huge = Vec::from(&BINARY_MAGIC[..]);
        huge.push(BINARY_VERSION);
        huge.push(5); // arr
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Codec::decode(&huge).is_err());
    }

    #[test]
    fn oversized_lengths_are_rejected_at_encode_time() {
        // The u32 frame field is a hard boundary: a length one past it
        // must be a typed parse error, never a silent wrap.  (The
        // check is tested through `frame_len` — materializing a >4 GiB
        // string to drive `encode` end-to-end is not something a unit
        // test should allocate.)
        assert_eq!(frame_len(0, "string").unwrap(), 0u32.to_le_bytes());
        assert_eq!(
            frame_len(u32::MAX as usize, "string").unwrap(),
            u32::MAX.to_le_bytes()
        );
        #[cfg(target_pointer_width = "64")]
        {
            let err = frame_len(u32::MAX as usize + 1, "string").unwrap_err();
            assert!(matches!(err, Error::Parse(_)), "typed Parse error: {err}");
            assert!(err.to_string().contains("u32 frame field"), "{err}");
            assert!(frame_len(usize::MAX, "array").is_err());
        }
    }

    /// Seeded random value generator for the fuzz round-trip.
    fn random_value(rng: &mut Xoshiro256, depth: usize) -> Json {
        let kind = if depth >= 4 {
            rng.uniform_in(0.0, 4.0) as usize // scalars only at depth
        } else {
            rng.uniform_in(0.0, 6.0) as usize
        };
        match kind {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform_in(0.0, 1.0) < 0.5),
            2 => Json::Num(rng.uniform_in(-1e9, 1e9)),
            3 => {
                let n = rng.uniform_in(0.0, 12.0) as usize;
                let chars: Vec<char> = "ab\"\\\n\tμλ😀 xyz".chars().collect();
                let s: String = (0..n)
                    .map(|_| {
                        let i = rng.uniform_in(0.0, chars.len() as f64) as usize;
                        chars[i.min(chars.len() - 1)]
                    })
                    .collect();
                Json::Str(s)
            }
            4 => {
                let n = rng.uniform_in(0.0, 5.0) as usize;
                Json::Arr((0..n).map(|_| random_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.uniform_in(0.0, 5.0) as usize;
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }

    #[test]
    fn envelope_roundtrip_and_stream_concatenation() {
        let a = sample();
        let b = Json::Num(42.0);
        let mut stream = encode_envelope(ENVELOPE_REQUEST, &a).unwrap();
        let first_len = stream.len();
        stream.extend_from_slice(&encode_envelope(ENVELOPE_RESPONSE, &b).unwrap());
        // First frame decodes in place, reporting exactly its length.
        let (tag, payload, used) = decode_envelope(&stream).unwrap();
        assert_eq!((tag, used), (ENVELOPE_REQUEST, first_len));
        assert_eq!(payload, a);
        // The reported offset is the start of the next frame.
        let (tag2, payload2, used2) = decode_envelope(&stream[used..]).unwrap();
        assert_eq!(tag2, ENVELOPE_RESPONSE);
        assert_eq!(payload2, b);
        assert_eq!(used + used2, stream.len());
        // Envelopes and documents stay disjoint: a plain artifact is
        // not an envelope, and an envelope is not a plain artifact.
        assert!(decode_envelope(&Codec::Binary.encode(&a).unwrap()).is_err());
        assert!(Codec::decode(&encode_envelope(ENVELOPE_REQUEST, &a).unwrap()).is_err());
    }

    #[test]
    fn fuzz_truncated_envelopes_error_cleanly() {
        // Seeded truncation fuzz: for random envelopes, every strict
        // prefix of a valid frame must decode to a typed error — never
        // a panic, an over-read, or a bogus success.
        let mut rng = Xoshiro256::seed_from_u64(0xE57E_10FE);
        for i in 0..64 {
            let v = random_value(&mut rng, 0);
            let tag = if i % 2 == 0 { ENVELOPE_REQUEST } else { ENVELOPE_RESPONSE };
            let frame = encode_envelope(tag, &v).unwrap();
            for cut in 0..frame.len() {
                let r = decode_envelope(&frame[..cut]);
                assert!(r.is_err(), "prefix of length {cut} must be an error");
            }
            // The full frame decodes, and junk after it is ignored by
            // the mid-stream entry point (consumed stops at the frame).
            let mut padded = frame.clone();
            padded.extend_from_slice(b"\xFFjunk-after-frame");
            let (t, p, used) = decode_envelope(&padded).unwrap();
            assert_eq!((t, used), (tag, frame.len()));
            assert_eq!(p, v);
        }
        // An oversized declared length mid-stream is corrupt, not an
        // allocation request.
        let mut huge = Vec::from(&BINARY_MAGIC[..]);
        huge.push(BINARY_VERSION);
        huge.push(ENVELOPE_REQUEST);
        huge.push(5); // arr
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_envelope(&huge).is_err());
    }

    #[test]
    fn metrics_snapshot_tag_is_disjoint_and_frames_cleanly() {
        // The telemetry tag shares the envelope framing and hardening
        // but never collides with value tags or the transport tags.
        assert!(METRICS_SNAPSHOT >= 0x10);
        assert_ne!(METRICS_SNAPSHOT, ENVELOPE_REQUEST);
        assert_ne!(METRICS_SNAPSHOT, ENVELOPE_RESPONSE);
        let v = sample();
        let frame = encode_envelope(METRICS_SNAPSHOT, &v).unwrap();
        let (tag, payload, used) = decode_envelope(&frame).unwrap();
        assert_eq!((tag, used), (METRICS_SNAPSHOT, frame.len()));
        assert_eq!(payload, v);
        // A metrics frame is not a plain document, and truncations of
        // it are typed errors like any other envelope.
        assert!(Codec::decode(&frame).is_err());
        for cut in 0..frame.len() {
            assert!(decode_envelope(&frame[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn fuzz_json_and_binary_decode_identically() {
        let mut rng = Xoshiro256::seed_from_u64(0xC0DEC);
        for _ in 0..200 {
            let v = random_value(&mut rng, 0);
            let from_json = Codec::decode(&Codec::Json.encode(&v).unwrap()).unwrap();
            let from_bin = Codec::decode(&Codec::Binary.encode(&v).unwrap()).unwrap();
            // Binary is exact; JSON text of finite f64 re-parses
            // exactly (shortest round-trip formatting) — so all three
            // agree bit-for-bit.
            assert_eq!(from_bin, v);
            assert_eq!(from_json, from_bin);
        }
    }
}
