//! Scoped worker pool for compute-bound benchmark chunks.
//!
//! `tokio` is not in the offline registry, and the coordinator's
//! workload is pure CPU batches, so the honest substrate is a scoped
//! thread pool with an atomic work-stealing index: submit `n` chunk
//! jobs, run them on `k` threads, collect results in submission order.
//! Panics in workers are propagated to the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Pool sizing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// One worker per available CPU (default).
    Auto,
    /// Exactly `n` workers (1 = sequential, still exercised through the
    /// same code path for determinism tests).
    Fixed(usize),
}

impl Parallelism {
    /// Environment override for `Auto`: CI pins this to 1 and 4 to
    /// exercise the thread-count-determinism contract on fixed widths
    /// (results are bit-identical either way; this pins the *width*).
    pub const THREADS_ENV: &'static str = "MELISO_THREADS";

    pub fn threads(self) -> usize {
        match self {
            Parallelism::Auto => {
                parse_threads_override(std::env::var(Self::THREADS_ENV).ok().as_deref())
                    .unwrap_or_else(|| {
                        std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1)
                    })
            }
            Parallelism::Fixed(n) => n.max(1),
        }
    }
}

/// Parse a `MELISO_THREADS` value; `None`/invalid/zero disables the
/// override (factored out so the policy is unit-testable without
/// mutating the process environment, which would race concurrent
/// `env::var` readers in the parallel test binary).
fn parse_threads_override(value: Option<&str>) -> Option<usize> {
    value
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::Auto
    }
}

/// Run `job(i)` for every `i in 0..n` on the pool and return results in
/// index order.  `job` must be `Sync` (it is shared by workers); use
/// interior chunk state, not shared mutable state.
pub fn run_indexed<T, F>(par: Parallelism, n: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = par.threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(&job).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = job(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("worker finished without storing a result")
        })
        .collect()
}

/// Split `0..n` into at most `par.threads()` contiguous blocks (near-
/// equal sizes, in index order).  Used by the engines to fan samples
/// across workers while keeping per-worker scratch buffers.
pub fn partition_blocks(par: Parallelism, n: usize) -> Vec<(usize, usize)> {
    let threads = par.threads().min(n.max(1)).max(1);
    let base = n / threads;
    let extra = n % threads;
    let mut blocks = Vec::with_capacity(threads);
    let mut start = 0;
    for i in 0..threads {
        let len = base + usize::from(i < extra);
        if len > 0 {
            blocks.push((start, len));
            start += len;
        }
    }
    blocks
}

/// Run `job(i, &mut scratch, out_i)` for every `i in 0..n`, where
/// `out_i` is the `i`-th `stride`-sized slice of the returned buffer.
/// Work is fanned over the pool in contiguous blocks (one per worker,
/// via [`run_indexed`]); each worker builds its scratch **once** with
/// `make_scratch` and reuses it across its samples.  Results are
/// bit-identical for any `par` because every index writes only its own
/// slice and sample computations are independent.
pub fn run_blocked<T, S, FS, F>(
    par: Parallelism,
    n: usize,
    stride: usize,
    make_scratch: FS,
    job: F,
) -> Vec<T>
where
    T: Default + Clone + Send,
    FS: Fn() -> S + Sync,
    F: Fn(usize, &mut S, &mut [T]) + Sync,
{
    let blocks = partition_blocks(par, n);
    let outs: Vec<Vec<T>> = run_indexed(par, blocks.len(), |bi| {
        let (start, len) = blocks[bi];
        let mut scratch = make_scratch();
        let mut out = vec![T::default(); len * stride];
        for i in 0..len {
            job(start + i, &mut scratch, &mut out[i * stride..(i + 1) * stride]);
        }
        out
    });
    let mut all = Vec::with_capacity(n * stride);
    for o in outs {
        all.extend(o);
    }
    all
}

/// Map a slice in parallel, preserving order.
pub fn par_map<T, U, F>(par: Parallelism, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    run_indexed(par, items.len(), |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_submission_order() {
        let out = run_indexed(Parallelism::Fixed(4), 100, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path_matches_parallel() {
        let seq = run_indexed(Parallelism::Fixed(1), 37, |i| i * i);
        let par = run_indexed(Parallelism::Fixed(8), 37, |i| i * i);
        assert_eq!(seq, par);
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let count = AtomicU64::new(0);
        let n = 1000;
        let _ = run_indexed(Parallelism::Auto, n, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<usize> = run_indexed(Parallelism::Auto, 0, |i| i);
        assert!(out.is_empty());
        let out = run_indexed(Parallelism::Auto, 1, |i| i + 5);
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn par_map_works() {
        let items = vec![1.0f64, 2.0, 3.0];
        let out = par_map(Parallelism::Fixed(2), &items, |x| x * 10.0);
        assert_eq!(out, vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn auto_threads_positive() {
        assert!(Parallelism::Auto.threads() >= 1);
        assert_eq!(Parallelism::Fixed(0).threads(), 1);
    }

    #[test]
    fn meliso_threads_override_policy() {
        // The policy is tested on the pure parser — mutating the real
        // environment here would race concurrent env::var readers in
        // the parallel test binary.  CI's MELISO_THREADS=1/4 legs
        // exercise the env wiring end-to-end.
        assert_eq!(parse_threads_override(Some("3")), Some(3));
        assert_eq!(parse_threads_override(Some(" 4 ")), Some(4));
        for bad in ["0", "-2", "lots", ""] {
            assert_eq!(parse_threads_override(Some(bad)), None, "value {bad:?}");
        }
        assert_eq!(parse_threads_override(None), None);
        // Fixed is never overridden; Auto stays positive either way.
        assert_eq!(Parallelism::Fixed(2).threads(), 2);
        assert!(Parallelism::Auto.threads() >= 1);
    }

    #[test]
    fn partition_covers_everything_in_order() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            for t in [1usize, 2, 3, 8, 64] {
                let blocks = partition_blocks(Parallelism::Fixed(t), n);
                let mut next = 0;
                for &(start, len) in &blocks {
                    assert_eq!(start, next, "n={n} t={t}");
                    assert!(len > 0);
                    next += len;
                }
                assert_eq!(next, n, "n={n} t={t}");
                assert!(blocks.len() <= t.max(1));
            }
        }
    }

    #[test]
    fn run_blocked_matches_sequential_for_any_thread_count() {
        let job = |i: usize, scratch: &mut u64, out: &mut [u64]| {
            *scratch += 1; // scratch reuse must not affect results
            out[0] = (i * 3) as u64;
            out[1] = (i * 3 + 1) as u64;
        };
        let seq = run_blocked(Parallelism::Fixed(1), 33, 2, || 0u64, job);
        let par = run_blocked(Parallelism::Fixed(7), 33, 2, || 0u64, job);
        assert_eq!(seq, par);
        assert_eq!(seq.len(), 66);
        assert_eq!(seq[6], 9); // sample 3, first element
    }

    #[test]
    fn run_blocked_scratch_is_per_worker() {
        use std::sync::atomic::AtomicUsize;
        let made = AtomicUsize::new(0);
        let _ = run_blocked(
            Parallelism::Fixed(4),
            100,
            1,
            || {
                made.fetch_add(1, Ordering::Relaxed);
            },
            |i, _s, out: &mut [usize]| out[0] = i,
        );
        // One scratch per block, and at most one block per worker.
        assert!(made.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        let _ = run_indexed(Parallelism::Fixed(2), 4, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
