//! Scoped worker pool for compute-bound benchmark chunks.
//!
//! `tokio` is not in the offline registry, and the coordinator's
//! workload is pure CPU batches, so the honest substrate is a scoped
//! thread pool with an atomic work-stealing index: submit `n` chunk
//! jobs, run them on `k` threads, collect results in submission order.
//! Panics in workers are propagated to the caller.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Pool sizing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// One worker per available CPU (default).
    Auto,
    /// Exactly `n` workers (1 = sequential, still exercised through the
    /// same code path for determinism tests).
    Fixed(usize),
}

impl Parallelism {
    pub fn threads(self) -> usize {
        match self {
            Parallelism::Auto => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            Parallelism::Fixed(n) => n.max(1),
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::Auto
    }
}

/// Run `job(i)` for every `i in 0..n` on the pool and return results in
/// index order.  `job` must be `Sync` (it is shared by workers); use
/// interior chunk state, not shared mutable state.
pub fn run_indexed<T, F>(par: Parallelism, n: usize, job: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = par.threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(&job).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = job(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("worker finished without storing a result")
        })
        .collect()
}

/// Map a slice in parallel, preserving order.
pub fn par_map<T, U, F>(par: Parallelism, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    run_indexed(par, items.len(), |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_in_submission_order() {
        let out = run_indexed(Parallelism::Fixed(4), 100, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path_matches_parallel() {
        let seq = run_indexed(Parallelism::Fixed(1), 37, |i| i * i);
        let par = run_indexed(Parallelism::Fixed(8), 37, |i| i * i);
        assert_eq!(seq, par);
    }

    #[test]
    fn all_jobs_run_exactly_once() {
        let count = AtomicU64::new(0);
        let n = 1000;
        let _ = run_indexed(Parallelism::Auto, n, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<usize> = run_indexed(Parallelism::Auto, 0, |i| i);
        assert!(out.is_empty());
        let out = run_indexed(Parallelism::Auto, 1, |i| i + 5);
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn par_map_works() {
        let items = vec![1.0f64, 2.0, 3.0];
        let out = par_map(Parallelism::Fixed(2), &items, |x| x * 10.0);
        assert_eq!(out, vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn auto_threads_positive() {
        assert!(Parallelism::Auto.threads() >= 1);
        assert_eq!(Parallelism::Fixed(0).threads(), 1);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        let _ = run_indexed(Parallelism::Fixed(2), 4, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}
