//! Micro-benchmark harness (criterion is not in the offline registry;
//! this provides the same warmup/sample/report role for the
//! `harness = false` bench targets in `rust/benches/`).
//!
//! Output format is one line per benchmark:
//! `bench <name> ... median 12.345ms  mean 12.5ms  min 12.1ms  (n=10)`
//! plus an optional throughput line when `items_per_iter` is set.
//!
//! Results are also machine-readable: [`BenchResult`] round-trips
//! through [`crate::util::json`], and [`write_bench_json`] /
//! [`read_bench_json`] serialize a whole suite as one `BENCH.json`
//! document (schema documented in `rust/DESIGN.md` §13) — the format
//! `meliso bench` emits and CI's `perf-smoke` job archives and
//! soft-gates against.

use std::path::Path;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::util::codec::Codec;
use crate::util::json::{obj, Json};

/// Schema version of the `BENCH.json` document.
pub const BENCH_SCHEMA_VERSION: f64 = 1.0;

/// One benchmark's options.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Measured samples.
    pub samples: usize,
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// If set, report items/s using this per-iteration item count.
    pub items_per_iter: Option<f64>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { samples: 10, warmup: 2, items_per_iter: None }
    }
}

/// Measured statistics in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub samples: usize,
    /// Items one iteration processes, when the benchmark declared a
    /// throughput denominator ([`BenchOpts::items_per_iter`]).
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn items_per_sec(&self, items: f64) -> f64 {
        items / self.median
    }

    /// Median-based throughput, when the benchmark declared an item
    /// count.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|items| self.items_per_sec(items))
    }

    /// Serialize to the `BENCH.json` result schema.
    pub fn to_json(&self) -> Json {
        obj([
            ("name", Json::Str(self.name.clone())),
            ("median_secs", Json::Num(self.median)),
            ("mean_secs", Json::Num(self.mean)),
            ("min_secs", Json::Num(self.min)),
            ("max_secs", Json::Num(self.max)),
            ("samples", Json::Num(self.samples as f64)),
            (
                "items_per_iter",
                self.items_per_iter.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "items_per_s",
                self.throughput().map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Parse one result back from its `BENCH.json` entry.
    pub fn from_json(v: &Json) -> Result<BenchResult> {
        let field = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Parse(format!("bench result missing '{key}'")))
        };
        Ok(BenchResult {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Parse("bench result missing 'name'".into()))?
                .to_string(),
            median: field("median_secs")?,
            mean: field("mean_secs")?,
            min: field("min_secs")?,
            max: field("max_secs")?,
            samples: field("samples")? as usize,
            items_per_iter: v.get("items_per_iter").and_then(Json::as_f64),
        })
    }
}

/// Serialize a bench suite as one `BENCH.json` document (pretty,
/// versioned — see `rust/DESIGN.md` §13 for the schema contract).
pub fn bench_suite_json(results: &[BenchResult]) -> Json {
    obj([
        ("version", Json::Num(BENCH_SCHEMA_VERSION)),
        (
            "results",
            Json::Arr(results.iter().map(BenchResult::to_json).collect()),
        ),
    ])
}

/// Write a bench-suite document, creating parent directories.  The
/// framing follows the path convention ([`Codec::for_path`]): a
/// `.json` path writes pretty text, a `.melb` path the binary framing.
pub fn write_bench_json(results: &[BenchResult], path: &Path) -> Result<()> {
    Codec::for_path(path).write(path, &bench_suite_json(results))
}

/// Read a bench-suite document back into results (either framing —
/// the codec sniffs).
pub fn read_bench_json(path: &Path) -> Result<Vec<BenchResult>> {
    let doc = Codec::read(path)?;
    let version = doc
        .get("version")
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Parse("BENCH.json missing 'version'".into()))?;
    if version > BENCH_SCHEMA_VERSION {
        return Err(Error::Parse(format!(
            "BENCH.json schema version {version} is newer than this binary ({BENCH_SCHEMA_VERSION})"
        )));
    }
    doc.get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Parse("BENCH.json missing 'results'".into()))?
        .iter()
        .map(BenchResult::from_json)
        .collect()
}

fn pretty(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Run one benchmark and print a report line.  Returns the stats so
/// callers (EXPERIMENTS.md generation) can post-process.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let mut times = Vec::with_capacity(opts.samples);
    for _ in 0..opts.samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        median,
        mean,
        min: times[0],
        max: *times.last().unwrap(),
        samples: times.len(),
        items_per_iter: opts.items_per_iter,
    };
    println!(
        "bench {name:<44} median {:>10}  mean {:>10}  min {:>10}  (n={})",
        pretty(median),
        pretty(mean),
        pretty(result.min),
        result.samples
    );
    if let Some(items) = opts.items_per_iter {
        println!(
            "      {:<44} throughput {:.3e} items/s",
            "", result.items_per_sec(items)
        );
    }
    result
}

/// Keep a value alive and opaque to the optimizer (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench(
            "noop-spin",
            BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(100.0) },
            || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert_eq!(r.samples, 5);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean > 0.0);
        assert!(r.items_per_sec(100.0) > 0.0);
    }

    #[test]
    fn pretty_units() {
        assert!(pretty(5e-9).ends_with("ns"));
        assert!(pretty(5e-5).ends_with("us"));
        assert!(pretty(5e-2).ends_with("ms"));
        assert!(pretty(5.0).ends_with('s'));
    }

    fn sample_results() -> Vec<BenchResult> {
        vec![
            BenchResult {
                name: "native-par".into(),
                median: 0.0125,
                mean: 0.013,
                min: 0.012,
                max: 0.016,
                samples: 10,
                items_per_iter: Some(256.0),
            },
            BenchResult {
                name: "stats-moments".into(),
                median: 2.5e-4,
                mean: 2.6e-4,
                min: 2.4e-4,
                max: 3.0e-4,
                samples: 5,
                items_per_iter: None,
            },
        ]
    }

    #[test]
    fn result_json_roundtrip_preserves_fields() {
        for r in sample_results() {
            let back = BenchResult::from_json(&r.to_json()).unwrap();
            assert_eq!(back.name, r.name);
            assert_eq!(back.median, r.median);
            assert_eq!(back.mean, r.mean);
            assert_eq!(back.min, r.min);
            assert_eq!(back.max, r.max);
            assert_eq!(back.samples, r.samples);
            assert_eq!(back.items_per_iter, r.items_per_iter);
            assert_eq!(back.throughput(), r.throughput());
        }
    }

    #[test]
    fn bench_json_file_roundtrip() {
        let dir = std::env::temp_dir().join("meliso_bench_json_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("BENCH.json");
        let results = sample_results();
        write_bench_json(&results, &path).unwrap();
        // The document is plain parseable JSON with the schema header.
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("version").unwrap().as_f64(), Some(BENCH_SCHEMA_VERSION));
        let back = read_bench_json(&path).unwrap();
        assert_eq!(back.len(), results.len());
        assert_eq!(back[0].name, "native-par");
        assert_eq!(back[0].median, 0.0125);
        assert_eq!(back[0].items_per_iter, Some(256.0));
        assert_eq!(back[1].items_per_iter, None);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bench_melb_file_roundtrip() {
        // The binary twin of the suite document decodes to the same
        // results (sniffing read; no text re-parse).
        let dir = std::env::temp_dir().join("meliso_bench_melb_test");
        let _ = std::fs::remove_dir_all(&dir);
        let results = sample_results();
        let path = dir.join("BENCH.melb");
        write_bench_json(&results, &path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[..4], b"MELB");
        let back = read_bench_json(&path).unwrap();
        assert_eq!(back.len(), results.len());
        assert_eq!(back[0].median, results[0].median);
        assert_eq!(back[1].items_per_iter, None);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn malformed_bench_json_rejected() {
        let dir = std::env::temp_dir().join("meliso_bench_json_bad_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH.json");
        std::fs::write(&path, "{\"results\": []}").unwrap(); // no version
        assert!(read_bench_json(&path).is_err());
        std::fs::write(&path, "{\"version\": 99, \"results\": []}").unwrap();
        assert!(read_bench_json(&path).is_err());
        std::fs::write(&path, "{\"version\": 1, \"results\": [{\"name\": \"x\"}]}").unwrap();
        assert!(read_bench_json(&path).is_err()); // missing stats
        std::fs::write(&path, "{\"version\": 1, \"results\": []}").unwrap();
        assert_eq!(read_bench_json(&path).unwrap().len(), 0);
        let _ = std::fs::remove_dir_all(dir);
    }
}
