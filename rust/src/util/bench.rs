//! Micro-benchmark harness (criterion is not in the offline registry;
//! this provides the same warmup/sample/report role for the
//! `harness = false` bench targets in `rust/benches/`).
//!
//! Output format is one line per benchmark:
//! `bench <name> ... median 12.345ms  mean 12.5ms  min 12.1ms  (n=10)`
//! plus an optional throughput line when `items_per_iter` is set.

use std::time::Instant;

/// One benchmark's options.
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Measured samples.
    pub samples: usize,
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// If set, report items/s using this per-iteration item count.
    pub items_per_iter: Option<f64>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { samples: 10, warmup: 2, items_per_iter: None }
    }
}

/// Measured statistics in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn items_per_sec(&self, items: f64) -> f64 {
        items / self.median
    }
}

fn pretty(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Run one benchmark and print a report line.  Returns the stats so
/// callers (EXPERIMENTS.md generation) can post-process.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let mut times = Vec::with_capacity(opts.samples);
    for _ in 0..opts.samples.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        median,
        mean,
        min: times[0],
        max: *times.last().unwrap(),
        samples: times.len(),
    };
    println!(
        "bench {name:<44} median {:>10}  mean {:>10}  min {:>10}  (n={})",
        pretty(median),
        pretty(mean),
        pretty(result.min),
        result.samples
    );
    if let Some(items) = opts.items_per_iter {
        println!(
            "      {:<44} throughput {:.3e} items/s",
            "", result.items_per_sec(items)
        );
    }
    result
}

/// Keep a value alive and opaque to the optimizer (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench(
            "noop-spin",
            BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(100.0) },
            || {
                let mut s = 0u64;
                for i in 0..1000 {
                    s = s.wrapping_add(black_box(i));
                }
                black_box(s);
            },
        );
        assert_eq!(r.samples, 5);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean > 0.0);
        assert!(r.items_per_sec(100.0) > 0.0);
    }

    #[test]
    fn pretty_units() {
        assert!(pretty(5e-9).ends_with("ns"));
        assert!(pretty(5e-5).ends_with("us"));
        assert!(pretty(5e-2).ends_with("ms"));
        assert!(pretty(5.0).ends_with('s'));
    }
}
