//! General-purpose substrates the offline environment forces us to own:
//! PRNG (`rand` is not vendored), JSON/TOML/CSV codecs (`serde` facade
//! is not vendored), a scoped worker pool (`tokio` is not vendored),
//! and progress/timing helpers.

pub mod bench;
pub mod codec;
pub mod csv;
pub mod json;
pub mod pool;
pub mod progress;
pub mod rng;
pub mod toml;
