//! CSV emission for figure series (one file per figure, consumed by
//! any plotting frontend).  Quoting follows RFC 4180 for the subset we
//! emit: fields containing comma/quote/newline get quoted, quotes are
//! doubled.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// An in-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn columns(&self) -> usize {
        self.header.len()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Push a row of stringifiable cells; panics on arity mismatch
    /// (programmer error, not data error).
    pub fn push<S: ToString, I: IntoIterator<Item = S>>(&mut self, row: I) {
        let row: Vec<String> = row.into_iter().map(|c| c.to_string()).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row arity {} != header arity {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Push a row of f64 cells formatted with full precision.
    pub fn push_f64<I: IntoIterator<Item = f64>>(&mut self, row: I) {
        self.push(row.into_iter().map(|v| format!("{v}")));
    }

    /// Serialize to CSV text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

fn write_row(out: &mut String, cells: &[String]) {
    for (i, cell) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if cell.contains([',', '"', '\n']) {
            out.push('"');
            out.push_str(&cell.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_emission() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push(["1", "2"]);
        t.push_f64([0.5, -1.25]);
        assert_eq!(t.to_string(), "a,b\n1,2\n0.5,-1.25\n");
        assert_eq!(t.len(), 2);
        assert_eq!(t.columns(), 2);
    }

    #[test]
    fn quoting() {
        let mut t = CsvTable::new(["x"]);
        t.push(["has,comma"]);
        t.push(["has\"quote"]);
        t.push(["has\nnewline"]);
        assert_eq!(
            t.to_string(),
            "x\n\"has,comma\"\n\"has\"\"quote\"\n\"has\nnewline\"\n"
        );
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push(["only-one"]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("meliso_csv_test");
        let path = dir.join("sub").join("t.csv");
        let mut t = CsvTable::new(["a"]);
        t.push(["1"]);
        t.write_file(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a\n1\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
