//! TOML-subset parser for experiment/device configuration files.
//!
//! Supports the subset the config system uses: `[table]` and
//! `[table.sub]` headers, `key = value` with string / float / integer /
//! bool / array values, `#` comments.  No multi-line strings, no
//! datetimes, no inline tables, no array-of-tables — config files in
//! this repo do not need them, and failing loudly on unsupported syntax
//! is safer than mis-parsing it.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A TOML scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric coercion: ints widen to f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed TOML document: dotted table path -> key -> value.
/// Top-level keys live under the `""` table path.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub tables: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        doc.tables.entry(current.clone()).or_default();

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated table header"))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    return Err(err(lineno, "unsupported table header"));
                }
                current = name.to_string();
                doc.tables.entry(current.clone()).or_default();
            } else {
                let eq = line
                    .find('=')
                    .ok_or_else(|| err(lineno, "expected key = value"))?;
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(err(lineno, "empty key"));
                }
                let value = parse_value(line[eq + 1..].trim(), lineno)?;
                let table = doc.tables.entry(current.clone()).or_default();
                if table.insert(key.to_string(), value).is_some() {
                    return Err(err(lineno, &format!("duplicate key '{key}'")));
                }
            }
        }
        Ok(doc)
    }

    /// Look up `table.key`, with `""` for top level.
    pub fn get(&self, table: &str, key: &str) -> Option<&TomlValue> {
        self.tables.get(table).and_then(|t| t.get(key))
    }

    /// Table names in document order (BTreeMap: sorted).
    pub fn table_names(&self) -> impl Iterator<Item = &str> {
        self.tables.keys().map(|s| s.as_str())
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Parse(format!("toml line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue> {
    let text = text.trim();
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quote in string"));
        }
        return Ok(TomlValue::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array (single line only)"))?;
        let mut items = Vec::new();
        for piece in split_top_level(inner) {
            let piece = piece.trim();
            if !piece.is_empty() {
                items.push(parse_value(piece, lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    // Number: integer if it parses as i64 and has no float syntax.
    let clean = text.replace('_', "");
    if !clean.contains('.') && !clean.contains(['e', 'E']) {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    clean
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| err(lineno, &format!("bad value '{text}'")))
}

/// Split an array body on commas not inside nested brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let doc = TomlDoc::parse(
            r#"
# benchmark config
seed = 42
name = "fig2a"

[device]
states = 97
memory_window = 12.5
nonideal = true
sweep = [1.0, 2.0, 3.0]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("fig2a"));
        assert_eq!(doc.get("device", "states").unwrap().as_f64(), Some(97.0));
        assert_eq!(
            doc.get("device", "memory_window").unwrap().as_f64(),
            Some(12.5)
        );
        assert_eq!(doc.get("device", "nonideal").unwrap().as_bool(), Some(true));
        let arr = doc.get("device", "sweep").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_f64(), Some(3.0));
    }

    #[test]
    fn dotted_table_names() {
        let doc = TomlDoc::parse("[a.b]\nx = 1\n[a.c]\nx = 2\n").unwrap();
        assert_eq!(doc.get("a.b", "x").unwrap().as_i64(), Some(1));
        assert_eq!(doc.get("a.c", "x").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = TomlDoc::parse("s = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn mixed_and_nested_arrays() {
        let doc = TomlDoc::parse("a = [[1, 2], [3]]\n").unwrap();
        let outer = doc.get("", "a").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[0].as_array().unwrap()[1].as_i64(), Some(2));
    }

    #[test]
    fn negative_and_underscore_numbers() {
        let doc = TomlDoc::parse("a = -3\nb = 1_000\nc = -2.5e-3\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(-3));
        assert_eq!(doc.get("", "b").unwrap().as_i64(), Some(1000));
        assert!((doc.get("", "c").unwrap().as_f64().unwrap() + 0.0025).abs() < 1e-12);
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = TomlDoc::parse("a = 5\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn rejects_errors() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("k = \"open\n").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2\n").is_err());
        assert!(TomlDoc::parse("k = zzz\n").is_err());
    }
}
