//! Typed configuration for benchmark runs: defaults, TOML file
//! loading, CLI overrides.  A config file can pin everything a paper
//! experiment needs, e.g.:
//!
//! ```toml
//! # meliso.toml
//! population = 1000
//! seed = 42
//! engine = "native"          # native | tiled | xla | software
//! out = "out"
//! threads = 0                 # 0 = auto
//! mitigation = "diff,avg:4"   # error-mitigation pipeline (default none)
//!
//! [pipeline]                  # layered inference (`meliso infer`)
//! depth = 4                   # layers in a uniform-width network
//! activation = "relu"         # identity | relu | tanh | hardtanh
//! layers = "32x48x10"         # explicit dimension chain (overrides depth)
//!
//! [serve]                     # request serving (`meliso serve-bench`)
//! clients = 8                 # simulated client threads
//! requests = 64               # requests per client
//! models = 4                  # distinct deployed weight matrices
//! queue = 256                 # bounded-queue capacity (backpressure)
//! batch_max = 32              # largest coalesced batch
//! window_us = 200             # batching window, microseconds
//! workers = 2                 # scheduler worker threads
//! cache = true                # programmed-crossbar cache on/off
//! cache_capacity = 32         # models resident at once
//!
//! [overload]                  # admission control (`serve-bench --overload`)
//! factor = 2.0                # offered load as a multiple of capacity (0 = closed loop)
//! deadline_us = 0             # per-request SLO deadline, microseconds (0 = none)
//! shed = true                 # reject on full queue instead of blocking
//!
//! [fleet]                     # node/router fleet (`meliso fleet-bench`)
//! nodes = 2                   # serving nodes behind the router
//! replication = 1             # replicas per model digest
//! fail_rate = 0.0             # failure-injection intensity in [0, 1]
//! fail_seed = 7               # failure-point seed
//! transport = "in-process"    # wire: "in-process" or "socket"
//! connect_timeout_ms = 1000   # socket: per-attempt connect timeout
//! read_timeout_ms = 5000      # socket: ACK/frame read timeout
//! retries = 3                 # socket: connect retries after the first try
//!
//! [shard]                     # sharded engine (`--engine sharded`)
//! grid = "2x2"                # shard grid RxC (also `--shards`)
//! checksum = true             # ABFT checksum correction on/off
//! threshold = 0.35            # detection factor x sqrt(shard cells)
//! fault_rate = 0.0            # injected gross faults per (sample, shard)
//! fault_level = 1.0           # stuck differential level of injections
//! fault_seed = 7              # fault-stream seed
//!
//! [obs]                       # telemetry (`--obs`, `meliso metrics`)
//! enabled = true              # global metrics registry + stage tracing
//!
//! [device]                    # optional custom device
//! states = 97
//! memory_window = 12.5
//! nu_ltp = 2.4
//! nu_ltd = -4.88
//! sigma_c2c = 0.035
//! ```

use std::path::{Path, PathBuf};

use crate::device::params::{
    DeviceParams, DEFAULT_K_BASE, DEFAULT_K_C2C, DEFAULT_S_EXP,
};
use crate::error::{Error, Result};
use crate::mitigation::MitigationConfig;
use crate::pipeline::{parse_dims, Activation};
use crate::shard::parse_grid;
use crate::util::pool::Parallelism;
use crate::util::toml::TomlDoc;

/// Which compute backend to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Pure-rust crossbar simulation (no artifacts needed).
    #[default]
    Native,
    /// Tiled crossbar simulation for arbitrary workload sizes.
    Tiled,
    /// Sharded multi-crossbar execution with checksum error correction.
    Sharded,
    /// AOT artifacts through PJRT (the production path).
    Xla,
    /// Exact software VMM (zero error; sanity baseline).
    Software,
}

impl EngineKind {
    /// Every engine, in documentation order — the single source of the
    /// engine-name list, so `parse` failures and `--help` can never
    /// drift out of sync with the enum.
    pub const ALL: [EngineKind; 5] = [
        EngineKind::Native,
        EngineKind::Tiled,
        EngineKind::Sharded,
        EngineKind::Xla,
        EngineKind::Software,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.to_ascii_lowercase();
        Self::ALL
            .iter()
            .copied()
            .find(|e| e.name() == lower)
            .ok_or_else(|| {
                Error::Config(format!(
                    "unknown engine '{s}' (available: {})",
                    Self::names().join(", ")
                ))
            })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Tiled => "tiled",
            EngineKind::Sharded => "sharded",
            EngineKind::Xla => "xla",
            EngineKind::Software => "software",
        }
    }

    /// All engine names, in documentation order.
    pub fn names() -> Vec<&'static str> {
        Self::ALL.iter().map(|e| e.name()).collect()
    }
}

/// Layered-inference settings (`meliso infer`, the `[pipeline]` TOML
/// section, and the `--depth/--layers/--activation` flags).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSettings {
    /// Layers in a uniform-width network (the width is `RunConfig::
    /// size`); ignored when `dims` pins an explicit chain.
    pub depth: usize,
    pub activation: Activation,
    /// Explicit dimension chain `d_0, ..., d_L` (layer `k` is a
    /// `d_k -> d_{k+1}` crossbar), from `--layers` / `layers = "..."`.
    pub dims: Option<Vec<usize>>,
    /// Deployed mode (`--deploy` / `deploy = true`): program each
    /// layer once through the serving program cache and read every
    /// sample against that instance, instead of per-sample Monte-Carlo
    /// reprogramming.
    pub deploy: bool,
}

impl Default for PipelineSettings {
    fn default() -> Self {
        Self { depth: 4, activation: Activation::Relu, dims: None, deploy: false }
    }
}

/// Request-serving settings (`meliso serve-bench` and the `[serve]`
/// TOML section).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeSettings {
    /// Simulated client threads.
    pub clients: usize,
    /// Requests each client submits.
    pub requests: usize,
    /// Distinct deployed models rotated across requests.
    pub models: usize,
    /// Bounded request-queue capacity (backpressure bound).
    pub queue: usize,
    /// Largest coalesced batch.
    pub batch_max: usize,
    /// Batching window in microseconds (0 = serve whatever is queued).
    pub window_us: u64,
    /// Scheduler worker threads.
    pub workers: usize,
    /// Serve through the program cache (off = reprogram per batch
    /// group, the measurable status-quo baseline).
    pub cache: bool,
    /// Program-cache capacity (models resident at once).
    pub cache_capacity: usize,
}

impl Default for ServeSettings {
    fn default() -> Self {
        Self {
            clients: 8,
            requests: 64,
            models: 4,
            queue: 256,
            batch_max: 32,
            window_us: 200,
            workers: 2,
            cache: true,
            cache_capacity: 32,
        }
    }
}

/// Overload / admission-control settings (`meliso serve-bench
/// --overload <factor>` and the `[overload]` TOML section).
///
/// All three knobs default to "off": the default serve-bench run is
/// the closed-loop, backpressure-only configuration whose outputs are
/// bit-identical to the pre-admission-control scheduler (DESIGN.md
/// §18).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OverloadSettings {
    /// Offered load as a multiple of calibrated capacity.  `0.0`
    /// disables open-loop pacing (clients submit as fast as
    /// backpressure allows).  When positive, serve-bench first runs a
    /// closed-loop calibration leg to measure capacity, then paces
    /// client arrivals at `factor x capacity` requests/s.
    pub factor: f64,
    /// Per-request SLO deadline in microseconds from admission
    /// (`0` = no deadline).  Expired requests are rejected at
    /// admission or dropped at `pop_batch`, never served late.
    pub deadline_us: u64,
    /// Shed on a full queue (reject with a typed reason) instead of
    /// blocking the producer.  Implied by a positive `factor`: an
    /// open-loop run that blocks is not offering the configured load.
    pub shed: bool,
}

/// Fleet-fabric settings (`meliso fleet-bench` and the `[fleet]` TOML
/// section).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetSettings {
    /// Fleet size (serving nodes behind the router).
    pub nodes: usize,
    /// Replicas per model digest (clamped to the fleet size at run
    /// time).
    pub replication: usize,
    /// Failure-injection intensity in `[0, 1]`:
    /// `ceil(fail_rate * (nodes - 1))` seeded mid-stream node deaths
    /// (0.0 disables).
    pub fail_rate: f64,
    /// Seed of the failure-point draws.
    pub fail_seed: u64,
    /// Which wire the fabric runs on.
    pub transport: FleetTransport,
    /// Socket transport: per-attempt connect timeout, milliseconds.
    pub connect_timeout_ms: u64,
    /// Socket transport: ACK/frame read timeout, milliseconds.
    pub read_timeout_ms: u64,
    /// Socket transport: additional connect attempts after the first.
    pub retries: u32,
}

/// How `fleet-bench` frames travel between router and nodes
/// (`--transport`, `fleet.transport`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FleetTransport {
    /// In-process channels (the default).
    #[default]
    InProcess,
    /// Loopback TCP sockets with real framing, timeouts, and retries.
    Socket,
}

impl FleetTransport {
    /// Parse the CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<FleetTransport> {
        match s {
            "in-process" => Ok(FleetTransport::InProcess),
            "socket" => Ok(FleetTransport::Socket),
            other => Err(Error::Config(format!(
                "transport must be 'in-process' or 'socket', got '{other}'"
            ))),
        }
    }

    /// The canonical spelling (round-trips through [`Self::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            FleetTransport::InProcess => "in-process",
            FleetTransport::Socket => "socket",
        }
    }
}

impl Default for FleetSettings {
    fn default() -> Self {
        Self {
            nodes: 2,
            replication: 1,
            fail_rate: 0.0,
            fail_seed: 0x464C_4554, // "FLET"
            transport: FleetTransport::InProcess,
            connect_timeout_ms: 1_000,
            read_timeout_ms: 5_000,
            retries: 3,
        }
    }
}

/// Sharded-engine settings (`--engine sharded --shards RxC` and the
/// `[shard]` TOML section).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardSettings {
    /// Shard grid rows.
    pub grid_r: usize,
    /// Shard grid columns.
    pub grid_c: usize,
    /// Checksum columns + reduction verification on/off.
    pub checksum: bool,
    /// Detection-threshold factor (scaled by `sqrt(shard cells)`; see
    /// [`crate::vmm::sharded`]).
    pub threshold: f64,
    /// Gross-fault injection rate per `(sample, shard)` cycle
    /// (`0.0` = no injection).
    pub fault_rate: f64,
    /// Stuck differential level of injected faults, in `[-1, 1]`.
    pub fault_level: f64,
    /// Root seed of the fault stream.
    pub fault_seed: u64,
}

impl Default for ShardSettings {
    fn default() -> Self {
        Self {
            grid_r: 2,
            grid_c: 2,
            checksum: true,
            threshold: crate::vmm::DEFAULT_CHECKSUM_THRESHOLD,
            fault_rate: 0.0,
            fault_level: 1.0,
            fault_seed: 0x5A4D_4544, // "SHRD"-ish tag, independent of the workload seed
        }
    }
}

/// Telemetry settings (`--obs`, the `[obs]` TOML section, and the
/// `meliso metrics` command).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ObsSettings {
    /// Enable the global metrics registry and stage tracing for the
    /// run ([`crate::obs`]).  Off by default: the disabled path is one
    /// atomic load per instrumentation site.
    pub enabled: bool,
}

/// Fully resolved run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub population: usize,
    pub seed: u64,
    pub engine: EngineKind,
    pub out_dir: PathBuf,
    /// Total host worker budget (0 = one per CPU); the coordinator
    /// divides it by the engine fan-out.
    pub threads: usize,
    /// Engine-level fan-out for the native/tiled engines (0 = one per
    /// CPU, 1 = sequential engine).
    pub engine_threads: usize,
    /// Logical workload geometry (rows = cols = size) for `bench` and
    /// size-parameterized runs; the paper protocol is 32.
    pub size: usize,
    /// Physical tile geometry of the tiled engine (square tiles).
    pub tile: usize,
    /// Error-mitigation pipeline applied to the engine and the solver
    /// operators (`--mitigation diff,slice:2,avg:4,cal`; identity by
    /// default).
    pub mitigation: MitigationConfig,
    /// Layered-inference settings (`meliso infer`).
    pub pipeline: PipelineSettings,
    /// Sharded-engine settings (`--engine sharded`).
    pub shard: ShardSettings,
    /// Request-serving settings (`meliso serve-bench`).
    pub serve: ServeSettings,
    /// Overload / admission-control settings (`--overload` /
    /// `[overload]`).
    pub overload: OverloadSettings,
    /// Fleet-fabric settings (`meliso fleet-bench`).
    pub fleet: FleetSettings,
    /// Telemetry settings (`--obs` / `[obs]`).
    pub obs: ObsSettings,
    pub quiet: bool,
    /// Optional custom device overriding the presets.
    pub custom_device: Option<DeviceParams>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            population: crate::PAPER_POPULATION,
            seed: 0x4D45_4C49_534F, // "MELISO"
            engine: EngineKind::Native,
            out_dir: PathBuf::from("out"),
            threads: 0,
            engine_threads: 0,
            size: crate::ROWS,
            tile: crate::ROWS,
            mitigation: MitigationConfig::NONE,
            pipeline: PipelineSettings::default(),
            shard: ShardSettings::default(),
            serve: ServeSettings::default(),
            overload: OverloadSettings::default(),
            fleet: FleetSettings::default(),
            obs: ObsSettings::default(),
            quiet: false,
            custom_device: None,
        }
    }
}

impl RunConfig {
    pub fn parallelism(&self) -> Parallelism {
        if self.threads == 0 {
            Parallelism::Auto
        } else {
            Parallelism::Fixed(self.threads)
        }
    }

    /// Engine-level parallelism for engines that fan internally,
    /// capped by the total `threads` budget: `--threads 2` with an
    /// auto-fanning engine must not light up every CPU.
    pub fn engine_parallelism(&self) -> Parallelism {
        let engine = if self.engine_threads == 0 {
            usize::MAX
        } else {
            self.engine_threads
        };
        let budget = if self.threads == 0 { usize::MAX } else { self.threads };
        match engine.min(budget) {
            usize::MAX => Parallelism::Auto,
            n => Parallelism::Fixed(n),
        }
    }

    /// Load from a TOML file and merge over the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(v) = doc.get("", "population") {
            cfg.population = v
                .as_i64()
                .filter(|&n| n > 0)
                .ok_or_else(|| Error::Config("population must be a positive int".into()))?
                as usize;
        }
        if let Some(v) = doc.get("", "seed") {
            cfg.seed = v
                .as_i64()
                .ok_or_else(|| Error::Config("seed must be an int".into()))?
                as u64;
        }
        if let Some(v) = doc.get("", "engine") {
            cfg.engine = EngineKind::parse(
                v.as_str()
                    .ok_or_else(|| Error::Config("engine must be a string".into()))?,
            )?;
        }
        if let Some(v) = doc.get("", "out") {
            cfg.out_dir = PathBuf::from(
                v.as_str()
                    .ok_or_else(|| Error::Config("out must be a string".into()))?,
            );
        }
        if let Some(v) = doc.get("", "threads") {
            cfg.threads = v
                .as_i64()
                .filter(|&n| n >= 0)
                .ok_or_else(|| Error::Config("threads must be a non-negative int".into()))?
                as usize;
        }
        if let Some(v) = doc.get("", "engine_threads") {
            cfg.engine_threads = v
                .as_i64()
                .filter(|&n| n >= 0)
                .ok_or_else(|| {
                    Error::Config("engine_threads must be a non-negative int".into())
                })? as usize;
        }
        if let Some(v) = doc.get("", "size") {
            cfg.size = v
                .as_i64()
                .filter(|&n| n > 0)
                .ok_or_else(|| Error::Config("size must be a positive int".into()))?
                as usize;
        }
        if let Some(v) = doc.get("", "tile") {
            cfg.tile = v
                .as_i64()
                .filter(|&n| n > 0)
                .ok_or_else(|| Error::Config("tile must be a positive int".into()))?
                as usize;
        }
        if let Some(v) = doc.get("", "mitigation") {
            cfg.mitigation = MitigationConfig::parse(
                v.as_str()
                    .ok_or_else(|| Error::Config("mitigation must be a string".into()))?,
            )?;
        }
        if let Some(v) = doc.get("", "quiet") {
            cfg.quiet = v
                .as_bool()
                .ok_or_else(|| Error::Config("quiet must be a bool".into()))?;
        }
        if let Some(v) = doc.get("pipeline", "depth") {
            cfg.pipeline.depth = v
                .as_i64()
                .filter(|&n| n > 0)
                .ok_or_else(|| Error::Config("pipeline.depth must be a positive int".into()))?
                as usize;
        }
        if let Some(v) = doc.get("pipeline", "activation") {
            cfg.pipeline.activation = Activation::parse(
                v.as_str()
                    .ok_or_else(|| Error::Config("pipeline.activation must be a string".into()))?,
            )?;
        }
        if let Some(v) = doc.get("pipeline", "layers") {
            cfg.pipeline.dims = Some(parse_dims(
                v.as_str()
                    .ok_or_else(|| Error::Config("pipeline.layers must be a string".into()))?,
            )?);
        }
        if let Some(v) = doc.get("pipeline", "deploy") {
            cfg.pipeline.deploy = v
                .as_bool()
                .ok_or_else(|| Error::Config("pipeline.deploy must be a bool".into()))?;
        }
        {
            // Positive-int [serve] keys share one parse shape.
            let positive = |doc: &TomlDoc, key: &str| -> Result<Option<usize>> {
                match doc.get("serve", key) {
                    None => Ok(None),
                    Some(v) => v
                        .as_i64()
                        .filter(|&n| n > 0)
                        .map(|n| Some(n as usize))
                        .ok_or_else(|| {
                            Error::Config(format!("serve.{key} must be a positive int"))
                        }),
                }
            };
            let s = &mut cfg.serve;
            if let Some(n) = positive(&doc, "clients")? {
                s.clients = n;
            }
            if let Some(n) = positive(&doc, "requests")? {
                s.requests = n;
            }
            if let Some(n) = positive(&doc, "models")? {
                s.models = n;
            }
            if let Some(n) = positive(&doc, "queue")? {
                s.queue = n;
            }
            if let Some(n) = positive(&doc, "batch_max")? {
                s.batch_max = n;
            }
            if let Some(n) = positive(&doc, "workers")? {
                s.workers = n;
            }
            if let Some(n) = positive(&doc, "cache_capacity")? {
                s.cache_capacity = n;
            }
        }
        if let Some(v) = doc.get("serve", "window_us") {
            cfg.serve.window_us = v
                .as_i64()
                .filter(|&n| n >= 0)
                .ok_or_else(|| Error::Config("serve.window_us must be a non-negative int".into()))?
                as u64;
        }
        if let Some(v) = doc.get("serve", "cache") {
            cfg.serve.cache = v
                .as_bool()
                .ok_or_else(|| Error::Config("serve.cache must be a bool".into()))?;
        }
        if let Some(v) = doc.get("overload", "factor") {
            cfg.overload.factor = v
                .as_f64()
                .filter(|f| f.is_finite() && *f >= 0.0)
                .ok_or_else(|| {
                    Error::Config("overload.factor must be a non-negative number".into())
                })?;
        }
        if let Some(v) = doc.get("overload", "deadline_us") {
            cfg.overload.deadline_us = v
                .as_i64()
                .filter(|&n| n >= 0)
                .ok_or_else(|| {
                    Error::Config("overload.deadline_us must be a non-negative int".into())
                })? as u64;
        }
        if let Some(v) = doc.get("overload", "shed") {
            cfg.overload.shed = v
                .as_bool()
                .ok_or_else(|| Error::Config("overload.shed must be a bool".into()))?;
        }
        if let Some(v) = doc.get("shard", "grid") {
            let (r, c) = parse_grid(
                v.as_str()
                    .ok_or_else(|| Error::Config("shard.grid must be a string".into()))?,
            )?;
            cfg.shard.grid_r = r;
            cfg.shard.grid_c = c;
        }
        if let Some(v) = doc.get("shard", "checksum") {
            cfg.shard.checksum = v
                .as_bool()
                .ok_or_else(|| Error::Config("shard.checksum must be a bool".into()))?;
        }
        if let Some(v) = doc.get("shard", "threshold") {
            cfg.shard.threshold = v
                .as_f64()
                .filter(|t| t.is_finite() && *t > 0.0)
                .ok_or_else(|| Error::Config("shard.threshold must be positive".into()))?;
        }
        if let Some(v) = doc.get("shard", "fault_rate") {
            cfg.shard.fault_rate = v
                .as_f64()
                .filter(|r| (0.0..=1.0).contains(r))
                .ok_or_else(|| Error::Config("shard.fault_rate must be in [0, 1]".into()))?;
        }
        if let Some(v) = doc.get("shard", "fault_level") {
            cfg.shard.fault_level = v
                .as_f64()
                .filter(|l| (-1.0..=1.0).contains(l))
                .ok_or_else(|| Error::Config("shard.fault_level must be in [-1, 1]".into()))?;
        }
        if let Some(v) = doc.get("shard", "fault_seed") {
            cfg.shard.fault_seed = v
                .as_i64()
                .ok_or_else(|| Error::Config("shard.fault_seed must be an int".into()))?
                as u64;
        }
        {
            // Positive-int [fleet] keys share the [serve] parse shape.
            let positive = |doc: &TomlDoc, key: &str| -> Result<Option<usize>> {
                match doc.get("fleet", key) {
                    None => Ok(None),
                    Some(v) => v
                        .as_i64()
                        .filter(|&n| n > 0)
                        .map(|n| Some(n as usize))
                        .ok_or_else(|| {
                            Error::Config(format!("fleet.{key} must be a positive int"))
                        }),
                }
            };
            if let Some(n) = positive(&doc, "nodes")? {
                cfg.fleet.nodes = n;
            }
            if let Some(n) = positive(&doc, "replication")? {
                cfg.fleet.replication = n;
            }
        }
        if let Some(v) = doc.get("fleet", "fail_rate") {
            cfg.fleet.fail_rate = v
                .as_f64()
                .filter(|r| (0.0..=1.0).contains(r))
                .ok_or_else(|| Error::Config("fleet.fail_rate must be in [0, 1]".into()))?;
        }
        if let Some(v) = doc.get("fleet", "fail_seed") {
            cfg.fleet.fail_seed = v
                .as_i64()
                .ok_or_else(|| Error::Config("fleet.fail_seed must be an int".into()))?
                as u64;
        }
        if let Some(v) = doc.get("fleet", "transport") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("fleet.transport must be a string".into()))?;
            cfg.fleet.transport = FleetTransport::parse(s)
                .map_err(|_| Error::Config(format!("fleet.transport: unknown wire '{s}'")))?;
        }
        {
            // Positive-ms socket knobs share the int parse shape.
            let positive_ms = |doc: &TomlDoc, key: &str| -> Result<Option<u64>> {
                match doc.get("fleet", key) {
                    None => Ok(None),
                    Some(v) => v
                        .as_i64()
                        .filter(|&n| n > 0)
                        .map(|n| Some(n as u64))
                        .ok_or_else(|| {
                            Error::Config(format!("fleet.{key} must be a positive int"))
                        }),
                }
            };
            if let Some(ms) = positive_ms(&doc, "connect_timeout_ms")? {
                cfg.fleet.connect_timeout_ms = ms;
            }
            if let Some(ms) = positive_ms(&doc, "read_timeout_ms")? {
                cfg.fleet.read_timeout_ms = ms;
            }
        }
        if let Some(v) = doc.get("fleet", "retries") {
            cfg.fleet.retries = v
                .as_i64()
                .filter(|&n| n >= 0)
                .ok_or_else(|| Error::Config("fleet.retries must be a non-negative int".into()))?
                as u32;
        }
        if let Some(v) = doc.get("obs", "enabled") {
            cfg.obs.enabled = v
                .as_bool()
                .ok_or_else(|| Error::Config("obs.enabled must be a bool".into()))?;
        }
        if doc.tables.contains_key("device") {
            cfg.custom_device = Some(parse_device(&doc)?);
        }
        Ok(cfg)
    }
}

fn parse_device(doc: &TomlDoc) -> Result<DeviceParams> {
    let get = |key: &str, default: f64| -> Result<f64> {
        match doc.get("device", key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| Error::Config(format!("device.{key} must be numeric"))),
        }
    };
    let params = DeviceParams {
        states: get("states", 64.0)?,
        memory_window: get("memory_window", 10.0)?,
        nu_ltp: get("nu_ltp", 0.0)?,
        nu_ltd: get("nu_ltd", 0.0)?,
        sigma_c2c: get("sigma_c2c", 0.0)?,
        k_c2c: get("k_c2c", DEFAULT_K_C2C)?,
        k_base: get("k_base", DEFAULT_K_BASE)?,
        s_exp: get("s_exp", DEFAULT_S_EXP)?,
    };
    params.validate().map_err(Error::Config)?;
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_protocol() {
        let c = RunConfig::default();
        assert_eq!(c.population, 1000);
        assert_eq!(c.engine, EngineKind::Native);
    }

    #[test]
    fn parse_full_document() {
        let c = RunConfig::from_toml(
            r#"
population = 200
seed = 7
engine = "software"
out = "results"
threads = 4
quiet = true

[device]
states = 97
memory_window = 12.5
nu_ltp = 2.4
nu_ltd = -4.88
sigma_c2c = 0.035
"#,
        )
        .unwrap();
        assert_eq!(c.population, 200);
        assert_eq!(c.seed, 7);
        assert_eq!(c.engine, EngineKind::Software);
        assert_eq!(c.out_dir, PathBuf::from("results"));
        assert_eq!(c.threads, 4);
        assert!(c.quiet);
        let d = c.custom_device.unwrap();
        assert_eq!(d.states, 97.0);
        assert_eq!(d.nu_ltd, -4.88);
        // Calibration defaults preserved.
        assert_eq!(d.k_c2c, DEFAULT_K_C2C);
    }

    #[test]
    fn rejects_invalid_values() {
        assert!(RunConfig::from_toml("population = -5\n").is_err());
        assert!(RunConfig::from_toml("engine = \"quantum\"\n").is_err());
        assert!(RunConfig::from_toml("[device]\nmemory_window = 0.5\n").is_err());
    }

    #[test]
    fn engine_kind_parse() {
        assert_eq!(EngineKind::parse("XLA").unwrap(), EngineKind::Xla);
        assert_eq!(EngineKind::parse("tiled").unwrap(), EngineKind::Tiled);
        assert_eq!(EngineKind::parse("sharded").unwrap(), EngineKind::Sharded);
        assert!(EngineKind::parse("gpu").is_err());
        assert_eq!(EngineKind::Native.name(), "native");
        assert_eq!(EngineKind::Tiled.name(), "tiled");
        assert_eq!(EngineKind::Sharded.name(), "sharded");
    }

    #[test]
    fn unknown_engine_error_lists_every_engine() {
        // The failure must be actionable: every engine name, including
        // the sharded engine, in one message.
        let msg = EngineKind::parse("warp").unwrap_err().to_string();
        for name in EngineKind::names() {
            assert!(msg.contains(name), "missing '{name}' in: {msg}");
        }
        assert!(msg.contains("warp"), "{msg}");
        // The list itself covers the full enum.
        assert_eq!(EngineKind::names().len(), EngineKind::ALL.len());
        assert!(EngineKind::names().contains(&"sharded"));
    }

    #[test]
    fn shard_section_parses() {
        let c = RunConfig::from_toml(
            "engine = \"sharded\"\n\
             [shard]\n\
             grid = \"4x2\"\n\
             checksum = false\n\
             threshold = 1.25\n\
             fault_rate = 0.1\n\
             fault_level = -1.0\n\
             fault_seed = 99\n",
        )
        .unwrap();
        assert_eq!(c.engine, EngineKind::Sharded);
        assert_eq!((c.shard.grid_r, c.shard.grid_c), (4, 2));
        assert!(!c.shard.checksum);
        assert_eq!(c.shard.threshold, 1.25);
        assert_eq!(c.shard.fault_rate, 0.1);
        assert_eq!(c.shard.fault_level, -1.0);
        assert_eq!(c.shard.fault_seed, 99);
        // Defaults.
        let d = RunConfig::default().shard;
        assert_eq!((d.grid_r, d.grid_c), (2, 2));
        assert!(d.checksum);
        assert_eq!(d.fault_rate, 0.0);
        // Rejections.
        assert!(RunConfig::from_toml("[shard]\ngrid = \"0x2\"\n").is_err());
        assert!(RunConfig::from_toml("[shard]\ngrid = 4\n").is_err());
        assert!(RunConfig::from_toml("[shard]\nthreshold = 0\n").is_err());
        assert!(RunConfig::from_toml("[shard]\nfault_rate = 1.5\n").is_err());
        assert!(RunConfig::from_toml("[shard]\nfault_level = 2.0\n").is_err());
    }

    #[test]
    fn serve_section_parses() {
        let c = RunConfig::from_toml(
            "[serve]\n\
             clients = 12\n\
             requests = 100\n\
             models = 3\n\
             queue = 64\n\
             batch_max = 16\n\
             window_us = 0\n\
             workers = 4\n\
             cache = false\n\
             cache_capacity = 5\n",
        )
        .unwrap();
        assert_eq!(c.serve.clients, 12);
        assert_eq!(c.serve.requests, 100);
        assert_eq!(c.serve.models, 3);
        assert_eq!(c.serve.queue, 64);
        assert_eq!(c.serve.batch_max, 16);
        assert_eq!(c.serve.window_us, 0);
        assert_eq!(c.serve.workers, 4);
        assert!(!c.serve.cache);
        assert_eq!(c.serve.cache_capacity, 5);
        // Defaults.
        let d = RunConfig::default().serve;
        assert_eq!(d.clients, 8);
        assert_eq!(d.batch_max, 32);
        assert!(d.cache);
        // Rejections.
        assert!(RunConfig::from_toml("[serve]\nclients = 0\n").is_err());
        assert!(RunConfig::from_toml("[serve]\nrequests = -4\n").is_err());
        assert!(RunConfig::from_toml("[serve]\nwindow_us = -1\n").is_err());
        assert!(RunConfig::from_toml("[serve]\ncache = 3\n").is_err());
    }

    #[test]
    fn overload_section_parses() {
        let c = RunConfig::from_toml(
            "[overload]\n\
             factor = 2.5\n\
             deadline_us = 400\n\
             shed = true\n",
        )
        .unwrap();
        assert_eq!(c.overload.factor, 2.5);
        assert_eq!(c.overload.deadline_us, 400);
        assert!(c.overload.shed);
        // Defaults: everything off — the closed-loop, backpressure-only
        // configuration.
        let d = RunConfig::default().overload;
        assert_eq!(d.factor, 0.0);
        assert_eq!(d.deadline_us, 0);
        assert!(!d.shed);
        // Rejections.
        assert!(RunConfig::from_toml("[overload]\nfactor = -1.0\n").is_err());
        assert!(RunConfig::from_toml("[overload]\ndeadline_us = -5\n").is_err());
        assert!(RunConfig::from_toml("[overload]\nshed = 1\n").is_err());
    }

    #[test]
    fn fleet_section_parses() {
        let c = RunConfig::from_toml(
            "[fleet]\n\
             nodes = 4\n\
             replication = 2\n\
             fail_rate = 0.5\n\
             fail_seed = 13\n\
             transport = \"socket\"\n\
             connect_timeout_ms = 250\n\
             read_timeout_ms = 2000\n\
             retries = 5\n",
        )
        .unwrap();
        assert_eq!(c.fleet.nodes, 4);
        assert_eq!(c.fleet.replication, 2);
        assert_eq!(c.fleet.fail_rate, 0.5);
        assert_eq!(c.fleet.fail_seed, 13);
        assert_eq!(c.fleet.transport, FleetTransport::Socket);
        assert_eq!(c.fleet.connect_timeout_ms, 250);
        assert_eq!(c.fleet.read_timeout_ms, 2000);
        assert_eq!(c.fleet.retries, 5);
        // Defaults.
        let d = RunConfig::default().fleet;
        assert_eq!(d.nodes, 2);
        assert_eq!(d.replication, 1);
        assert_eq!(d.fail_rate, 0.0);
        assert_eq!(d.transport, FleetTransport::InProcess);
        assert_eq!(d.connect_timeout_ms, 1_000);
        assert_eq!(d.read_timeout_ms, 5_000);
        assert_eq!(d.retries, 3);
        // The transport names round-trip through the parser.
        for t in [FleetTransport::InProcess, FleetTransport::Socket] {
            assert_eq!(FleetTransport::parse(t.name()).unwrap(), t);
        }
        // Rejections.
        assert!(RunConfig::from_toml("[fleet]\nnodes = 0\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\nreplication = -1\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\nfail_rate = 1.5\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\nfail_seed = \"x\"\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\ntransport = \"carrier-pigeon\"\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\nconnect_timeout_ms = 0\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\nread_timeout_ms = -4\n").is_err());
        assert!(RunConfig::from_toml("[fleet]\nretries = -1\n").is_err());
    }

    #[test]
    fn obs_section_parses() {
        let c = RunConfig::from_toml("[obs]\nenabled = true\n").unwrap();
        assert!(c.obs.enabled);
        assert!(!RunConfig::default().obs.enabled, "telemetry is opt-in");
        assert!(RunConfig::from_toml("[obs]\nenabled = 1\n").is_err());
    }

    #[test]
    fn pipeline_deploy_parses() {
        let c = RunConfig::from_toml("[pipeline]\ndeploy = true\n").unwrap();
        assert!(c.pipeline.deploy);
        assert!(!RunConfig::default().pipeline.deploy);
        assert!(RunConfig::from_toml("[pipeline]\ndeploy = 1\n").is_err());
    }

    #[test]
    fn mitigation_key_parses() {
        let c = RunConfig::from_toml("mitigation = \"diff,slice:2,avg:4,cal\"\n").unwrap();
        assert!(c.mitigation.differential && c.mitigation.calibrate);
        assert_eq!(c.mitigation.slices, 2);
        assert_eq!(c.mitigation.replicas, 4);
        assert!(RunConfig::default().mitigation.is_noop());
        assert!(RunConfig::from_toml("mitigation = \"frob\"\n").is_err());
        assert!(RunConfig::from_toml("mitigation = 3\n").is_err());
    }

    #[test]
    fn pipeline_section_parses() {
        let c = RunConfig::from_toml(
            "[pipeline]\ndepth = 6\nactivation = \"tanh\"\nlayers = \"32x48x10\"\n",
        )
        .unwrap();
        assert_eq!(c.pipeline.depth, 6);
        assert_eq!(c.pipeline.activation, Activation::Tanh);
        assert_eq!(c.pipeline.dims, Some(vec![32, 48, 10]));
        // Defaults.
        let d = RunConfig::default().pipeline;
        assert_eq!(d.depth, 4);
        assert_eq!(d.activation, Activation::Relu);
        assert_eq!(d.dims, None);
        // Rejections.
        assert!(RunConfig::from_toml("[pipeline]\ndepth = 0\n").is_err());
        assert!(RunConfig::from_toml("[pipeline]\nactivation = \"softmax\"\n").is_err());
        assert!(RunConfig::from_toml("[pipeline]\nlayers = \"32\"\n").is_err());
        assert!(RunConfig::from_toml("[pipeline]\nlayers = 32\n").is_err());
    }

    #[test]
    fn tiled_and_parallelism_keys_parse() {
        let c = RunConfig::from_toml(
            "engine = \"tiled\"\nsize = 128\ntile = 64\nengine_threads = 2\n",
        )
        .unwrap();
        assert_eq!(c.engine, EngineKind::Tiled);
        assert_eq!(c.size, 128);
        assert_eq!(c.tile, 64);
        assert_eq!(c.engine_parallelism(), Parallelism::Fixed(2));
        assert!(RunConfig::from_toml("size = 0\n").is_err());
        assert!(RunConfig::from_toml("tile = -3\n").is_err());
    }

    #[test]
    fn total_budget_caps_engine_fanout() {
        // --threads 2 with an auto engine: the engine fan is capped at
        // the budget instead of lighting up every CPU.
        let mut c = RunConfig { threads: 2, ..RunConfig::default() };
        assert_eq!(c.engine_parallelism(), Parallelism::Fixed(2));
        // Explicit engine fan larger than the budget is capped too.
        c.engine_threads = 8;
        assert_eq!(c.engine_parallelism(), Parallelism::Fixed(2));
        // No budget -> the engine keeps its own setting.
        c.threads = 0;
        assert_eq!(c.engine_parallelism(), Parallelism::Fixed(8));
        c.engine_threads = 0;
        assert_eq!(c.engine_parallelism(), Parallelism::Auto);
    }

    #[test]
    fn parallelism_mapping() {
        let mut c = RunConfig::default();
        assert_eq!(c.parallelism(), Parallelism::Auto);
        c.threads = 3;
        assert_eq!(c.parallelism(), Parallelism::Fixed(3));
    }
}
