//! Deterministic seeded teacher networks: layer weights, input
//! populations, and per-(sample, layer) programming-noise streams.
//!
//! Every stream is keyed by `(network seed, purpose tag, index)`
//! through SplitMix64, mirroring the per-chunk child-seed discipline of
//! [`crate::coordinator::WorkloadSpec`]: weights, inputs, and noise are
//! pure functions of the spec, independent of chunking, scheduling
//! order, and thread count.  That is what makes the pipeline's layer
//! trace bit-reproducible.

use crate::coordinator::workload::{EntryDist, InputSpec};
use crate::crossbar::array::ProgramNoise;
use crate::error::{Error, Result};
use crate::mitigation::MitigationConfig;
use crate::util::rng::{splitmix64, Xoshiro256};
use crate::vmm::engine::VmmBatch;
use crate::vmm::program::ProgramSpec;

use super::{Activation, LayerSpec};

/// Stream tags separating the weight, input, and noise populations of
/// one network seed (arbitrary distinct constants).
const TAG_WEIGHTS: u64 = 0x5745_4947_4854; // "WEIGHT"
const TAG_INPUTS: u64 = 0x494E_5055_54; // "INPUT"
const TAG_NOISE: u64 = 0x4E4F_4953_45; // "NOISE"

/// Derive an independent stream seed for `(seed, tag)`.
fn stream_seed(seed: u64, tag: u64) -> u64 {
    let mut t = seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut t)
}

/// A complete layered-network specification: the layer chain, the
/// input population, and the seed every deterministic stream derives
/// from.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    pub layers: Vec<LayerSpec>,
    /// Number of input samples run through the network.
    pub population: usize,
    /// Distribution of the layer-0 input entries.
    pub inputs: EntryDist,
    pub seed: u64,
}

impl NetworkSpec {
    /// A uniform `depth`-layer, `width`-wide network (every crossbar is
    /// `width x width`).
    ///
    /// # Panics
    ///
    /// Panics when `depth` or `width` is 0 — this is the infallible
    /// convenience constructor for literal shapes; use
    /// [`Self::from_dims`] for fallible construction from user input.
    pub fn uniform(depth: usize, width: usize, activation: Activation, seed: u64) -> Self {
        assert!(depth >= 1, "network depth must be >= 1 (use from_dims for fallible input)");
        assert!(width >= 1, "network width must be >= 1 (use from_dims for fallible input)");
        let dims = vec![width; depth + 1];
        Self::from_dims(&dims, activation, seed)
            .expect("uniform dims of a positive depth and width are a valid chain")
    }

    /// Build from a dimension chain `d_0, ..., d_L` (layer `k` is a
    /// `d_k -> d_{k+1}` crossbar); see [`super::parse_dims`].
    pub fn from_dims(dims: &[usize], activation: Activation, seed: u64) -> Result<Self> {
        if dims.len() < 2 {
            return Err(Error::Config(
                "a network needs at least two dimensions (input x output)".into(),
            ));
        }
        if let Some(&bad) = dims.iter().find(|&&d| d == 0) {
            return Err(Error::Config(format!("layer dimension must be > 0, got {bad}")));
        }
        let layers = dims
            .windows(2)
            .map(|w| LayerSpec::new(w[0], w[1], activation))
            .collect();
        Ok(Self {
            layers,
            population: 64,
            inputs: EntryDist::Uniform { lo: 0.0, hi: 1.0 },
            seed,
        })
    }

    pub fn with_population(mut self, population: usize) -> Self {
        self.population = population;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Apply one mitigation pipeline to every layer.
    pub fn with_mitigation(mut self, cfg: MitigationConfig) -> Self {
        for l in &mut self.layers {
            l.mitigation = Some(cfg);
        }
        self
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    pub fn input_dim(&self) -> usize {
        self.layers.first().map(|l| l.rows).unwrap_or(0)
    }

    pub fn output_dim(&self) -> usize {
        self.layers.last().map(|l| l.cols).unwrap_or(0)
    }

    /// Validate the layer chain (non-empty, dims connect, dims > 0).
    pub fn validate(&self) -> Result<()> {
        if self.layers.is_empty() {
            return Err(Error::Config("network has no layers".into()));
        }
        if self.population == 0 {
            return Err(Error::Config("network population must be > 0".into()));
        }
        for (k, l) in self.layers.iter().enumerate() {
            if l.rows == 0 || l.cols == 0 {
                return Err(Error::Config(format!(
                    "layer {k}: dimensions must be > 0 (got {}x{})",
                    l.rows, l.cols
                )));
            }
            if !l.requant.is_finite() || l.requant <= 0.0 {
                return Err(Error::Config(format!(
                    "layer {k}: requant scale must be finite and > 0, got {}",
                    l.requant
                )));
            }
        }
        for (k, w) in self.layers.windows(2).enumerate() {
            if w[0].cols != w[1].rows {
                return Err(Error::Config(format!(
                    "layer {k} outputs {} columns but layer {} expects {} rows",
                    w[0].cols,
                    k + 1,
                    w[1].rows
                )));
            }
        }
        Ok(())
    }

    /// Human-readable dimension chain, e.g. `"32x32x16"`.
    pub fn dims_label(&self) -> String {
        let mut parts = Vec::with_capacity(self.depth() + 1);
        parts.push(self.input_dim().to_string());
        for l in &self.layers {
            parts.push(l.cols.to_string());
        }
        parts.join("x")
    }

    /// The input population generator (lives in the coordinator, like
    /// every other population of the framework).
    pub fn input_spec(&self) -> InputSpec {
        InputSpec {
            dim: self.input_dim(),
            population: self.population,
            dist: self.inputs,
            seed: stream_seed(self.seed, TAG_INPUTS),
        }
    }

    /// Teacher weights of layer `k`, row-major `(rows, cols)` in
    /// `[-1, 1]` — a pure function of `(seed, k)`.
    pub fn layer_weights(&self, k: usize) -> Vec<f32> {
        let l = &self.layers[k];
        let mut rng =
            Xoshiro256::seed_from_u64(stream_seed(self.seed, TAG_WEIGHTS)).child(k as u64);
        let mut w = vec![0.0f32; l.rows * l.cols];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        w
    }

    /// Program-once spec of layer `k` for deployed serving
    /// ([`crate::pipeline::PipelineOptions::deploy`]): the teacher
    /// weights under the `(sample 0, layer k)` programming-noise
    /// stream.  A deployed fabric programs **one** physical instance
    /// per layer; pinning it to the population's sample-0 Monte-Carlo
    /// draw keeps deployed traces reproducible and bit-comparable to
    /// the per-sample path's first sample.
    pub fn layer_program_spec(&self, k: usize) -> ProgramSpec {
        let l = &self.layers[k];
        let cells = l.rows * l.cols;
        let noise_root = Xoshiro256::seed_from_u64(stream_seed(self.seed, TAG_NOISE));
        let mut rng = noise_root.child(0).child(k as u64);
        // One contiguous fill, split into channels — bitwise the same
        // packing as `layer_batch_with_weights` uses for sample 0.
        let mut z = vec![0.0f32; 3 * cells];
        rng.fill_normal_f32(&mut z);
        let noise = ProgramNoise {
            z0: z[..cells].to_vec(),
            z1: z[cells..2 * cells].to_vec(),
            z2: z[2 * cells..].to_vec(),
        };
        // Cache-identity label: unique per (network noise stream,
        // layer).
        let mut tag =
            stream_seed(self.seed, TAG_NOISE) ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ProgramSpec::with_noise(l.rows, l.cols, self.layer_weights(k), noise, splitmix64(&mut tag))
    }

    /// Build the engine batch for layer `k` over the global sample
    /// range `[start, start+len)`, with per-sample inputs `x`
    /// (row-major `(len, rows)`).  Weights are the layer's teacher
    /// weights replicated per sample; the three noise planes are drawn
    /// from the `(seed, sample, layer)` stream — per-sample Monte-Carlo
    /// programming instances, independent of chunking.
    pub fn layer_batch(&self, k: usize, start: usize, len: usize, x: &[f32]) -> VmmBatch {
        self.layer_batch_with_weights(k, start, len, x, &self.layer_weights(k))
    }

    /// [`Self::layer_batch`] with the layer's teacher weights supplied
    /// by the caller (the runner generates each matrix once and shares
    /// it across chunks; `w` must equal `self.layer_weights(k)`).
    pub fn layer_batch_with_weights(
        &self,
        k: usize,
        start: usize,
        len: usize,
        x: &[f32],
        w: &[f32],
    ) -> VmmBatch {
        let l = &self.layers[k];
        let (r, c) = (l.rows, l.cols);
        assert_eq!(x.len(), len * r, "layer {k}: input length mismatch");
        let cells = r * c;
        assert_eq!(w.len(), cells, "layer {k}: weight length mismatch");
        let mut vb = VmmBatch::zeros(len, r, c);
        vb.x.copy_from_slice(x);
        let noise_root = Xoshiro256::seed_from_u64(stream_seed(self.seed, TAG_NOISE));
        for s in 0..len {
            vb.w[s * cells..(s + 1) * cells].copy_from_slice(w);
            let mut rng = noise_root.child((start + s) as u64).child(k as u64);
            let zbase = s * 3 * cells;
            rng.fill_normal_f32(&mut vb.z[zbase..zbase + 3 * cells]);
        }
        vb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_and_dims_builders() {
        let n = NetworkSpec::uniform(4, 32, Activation::Relu, 9);
        assert_eq!(n.depth(), 4);
        assert_eq!(n.input_dim(), 32);
        assert_eq!(n.output_dim(), 32);
        assert_eq!(n.dims_label(), "32x32x32x32x32");
        n.validate().unwrap();

        let m = NetworkSpec::from_dims(&[32, 48, 10], Activation::Tanh, 9).unwrap();
        assert_eq!(m.depth(), 2);
        assert_eq!(m.layers[0].cols, 48);
        assert_eq!(m.layers[1].rows, 48);
        assert_eq!(m.dims_label(), "32x48x10");
        m.validate().unwrap();

        assert!(NetworkSpec::from_dims(&[32], Activation::Relu, 9).is_err());
        assert!(NetworkSpec::from_dims(&[32, 0], Activation::Relu, 9).is_err());
    }

    #[test]
    fn validate_catches_broken_chains() {
        let mut n = NetworkSpec::uniform(2, 16, Activation::Relu, 1);
        n.layers[1].rows = 8; // breaks the 16 -> 16 chain
        assert!(n.validate().is_err());
        let mut p = NetworkSpec::uniform(1, 16, Activation::Relu, 1);
        p.population = 0;
        assert!(p.validate().is_err());
        let mut q = NetworkSpec::uniform(1, 16, Activation::Relu, 1);
        q.layers[0].requant = 0.0;
        assert!(q.validate().is_err());
    }

    #[test]
    fn weights_are_deterministic_per_layer_and_seed() {
        let n = NetworkSpec::uniform(3, 16, Activation::Relu, 42);
        assert_eq!(n.layer_weights(0), n.layer_weights(0));
        assert_ne!(n.layer_weights(0), n.layer_weights(1));
        let other = n.clone().with_seed(43);
        assert_ne!(n.layer_weights(0), other.layer_weights(0));
        assert!(n.layer_weights(2).iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn streams_are_disjoint() {
        // The weight, input, and noise streams of one seed must not
        // alias (a collision would correlate weights with noise).
        let n = NetworkSpec::uniform(1, 8, Activation::Identity, 5).with_population(1);
        let w = n.layer_weights(0);
        let x = n.input_spec().chunk(0, 1);
        let b = n.layer_batch(0, 0, 1, &x);
        assert_ne!(&w[..8], &b.z[..8]);
        assert_ne!(&x[..], &b.z[..8]);
    }

    #[test]
    fn layer_batch_is_chunk_invariant() {
        let n = NetworkSpec::uniform(2, 8, Activation::Relu, 7).with_population(6);
        let x = n.input_spec().chunk(0, 6);
        let whole = n.layer_batch(1, 0, 6, &x);
        for s in 0..6 {
            let one = n.layer_batch(1, s, 1, &x[s * 8..(s + 1) * 8]);
            assert_eq!(whole.w_of(s), one.w_of(0));
            assert_eq!(whole.x_of(s), one.x_of(0));
            for ch in 0..3 {
                assert_eq!(whole.z_of(s, ch), one.z_of(0, ch), "sample {s} ch {ch}");
            }
        }
        whole.check().unwrap();
    }

    #[test]
    fn noise_differs_across_layers_and_samples() {
        let n = NetworkSpec::uniform(2, 8, Activation::Relu, 7).with_population(2);
        let x = n.input_spec().chunk(0, 2);
        let l0 = n.layer_batch(0, 0, 2, &x);
        let l1 = n.layer_batch(1, 0, 2, &x);
        assert_ne!(l0.z_of(0, 0), l1.z_of(0, 0));
        assert_ne!(l0.z_of(0, 0), l0.z_of(1, 0));
    }

    #[test]
    fn layer_program_spec_matches_sample_zero_stream() {
        let n = NetworkSpec::uniform(2, 8, Activation::Relu, 19).with_population(3);
        let x = n.input_spec().chunk(0, 3);
        let batch = n.layer_batch(1, 0, 3, &x[..]);
        let spec = n.layer_program_spec(1);
        spec.check().unwrap();
        assert_eq!(&spec.w[..], batch.w_of(0));
        assert_eq!(&spec.noise.z0[..], batch.z_of(0, 0));
        assert_eq!(&spec.noise.z1[..], batch.z_of(0, 1));
        assert_eq!(&spec.noise.z2[..], batch.z_of(0, 2));
        // Distinct layers get distinct cache labels.
        assert_ne!(n.layer_program_spec(0).program_seed, spec.program_seed);
    }

    #[test]
    fn with_mitigation_covers_every_layer() {
        let cfg = MitigationConfig::parse("diff,avg:2").unwrap();
        let n = NetworkSpec::uniform(3, 8, Activation::Relu, 1).with_mitigation(cfg);
        assert!(n.layers.iter().all(|l| l.mitigation_or_none() == cfg));
    }
}
