//! Layered inference pipeline: chain crossbar VMMs through deep
//! networks and measure error propagation end-to-end.
//!
//! The paper benchmarks one isolated VMM; real in-memory workloads
//! compose them — layer `k`'s hardware output becomes layer `k+1`'s
//! input, so programming noise, quantization, and read distortion
//! *propagate*.  Following the multibit N-ary inference architecture
//! (arXiv 2604.26979) and the distributed in-memory stack of
//! arXiv 2508.13298, this subsystem models a feed-forward network in
//! which every layer is one crossbar VMM followed by an activation and
//! a requantization back to the crossbar's `[-1, 1]` input range:
//!
//! ```text
//! program W_k -> y = VMM(W_k, a_{k-1}) -> activate -> requantize -> a_k
//! ```
//!
//! [`runner::PipelineRunner`] runs the hardware chain on any
//! [`crate::vmm::VmmEngine`] (native, tiled, mitigated) and, in
//! lockstep, the exact software forward pass, so it can report
//! **per-layer** error statistics:
//!
//! * *injected-at-layer* — the error layer `k` adds on its own, i.e.
//!   hardware output minus the exact product *on the same (hardware)
//!   input*;
//! * *accumulated* — the running divergence of the hardware chain from
//!   the software chain after layer `k`'s activation/requantization;
//!
//! plus the end-to-end output error and a classification-style
//! argmax-agreement rate on deterministic seeded teacher networks
//! ([`network`]).  Per-layer [`crate::mitigation::MitigationConfig`]s
//! compose: each layer's crossbar can run behind its own mitigation
//! pipeline.

pub mod network;
pub mod runner;

pub use network::NetworkSpec;
pub use runner::{InferenceReport, LayerReport, PipelineOptions, PipelineRunner};

use crate::error::{Error, Result};
use crate::mitigation::MitigationConfig;

/// Per-layer nonlinearity applied to the raw VMM output before
/// requantization.  All variants are NaN-free: a NaN input maps to 0
/// (a hardware read never *is* NaN, but a defensive decode must not
/// poison the chain).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Activation {
    /// Pass-through (linear network).
    Identity,
    /// Rectifier `max(0, v)`.
    #[default]
    Relu,
    /// Hyperbolic tangent.
    Tanh,
    /// Clipped hard-tanh `clamp(v, -1, 1)` — the cheapest saturating
    /// nonlinearity, and what an ADC with a bounded code range does
    /// implicitly.
    HardTanh,
}

impl Activation {
    /// Parse a CLI/TOML name.
    pub fn parse(s: &str) -> Result<Activation> {
        match s.trim().to_ascii_lowercase().as_str() {
            "identity" | "id" | "linear" => Ok(Activation::Identity),
            "relu" => Ok(Activation::Relu),
            "tanh" => Ok(Activation::Tanh),
            "hardtanh" | "hard-tanh" | "clipped" => Ok(Activation::HardTanh),
            other => Err(Error::Config(format!(
                "unknown activation '{other}' (identity|relu|tanh|hardtanh)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::Identity => "identity",
            Activation::Relu => "relu",
            Activation::Tanh => "tanh",
            Activation::HardTanh => "hardtanh",
        }
    }

    /// Apply the nonlinearity to one raw VMM output element.
    #[inline]
    pub fn apply(&self, v: f32) -> f32 {
        if v.is_nan() {
            return 0.0;
        }
        match self {
            Activation::Identity => v,
            Activation::Relu => v.max(0.0),
            Activation::Tanh => v.tanh(),
            Activation::HardTanh => v.clamp(-1.0, 1.0),
        }
    }

    /// Variance gain of the He/Xavier-style default requantization
    /// scale: ReLU halves the signal power, so it gets the He factor.
    fn init_gain(&self) -> f64 {
        match self {
            Activation::Relu => 6.0,
            _ => 3.0,
        }
    }
}

/// Requantize a post-activation value back into the crossbar's
/// `[-1, 1]` input range: scale, then saturate.  NaN maps to 0 so a
/// poisoned element cannot take the whole chain down.
#[inline]
pub fn requantize(v: f32, scale: f32) -> f32 {
    let r = v * scale;
    if r.is_nan() {
        return 0.0;
    }
    r.clamp(-1.0, 1.0)
}

/// One network layer: a `rows -> cols` crossbar VMM, its activation,
/// its requantization scale, and an optional per-layer mitigation
/// pipeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerSpec {
    /// Input dimension (crossbar word lines).
    pub rows: usize,
    /// Output dimension (crossbar bit lines).
    pub cols: usize,
    pub activation: Activation,
    /// Requantization scale applied after the activation
    /// ([`requantize`]).  Defaults to the variance-preserving
    /// `sqrt(gain / rows)` for uniform `[-1, 1]` teacher weights, so
    /// activations neither explode nor vanish with depth.
    pub requant: f32,
    /// Per-layer error-mitigation pipeline (`None` = the network
    /// default / no mitigation).
    pub mitigation: Option<MitigationConfig>,
}

impl LayerSpec {
    /// Layer with the default variance-preserving requantization.
    pub fn new(rows: usize, cols: usize, activation: Activation) -> Self {
        Self {
            rows,
            cols,
            activation,
            requant: default_requant(rows, activation),
            mitigation: None,
        }
    }

    /// Override the requantization scale.
    pub fn with_requant(mut self, scale: f32) -> Self {
        self.requant = scale;
        self
    }

    /// Attach a mitigation pipeline to this layer.
    pub fn with_mitigation(mut self, cfg: MitigationConfig) -> Self {
        self.mitigation = Some(cfg);
        self
    }

    /// Effective mitigation (identity when unset).
    pub fn mitigation_or_none(&self) -> MitigationConfig {
        self.mitigation.unwrap_or(MitigationConfig::NONE)
    }
}

/// Default requantization scale `sqrt(gain / rows)`.
pub fn default_requant(rows: usize, activation: Activation) -> f32 {
    (activation.init_gain() / rows.max(1) as f64).sqrt() as f32
}

/// Parse a layer-dimension chain like `"32x48x10"` (or `"32-48-10"`):
/// `d_0 x d_1 x ... x d_L` describes `L` layers where layer `k` is a
/// `d_k -> d_{k+1}` crossbar.  Needs at least two dimensions.
pub fn parse_dims(spec: &str) -> Result<Vec<usize>> {
    let spec = spec.trim();
    let dims: Vec<usize> = spec
        .split(|c: char| c == 'x' || c == 'X' || c == '-')
        .map(|tok| {
            tok.trim()
                .parse::<usize>()
                .ok()
                .filter(|&d| d > 0)
                .ok_or_else(|| {
                    Error::Config(format!(
                        "bad layer spec '{spec}': '{tok}' is not a positive integer \
                         (expected e.g. 32x48x10)"
                    ))
                })
        })
        .collect::<Result<_>>()?;
    if dims.len() < 2 {
        return Err(Error::Config(format!(
            "layer spec '{spec}' needs at least two dimensions (input x output)"
        )));
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_parse_and_names() {
        assert_eq!(Activation::parse("relu").unwrap(), Activation::Relu);
        assert_eq!(Activation::parse("ID").unwrap(), Activation::Identity);
        assert_eq!(Activation::parse(" tanh ").unwrap(), Activation::Tanh);
        assert_eq!(Activation::parse("hard-tanh").unwrap(), Activation::HardTanh);
        assert_eq!(Activation::parse("clipped").unwrap(), Activation::HardTanh);
        assert!(Activation::parse("softmax").is_err());
        assert_eq!(Activation::Relu.name(), "relu");
        assert_eq!(Activation::default(), Activation::Relu);
    }

    #[test]
    fn activations_cover_saturation_edges() {
        // Relu kills negatives, passes positives.
        assert_eq!(Activation::Relu.apply(-3.5), 0.0);
        assert_eq!(Activation::Relu.apply(2.5), 2.5);
        // HardTanh saturates exactly at +/-1.
        assert_eq!(Activation::HardTanh.apply(7.0), 1.0);
        assert_eq!(Activation::HardTanh.apply(-7.0), -1.0);
        assert_eq!(Activation::HardTanh.apply(0.25), 0.25);
        // Tanh is bounded and odd.
        assert!(Activation::Tanh.apply(100.0) <= 1.0);
        assert!(Activation::Tanh.apply(-100.0) >= -1.0);
        // Identity passes everything.
        assert_eq!(Activation::Identity.apply(-42.0), -42.0);
        // NaN never propagates.
        for a in [
            Activation::Identity,
            Activation::Relu,
            Activation::Tanh,
            Activation::HardTanh,
        ] {
            assert_eq!(a.apply(f32::NAN), 0.0, "{}", a.name());
        }
        // The saturating activations also tame infinities.
        assert_eq!(Activation::Tanh.apply(f32::INFINITY), 1.0);
        assert_eq!(Activation::HardTanh.apply(f32::NEG_INFINITY), -1.0);
    }

    #[test]
    fn requantize_saturates_and_is_nan_free() {
        assert_eq!(requantize(10.0, 0.5), 1.0);
        assert_eq!(requantize(-10.0, 0.5), -1.0);
        assert_eq!(requantize(1.0, 0.5), 0.5);
        assert_eq!(requantize(0.0, 0.5), 0.0);
        // Exactly the edges.
        assert_eq!(requantize(2.0, 0.5), 1.0);
        assert_eq!(requantize(-2.0, 0.5), -1.0);
        // NaN input and NaN-producing scale both map to 0.
        assert_eq!(requantize(f32::NAN, 1.0), 0.0);
        assert_eq!(requantize(f32::INFINITY, 0.0), 0.0);
        // Infinities saturate.
        assert_eq!(requantize(f32::INFINITY, 1.0), 1.0);
        assert_eq!(requantize(f32::NEG_INFINITY, 1.0), -1.0);
    }

    #[test]
    fn default_requant_is_variance_preserving_scale() {
        let relu = default_requant(32, Activation::Relu);
        let id = default_requant(32, Activation::Identity);
        assert!((relu as f64 - (6.0f64 / 32.0).sqrt()).abs() < 1e-7);
        assert!((id as f64 - (3.0f64 / 32.0).sqrt()).abs() < 1e-7);
        // ReLU gets the He factor (sqrt(2) larger).
        assert!((relu / id - std::f32::consts::SQRT_2).abs() < 1e-6);
    }

    #[test]
    fn layer_spec_builders() {
        let l = LayerSpec::new(32, 16, Activation::Relu);
        assert_eq!(l.rows, 32);
        assert_eq!(l.cols, 16);
        assert!(l.mitigation.is_none());
        assert!(l.mitigation_or_none().is_noop());
        let m = l.with_mitigation(MitigationConfig::parse("avg:2").unwrap());
        assert_eq!(m.mitigation_or_none().replicas, 2);
        let r = l.with_requant(1.0);
        assert_eq!(r.requant, 1.0);
    }

    #[test]
    fn parse_dims_accepts_both_separators() {
        assert_eq!(parse_dims("32x48x10").unwrap(), vec![32, 48, 10]);
        assert_eq!(parse_dims("32-48-10").unwrap(), vec![32, 48, 10]);
        assert_eq!(parse_dims(" 8X8 ").unwrap(), vec![8, 8]);
        assert!(parse_dims("32").is_err());
        assert!(parse_dims("32x0x8").is_err());
        assert!(parse_dims("32xfrogx8").is_err());
        assert!(parse_dims("").is_err());
    }
}
