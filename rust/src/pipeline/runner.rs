//! [`PipelineRunner`]: execute a layered network on a crossbar engine
//! and its exact software twin in lockstep, collecting per-layer error
//! populations.
//!
//! ## Execution model
//!
//! The input population is split into fixed-size chunks (engine
//! batch-size preferences are honoured, as in the coordinator) and the
//! chunks are fanned over the worker pool.  Within a chunk the layers
//! run sequentially: layer `k`'s *hardware* activations feed layer
//! `k+1`'s crossbar, while a parallel software chain applies the exact
//! f64 product to its own activations.  Both chains share the same
//! activation + requantization arithmetic, so their divergence is
//! purely the hardware's doing.
//!
//! ## Determinism
//!
//! Chunk boundaries depend only on [`PipelineOptions::chunk`] (never on
//! the thread count), every weight/input/noise stream is a pure
//! function of `(seed, sample, layer)`
//! ([`super::network::NetworkSpec`]), and chunk results are reduced in
//! submission order — so the full layer trace is bit-identical for any
//! `parallelism` (`rust/tests/integration_pipeline.rs` enforces this).

use crate::coordinator::runner::plan_chunks;
use crate::coordinator::ErrorPopulation;
use crate::device::params::DeviceParams;
use crate::error::Result;
use crate::mitigation::MitigatedEngine;
use crate::obs::{self, Stage};
use crate::util::pool::{run_indexed, Parallelism};
use crate::util::progress::Stopwatch;
use crate::vmm::engine::DynEngine;
use crate::vmm::software::software_vmm_single;
use crate::vmm::VmmEngine;

use super::{requantize, NetworkSpec};

/// Execution options for one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineOptions {
    /// Samples per chunk (fixed — chunking must not depend on the
    /// thread count or determinism breaks).
    pub chunk: usize,
    /// Chunk-level worker budget; divided by the engine's internal
    /// fan-out exactly like the coordinator's.
    pub parallelism: Parallelism,
    /// Deployed mode: program each layer **once** (through this
    /// serving cache, so layer programs persist across `run` calls)
    /// and read every sample against that instance — deployment
    /// statistics, versus the default per-sample Monte-Carlo
    /// reprogramming.  Layer specs are pinned to the network's
    /// sample-0 noise stream ([`NetworkSpec::layer_program_spec`]).
    pub deploy: Option<std::sync::Arc<crate::serve::ProgramCache>>,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        Self { chunk: 64, parallelism: Parallelism::Auto, deploy: None }
    }
}

/// Per-layer error report: the injected-at-layer and accumulated error
/// populations (both feed the existing stats/fit machinery).
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub index: usize,
    pub rows: usize,
    pub cols: usize,
    pub activation: &'static str,
    /// Mitigation label of this layer (`"none"` when unmitigated).
    pub mitigation: String,
    pub requant: f32,
    /// Error layer `index` adds on its own: raw hardware output minus
    /// the exact product on the *same hardware* input.
    pub injected: ErrorPopulation,
    /// Divergence of the hardware chain from the software chain after
    /// this layer's activation + requantization.
    pub accumulated: ErrorPopulation,
}

impl LayerReport {
    /// Mean absolute injected error.
    pub fn injected_mean_abs(&self) -> f64 {
        mean_abs(self.injected.errors())
    }

    /// Mean absolute accumulated error.
    pub fn accumulated_mean_abs(&self) -> f64 {
        mean_abs(self.accumulated.errors())
    }
}

/// The full result of one pipeline run.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub layers: Vec<LayerReport>,
    pub samples: usize,
    /// Fraction of samples whose hardware argmax equals the software
    /// argmax at the network output (classification agreement).
    pub argmax_agreement: f64,
    /// Final hardware activations, row-major `(samples, output_dim)`.
    pub final_hw: Vec<f32>,
    /// Final software activations, same layout.
    pub final_sw: Vec<f32>,
    pub wall_secs: f64,
    pub engine: &'static str,
}

impl InferenceReport {
    /// End-to-end output error population (the last layer's accumulated
    /// errors).
    pub fn end_to_end(&self) -> &ErrorPopulation {
        &self
            .layers
            .last()
            .expect("a validated network has at least one layer")
            .accumulated
    }

    /// Hardware VMMs per second of wall time (samples x depth).
    pub fn vmm_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            (self.samples * self.layers.len()) as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

/// Mean absolute value of an error vector (NaN when empty).
pub fn mean_abs(errors: &[f64]) -> f64 {
    if errors.is_empty() {
        return f64::NAN;
    }
    errors.iter().map(|e| e.abs()).sum::<f64>() / errors.len() as f64
}

/// Index of the first maximum (classification argmax; deterministic
/// first-wins tie-breaking, NaN-proof because requantized activations
/// are always finite).
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Per-chunk raw trace, merged in submission order.
struct ChunkTrace {
    /// `(injected, accumulated)` per layer.
    layers: Vec<(Vec<f64>, Vec<f64>)>,
    matches: usize,
    final_hw: Vec<f32>,
    final_sw: Vec<f32>,
}

/// Runs layered networks on one engine (plus per-layer mitigation
/// wrappers built on demand from the network spec).
pub struct PipelineRunner {
    engine: DynEngine,
}

impl PipelineRunner {
    pub fn new(engine: DynEngine) -> Self {
        Self { engine }
    }

    pub fn engine(&self) -> &DynEngine {
        &self.engine
    }

    /// Run `net` on `device`, returning the per-layer error report.
    pub fn run(
        &self,
        net: &NetworkSpec,
        device: &DeviceParams,
        opts: &PipelineOptions,
    ) -> Result<InferenceReport> {
        net.validate()?;
        device.validate().map_err(crate::error::Error::Config)?;
        let wall = Stopwatch::start();

        // One engine handle per layer: the base engine, or the base
        // engine behind that layer's mitigation pipeline.
        let engines: Vec<DynEngine> = net
            .layers
            .iter()
            .map(|l| {
                let cfg = l.mitigation_or_none();
                if cfg.is_noop() {
                    self.engine.clone()
                } else {
                    DynEngine::new(MitigatedEngine::new(self.engine.clone(), cfg))
                }
            })
            .collect();

        let plan = plan_chunks(net.population, opts.chunk.max(1), &self.engine.preferred_batches());
        let engine_threads = self.engine.internal_parallelism().max(1);
        let chunk_threads = (opts.parallelism.threads() / engine_threads).max(1);
        let chunk_par = Parallelism::Fixed(chunk_threads);

        let inputs = net.input_spec();
        let device = *device;
        let engines_ref = &engines;
        // Teacher weights are chunk-invariant: generate each layer's
        // matrix once and share it across the fan-out.
        let weights: Vec<Vec<f32>> = (0..net.depth()).map(|k| net.layer_weights(k)).collect();
        let weights_ref = &weights;
        // Deployed mode: one program spec per layer, resolved through
        // the shared serving cache inside the chunk jobs.
        let deploy = opts.deploy.clone();
        let deploy_ref = &deploy;
        let specs: Option<Vec<crate::vmm::ProgramSpec>> = deploy
            .as_ref()
            .map(|_| (0..net.depth()).map(|k| net.layer_program_spec(k)).collect());
        let specs_ref = &specs;
        let results: Vec<Result<ChunkTrace>> = run_indexed(chunk_par, plan.len(), |ci| {
            let (start, len) = plan[ci];
            let mut a_hw = inputs.chunk(start, len);
            let mut a_sw = a_hw.clone();
            let mut layers = Vec::with_capacity(net.depth());
            for (k, layer) in net.layers.iter().enumerate() {
                let out = obs::time_stage(Stage::PipelineLayer, || {
                    if let (Some(cache), Some(specs)) =
                        (deploy_ref.as_ref(), specs_ref.as_ref())
                    {
                        let handle =
                            cache.get_or_program(&engines_ref[k], &specs[k], &device)?;
                        handle.forward(&a_hw, len)
                    } else {
                        let batch =
                            net.layer_batch_with_weights(k, start, len, &a_hw, &weights_ref[k]);
                        engines_ref[k].forward(&batch, &device)
                    }
                })?;
                // Injected-at-layer: hardware vs exact product on the
                // same (hardware) input — the engine computes that
                // exact product as its software reference.
                let injected: Vec<f64> = out
                    .y_hw
                    .iter()
                    .zip(&out.y_sw)
                    .map(|(&h, &s)| h as f64 - s as f64)
                    .collect();
                // Software chain: exact product on the software
                // activations, then the shared activation/requantize.
                let y_sw_chain =
                    exact_forward(&weights_ref[k], &a_sw, len, layer.rows, layer.cols);
                let next_hw: Vec<f32> = out
                    .y_hw
                    .iter()
                    .map(|&v| requantize(layer.activation.apply(v), layer.requant))
                    .collect();
                let next_sw: Vec<f32> = y_sw_chain
                    .iter()
                    .map(|&v| requantize(layer.activation.apply(v), layer.requant))
                    .collect();
                let accumulated: Vec<f64> = next_hw
                    .iter()
                    .zip(&next_sw)
                    .map(|(&h, &s)| h as f64 - s as f64)
                    .collect();
                layers.push((injected, accumulated));
                a_hw = next_hw;
                a_sw = next_sw;
            }
            let d = net.output_dim();
            let matches = (0..len)
                .filter(|&s| {
                    argmax(&a_hw[s * d..(s + 1) * d]) == argmax(&a_sw[s * d..(s + 1) * d])
                })
                .count();
            Ok(ChunkTrace { layers, matches, final_hw: a_hw, final_sw: a_sw })
        });

        // Reduce in submission order (determinism).
        let mut layers: Vec<LayerReport> = net
            .layers
            .iter()
            .enumerate()
            .map(|(k, l)| LayerReport {
                index: k,
                rows: l.rows,
                cols: l.cols,
                activation: l.activation.name(),
                mitigation: l.mitigation_or_none().label(),
                requant: l.requant,
                injected: ErrorPopulation::with_capacity(net.population * l.cols),
                accumulated: ErrorPopulation::with_capacity(net.population * l.cols),
            })
            .collect();
        let mut matches = 0usize;
        let mut final_hw = Vec::with_capacity(net.population * net.output_dim());
        let mut final_sw = Vec::with_capacity(net.population * net.output_dim());
        for r in results {
            let trace = r?;
            for (k, (inj, acc)) in trace.layers.into_iter().enumerate() {
                layers[k].injected.extend(&inj);
                layers[k].accumulated.extend(&acc);
            }
            matches += trace.matches;
            final_hw.extend_from_slice(&trace.final_hw);
            final_sw.extend_from_slice(&trace.final_sw);
        }
        Ok(InferenceReport {
            layers,
            samples: net.population,
            argmax_agreement: matches as f64 / net.population as f64,
            final_hw,
            final_sw,
            wall_secs: wall.elapsed_secs(),
            engine: self.engine.name(),
        })
    }
}

/// Exact batched product `y[s, j] = sum_i x[s, i] * w[i, j]` (shared
/// teacher weights, per-sample inputs) — the software chain's forward
/// step, delegating to the engines' single-sample reference kernel so
/// both sides of every error measurement share one arithmetic.
fn exact_forward(w: &[f32], x: &[f32], len: usize, rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), len * rows);
    let mut y = vec![0.0f32; len * cols];
    let mut acc = vec![0.0f64; cols];
    for s in 0..len {
        software_vmm_single(
            w,
            &x[s * rows..(s + 1) * rows],
            rows,
            cols,
            &mut acc,
            &mut y[s * cols..(s + 1) * cols],
        );
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::mitigation::MitigationConfig;
    use crate::pipeline::Activation;
    use crate::vmm::{NativeEngine, SoftwareEngine, TiledEngine};

    fn native() -> DynEngine {
        DynEngine::new(NativeEngine::default())
    }

    #[test]
    fn argmax_first_wins() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[1.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -3.0]), 1);
    }

    #[test]
    fn exact_forward_matches_hand_case() {
        // w = [[1, 2], [3, 4]] (2x2), x = [[1, 1], [0.5, 0]].
        let w = vec![1.0f32, 2.0, 3.0, 4.0];
        let x = vec![1.0f32, 1.0, 0.5, 0.0];
        let y = exact_forward(&w, &x, 2, 2, 2);
        assert_eq!(y, vec![4.0, 6.0, 0.5, 1.0]);
    }

    #[test]
    fn software_engine_pipeline_has_zero_error() {
        // On the exact software engine the hardware chain IS the
        // software chain: every population must be identically zero and
        // argmax agreement exact.
        let net = NetworkSpec::uniform(3, 16, Activation::Relu, 11).with_population(20);
        let runner = PipelineRunner::new(DynEngine::new(SoftwareEngine));
        let r = runner
            .run(&net, &DeviceParams::ideal(), &PipelineOptions::default())
            .unwrap();
        assert_eq!(r.samples, 20);
        assert_eq!(r.layers.len(), 3);
        for l in &r.layers {
            assert_eq!(l.injected.len(), 20 * 16);
            assert!(l.injected.errors().iter().all(|&e| e == 0.0));
            assert!(l.accumulated.errors().iter().all(|&e| e == 0.0));
        }
        assert_eq!(r.argmax_agreement, 1.0);
        assert_eq!(r.final_hw, r.final_sw);
        assert!(r.vmm_per_sec() >= 0.0);
    }

    #[test]
    fn ideal_device_stays_near_software() {
        let net = NetworkSpec::uniform(4, 16, Activation::HardTanh, 12).with_population(12);
        let runner = PipelineRunner::new(native());
        let r = runner
            .run(&net, &DeviceParams::ideal(), &PipelineOptions::default())
            .unwrap();
        // Ideal device: tiny decode error only, never exploding.
        assert!(r.end_to_end().stats().max().abs() < 0.1);
        // Near-ties can still flip an argmax under ~1e-3 decode error;
        // most samples must agree regardless.
        assert!(r.argmax_agreement > 0.5);
    }

    #[test]
    fn noisy_device_errors_grow_with_depth() {
        let net = NetworkSpec::uniform(4, 16, Activation::Relu, 13).with_population(24);
        let runner = PipelineRunner::new(native());
        let r = runner
            .run(&net, &presets::ag_si().params, &PipelineOptions::default())
            .unwrap();
        // Every layer injects nonzero error…
        for l in &r.layers {
            assert!(l.injected_mean_abs() > 0.0, "layer {}", l.index);
            assert!(l.accumulated.errors().iter().all(|e| e.is_finite()));
        }
        // …and the chain accumulates: the output diverges more than the
        // first layer alone.
        let first = r.layers[0].accumulated_mean_abs();
        let last = r.layers[3].accumulated_mean_abs();
        assert!(last > first * 0.5, "first={first} last={last}");
        assert!(r.end_to_end().len() == 24 * 16);
    }

    #[test]
    fn chunking_does_not_change_the_trace() {
        let net = NetworkSpec::uniform(2, 8, Activation::Relu, 14).with_population(10);
        let runner = PipelineRunner::new(native());
        let device = presets::epiram().params;
        let whole = runner
            .run(&net, &device, &PipelineOptions { chunk: 10, parallelism: Parallelism::Fixed(1), ..PipelineOptions::default() })
            .unwrap();
        let split = runner
            .run(&net, &device, &PipelineOptions { chunk: 3, parallelism: Parallelism::Fixed(1), ..PipelineOptions::default() })
            .unwrap();
        for (a, b) in whole.layers.iter().zip(&split.layers) {
            assert_eq!(a.injected.errors(), b.injected.errors());
            assert_eq!(a.accumulated.errors(), b.accumulated.errors());
        }
        assert_eq!(whole.final_hw, split.final_hw);
    }

    #[test]
    fn per_layer_mitigation_tightens_injected_error() {
        let device = presets::epiram().params;
        let plain = NetworkSpec::uniform(2, 16, Activation::Relu, 15).with_population(16);
        let mitigated = plain
            .clone()
            .with_mitigation(MitigationConfig::parse("avg:4").unwrap());
        let runner = PipelineRunner::new(native());
        let rp = runner.run(&plain, &device, &PipelineOptions::default()).unwrap();
        let rm = runner
            .run(&mitigated, &device, &PipelineOptions::default())
            .unwrap();
        assert_eq!(rm.layers[0].mitigation, "avg:4");
        assert_eq!(rp.layers[0].mitigation, "none");
        // Replica averaging on the C2C-dominated EpiRAM must cut the
        // first layer's injected error variance.
        let vp = rp.layers[0].injected.stats().variance();
        let vm = rm.layers[0].injected.stats().variance();
        assert!(vm < vp, "plain {vp} vs mitigated {vm}");
    }

    #[test]
    fn tiled_engine_runs_nonsquare_chains() {
        let net = NetworkSpec::from_dims(&[48, 40, 8], Activation::Tanh, 16)
            .unwrap()
            .with_population(6);
        let runner = PipelineRunner::new(DynEngine::new(TiledEngine::default()));
        let r = runner
            .run(&net, &presets::epiram().params, &PipelineOptions::default())
            .unwrap();
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.final_hw.len(), 6 * 8);
        assert!(r.end_to_end().errors().iter().all(|e| e.is_finite()));
    }

    #[test]
    fn invalid_network_and_device_rejected() {
        let runner = PipelineRunner::new(native());
        let mut net = NetworkSpec::uniform(2, 8, Activation::Relu, 17);
        net.layers[1].rows = 4;
        assert!(runner
            .run(&net, &DeviceParams::ideal(), &PipelineOptions::default())
            .is_err());
        let net = NetworkSpec::uniform(1, 8, Activation::Relu, 17);
        let mut bad = presets::ag_si().params;
        bad.memory_window = 0.5;
        assert!(runner.run(&net, &bad, &PipelineOptions::default()).is_err());
    }
}
