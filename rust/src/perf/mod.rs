//! The hotpath bench suite as a library: every layer of the stack,
//! measured through [`crate::util::bench`] with **stable, slug-style
//! benchmark names**, so the suite can run both as the
//! `cargo bench --bench hotpath` target (full mode) and as the
//! `meliso bench` subcommand (quick mode, writing `BENCH.json` for
//! CI's `perf-smoke` soft-gate).
//!
//! Names are mode-independent on purpose: a quick-mode `BENCH.json`
//! compares against a quick-mode baseline by name, and the recorded
//! `items_per_iter` makes the per-mode workload explicit in the
//! document itself.  Quick mode shrinks populations and sample counts
//! (CI smoke budget); full mode keeps the historical workloads of the
//! pre-PR-4 `hotpath` bench.

use crate::coordinator::{BenchmarkConfig, Coordinator, WorkloadSpec};
use crate::device::params::NonIdealities;
use crate::device::presets;
use crate::mitigation::{MitigatedEngine, MitigationConfig};
use crate::obs;
use crate::pipeline::{Activation, NetworkSpec, PipelineOptions, PipelineRunner};
use crate::shard::FaultSpec;
use crate::stats::moments::Moments;
use crate::util::bench::{bench, black_box, BenchOpts, BenchResult};
use crate::util::rng::Xoshiro256;
use crate::vmm::{
    DynEngine, NativeEngine, ProgramSpec, ShardedEngine, TiledEngine, VmmEngine, XlaEngine,
};

/// Suite execution options.
#[derive(Debug, Clone, Default)]
pub struct SuiteOpts {
    /// Shrink workloads and sample counts to a CI smoke budget.
    pub quick: bool,
    /// Run only benchmarks whose name contains this substring.
    pub filter: Option<String>,
}

/// One >2x-median regression against a baseline document.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    pub name: String,
    pub baseline_median: f64,
    pub current_median: f64,
    /// `current / baseline` (always `> factor` for reported entries).
    pub ratio: f64,
}

/// Compare suite results against a baseline by name and report every
/// median that regressed by more than `factor` — the `perf-smoke`
/// soft-gate (the caller warns; it never fails the build).  Benchmarks
/// missing from either side are skipped: machines differ, suites grow.
pub fn compare_to_baseline(
    current: &[BenchResult],
    baseline: &[BenchResult],
    factor: f64,
) -> Vec<Regression> {
    let mut out = Vec::new();
    for cur in current {
        let Some(base) = baseline.iter().find(|b| b.name == cur.name) else {
            continue;
        };
        if base.median <= 0.0 || !cur.median.is_finite() {
            continue;
        }
        let ratio = cur.median / base.median;
        if ratio > factor {
            out.push(Regression {
                name: cur.name.clone(),
                baseline_median: base.median,
                current_median: cur.median,
                ratio,
            });
        }
    }
    out
}

fn fmt_secs(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Render an old-vs-new median table (GitHub-flavored markdown) — the
/// `perf-smoke` job appends this to `$GITHUB_STEP_SUMMARY`.  Unlike
/// [`compare_to_baseline`] this reports *every* benchmark in either
/// document: matched names get a delta (improvements included, so the
/// summary shows the whole trajectory rather than only >2x
/// regressions), current-only names render as explicit `added` rows,
/// and baseline-only names as `removed` rows — a suite that grows or
/// shrinks is visible in the table itself, not silently dropped.
pub fn delta_table_md(current: &[BenchResult], baseline: &[BenchResult]) -> String {
    let mut out = String::from(
        "#### `meliso bench` median delta vs baseline\n\n\
         | benchmark | baseline median | current median | delta |\n\
         | --- | ---: | ---: | ---: |\n",
    );
    let mut matched = 0usize;
    let mut added = 0usize;
    for cur in current {
        match baseline.iter().find(|b| b.name == cur.name) {
            Some(base) => {
                if base.median <= 0.0 || !cur.median.is_finite() {
                    continue;
                }
                matched += 1;
                let ratio = cur.median / base.median;
                let delta = if ratio <= 1.0 {
                    format!("**{:.2}x faster**", 1.0 / ratio)
                } else {
                    format!("{ratio:.2}x slower")
                };
                out.push_str(&format!(
                    "| `{}` | {} | {} | {} |\n",
                    cur.name,
                    fmt_secs(base.median),
                    fmt_secs(cur.median),
                    delta
                ));
            }
            None => {
                added += 1;
                out.push_str(&format!(
                    "| `{}` | — | {} | added |\n",
                    cur.name,
                    fmt_secs(cur.median),
                ));
            }
        }
    }
    let mut removed = 0usize;
    for base in baseline {
        if current.iter().any(|c| c.name == base.name) {
            continue;
        }
        removed += 1;
        out.push_str(&format!(
            "| `{}` | {} | — | removed |\n",
            base.name,
            fmt_secs(base.median),
        ));
    }
    out.push_str(&format!(
        "\n_{matched} benchmark(s) compared; {added} added; {removed} removed._\n"
    ));
    out
}

struct Suite {
    quick: bool,
    filter: Option<String>,
    results: Vec<BenchResult>,
}

impl Suite {
    fn matches(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Run one benchmark unless filtered out; quick mode caps the
    /// measured samples.
    fn go<F: FnMut()>(&mut self, name: &str, opts: BenchOpts, f: F) -> Option<BenchResult> {
        if !self.matches(name) {
            return None;
        }
        let opts = if self.quick {
            BenchOpts { samples: opts.samples.min(3), warmup: 1, ..opts }
        } else {
            opts
        };
        let r = bench(name, opts, f);
        self.results.push(r.clone());
        Some(r)
    }
}

/// Run the hotpath suite and return every measured result (in run
/// order).  An empty return means the filter matched nothing.
pub fn run_suite(opts: &SuiteOpts) -> Vec<BenchResult> {
    let mut suite = Suite {
        quick: opts.quick,
        filter: opts.filter.clone(),
        results: Vec::new(),
    };
    let quick = opts.quick;
    let device = presets::ag_si().params.masked(NonIdealities::FULL);
    let spec = WorkloadSpec::paper_default(1);
    let base_n = if quick { 64 } else { 256 };
    let batch = spec.chunk(0, base_n);
    let items = Some(base_n as f64);
    let std_opts = BenchOpts { samples: 10, warmup: 2, items_per_iter: items };

    // L3: workload generation (w, x and 3 noise planes per sample).
    suite.go("workload-gen", std_opts, || {
        black_box(spec.chunk(0, base_n));
    });

    // L3: native physics engine — the sequential baseline vs the
    // pool-fanned engine (per-worker scratch, shared pulse table).
    let seq = suite.go("native-seq", std_opts, || {
        black_box(NativeEngine::sequential().forward(&batch, &device).unwrap());
    });
    let par = suite.go("native-par", std_opts, || {
        black_box(NativeEngine::default().forward(&batch, &device).unwrap());
    });
    if let (Some(seq), Some(par)) = (&seq, &par) {
        println!(
            "      native parallel speedup: {:.2}x samples/sec over sequential",
            par.items_per_sec(base_n as f64) / seq.items_per_sec(base_n as f64)
        );
    }

    // Mitigation pipeline: throughput cost of each strategy over the
    // parallel native engine.
    for (slug, spec_str) in [
        ("mitigated-diff", "diff"),
        ("mitigated-slice2", "slice:2"),
        ("mitigated-avg4", "avg:4"),
        ("mitigated-cal", "cal"),
        ("mitigated-combo", "diff,slice:2,avg:4,cal"),
    ] {
        let eng = MitigatedEngine::new(
            NativeEngine::default(),
            MitigationConfig::parse(spec_str).unwrap(),
        );
        suite.go(
            slug,
            BenchOpts { samples: 5, warmup: 1, items_per_iter: items },
            || {
                black_box(eng.forward(&batch, &device).unwrap());
            },
        );
    }

    // Tiled engine: arbitrary-size populations over 32x32 tile grids.
    let tiled = TiledEngine::default();
    for size in [128usize, 256] {
        let mut tspec = WorkloadSpec::paper_default(2);
        tspec.rows = size;
        tspec.cols = size;
        let scale = if quick { 4 } else { 16 };
        let samples = (scale * 128 * 128 / (size * size)).max(2);
        let tb = tspec.chunk(0, samples);
        suite.go(
            &format!("tiled-{size}"),
            BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(samples as f64) },
            || {
                black_box(tiled.forward(&tb, &device).unwrap());
            },
        );
    }

    // Sharded engine: grid partitioning + checksum reduction cost at
    // the paper geometry, plus a fault-campaign leg (injection +
    // detection + correction on the same path).
    for (gr, gc) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let eng = ShardedEngine::new(gr, gc);
        suite.go(
            &format!("sharded-{gr}x{gc}"),
            BenchOpts { samples: 5, warmup: 1, items_per_iter: items },
            || {
                black_box(eng.forward(&batch, &device).unwrap());
            },
        );
    }
    let faulted = ShardedEngine::new(2, 2).with_fault(FaultSpec::stuck_at_on(0.05, 7));
    suite.go(
        "sharded-2x2-faulted",
        BenchOpts { samples: 5, warmup: 1, items_per_iter: items },
        || {
            black_box(faulted.forward(&batch, &device).unwrap());
        },
    );

    // Serving hot path: program-once/read-many amortization on
    // repeated-weight traffic (DESIGN.md §14).  The uncached leg
    // reprograms per request — what every batch engine did before the
    // serving split — while the cached leg serves all requests from
    // one programmed array; both measure the hardware read path only.
    {
        let (srows, scols) = (128usize, 128);
        let nreq = if quick { 8 } else { 32 };
        let mut rng = Xoshiro256::seed_from_u64(0x53455256); // "SERV"
        let mut w = vec![0.0f32; srows * scols];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        let sspec = ProgramSpec::from_seed(srows, scols, w, 0x50524F47); // "PROG"
        let mut x = vec![0.0f32; nreq * srows];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let sengine = NativeEngine::default();
        let programmed = sengine.program(&sspec, &device).unwrap();
        let sopts = BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(nreq as f64) };
        let cached = suite.go("serve-cached-128", sopts, || {
            black_box(programmed.read(&x, nreq).unwrap());
        });
        let uncached = suite.go("serve-uncached-128", sopts, || {
            for s in 0..nreq {
                let fresh = sengine.program(&sspec, &device).unwrap();
                black_box(fresh.read(&x[s * srows..(s + 1) * srows], 1).unwrap());
            }
        });
        if let (Some(cached), Some(uncached)) = (&cached, &uncached) {
            println!(
                "      serve cache speedup: {:.2}x requests/sec over reprogram-per-request",
                cached.items_per_sec(nreq as f64) / uncached.items_per_sec(nreq as f64)
            );
        }
        // Observability overhead leg: the identical cached read
        // workload with the metrics registry *enabled* — the baseline
        // soft-gate slug behind the <10% enabled-path overhead
        // contract (DESIGN.md §17).  Serialized through the obs test
        // lock so parallel tests flipping the global gate never race
        // this measurement.
        if suite.matches("serve-cached-128-obs") {
            let _guard = obs::test_lock();
            let was = obs::enabled();
            obs::set_enabled(true);
            suite.go("serve-cached-128-obs", sopts, || {
                black_box(programmed.read(&x, nreq).unwrap());
            });
            obs::set_enabled(was);
        }
    }

    // Fleet fabric: the whole node/router path (encode -> consistent-
    // hash route -> serialized envelope hop -> per-node cache/queue/
    // workers -> response rollup) at 1 and 2 nodes, with the per-node
    // capacity the projection scales from (DESIGN.md §16).
    {
        use crate::serve::{run_fleet, FleetOptions, ServeOptions, SocketOptions, Transport};
        let fengine = DynEngine::new(NativeEngine::default());
        let rpc = if quick { 8 } else { 32 };
        for nodes in [1usize, 2] {
            let fopts = FleetOptions {
                serve: ServeOptions {
                    clients: 4,
                    requests_per_client: rpc,
                    models: 3,
                    rows: 32,
                    cols: 32,
                    queue_capacity: 32,
                    batch_max: 8,
                    window: std::time::Duration::from_micros(100),
                    workers: 1,
                    cache: true,
                    cache_capacity: 8,
                    measure_error: false,
                    ..ServeOptions::default()
                },
                nodes,
                replication: 1,
                fail_rate: 0.0,
                collect_responses: false,
                ..FleetOptions::default()
            };
            let total = fopts.serve.total_requests();
            let measured = suite.go(
                &format!("fleet-n{nodes}"),
                BenchOpts { samples: 3, warmup: 1, items_per_iter: Some(total as f64) },
                || {
                    black_box(run_fleet(&fengine, &device, &fopts).unwrap());
                },
            );
            if measured.is_some() {
                let r = run_fleet(&fengine, &device, &fopts).unwrap();
                println!(
                    "      fleet-n{nodes}: {:.0} req/s/node fitted -> {} node(s) \
                     at 1e8 req/day",
                    r.per_node_rps, r.aggregate.nodes_for_1e8_per_day
                );
            }
            // Socket leg at the 2-node shape: the same traffic over
            // loopback TCP, so the wire boundary's cost rides in the
            // perf record next to the in-process hop.
            if nodes == 2 {
                let sopts = FleetOptions {
                    transport: Transport::Socket(SocketOptions::default()),
                    ..fopts.clone()
                };
                suite.go(
                    "fleet-sock-n2",
                    BenchOpts { samples: 3, warmup: 1, items_per_iter: Some(total as f64) },
                    || {
                        black_box(run_fleet(&fengine, &device, &sopts).unwrap());
                    },
                );
            }
        }
    }

    // Layered inference pipeline: deep VMM chains, plain vs mitigated.
    let runner = PipelineRunner::new(DynEngine::new(NativeEngine::default()));
    let popts = PipelineOptions::default();
    let pipe_pop = if quick { 8 } else { 32 };
    for depth in [4usize, 8] {
        for (tag, mit) in [("", "none"), ("-mitigated", "diff,avg:2")] {
            let mut net = NetworkSpec::uniform(depth, 32, Activation::Relu, 3)
                .with_population(pipe_pop);
            if mit != "none" {
                net = net.with_mitigation(MitigationConfig::parse(mit).unwrap());
            }
            suite.go(
                &format!("pipeline-d{depth}{tag}"),
                BenchOpts {
                    samples: 3,
                    warmup: 1,
                    items_per_iter: Some((pipe_pop * depth) as f64),
                },
                || {
                    black_box(runner.run(&net, &device, &popts).unwrap());
                },
            );
        }
    }

    // Software reference.
    suite.go("software-vmm", std_opts, || {
        black_box(crate::vmm::software_vmm_batch(&batch));
    });

    // L2+L1 through PJRT, when artifacts exist.
    match XlaEngine::from_default_dir() {
        Ok(engine) => match engine.runtime().warmup() {
            Ok(_) => {
                suite.go("xla-forward", std_opts, || {
                    black_box(engine.forward(&batch, &device).unwrap());
                });
                let gp = vec![0.5f32; base_n * 32 * 32];
                let gn = vec![0.25f32; base_n * 32 * 32];
                let v = vec![0.1f32; base_n * 32];
                suite.go("xla-raw-read", std_opts, || {
                    black_box(engine.raw_vmm(&gp, &gn, &v, base_n).unwrap());
                });
                let pop = if quick { 128 } else { 1024 };
                let cfg = BenchmarkConfig::paper_default(device).with_population(pop);
                let coord = Coordinator::new(engine);
                suite.go(
                    "e2e-xla",
                    BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(pop as f64) },
                    || {
                        black_box(coord.run(&cfg).unwrap());
                    },
                );
            }
            Err(e) => eprintln!("(xla benches skipped: {e})"),
        },
        Err(e) => eprintln!("(xla benches skipped: {e})"),
    }

    // Stats reduction over a protocol-size error vector.
    let errs: Vec<f64> = (0..32_000).map(|i| (i as f64 * 0.37).sin()).collect();
    suite.go(
        "stats-moments",
        BenchOpts { samples: 10, warmup: 2, items_per_iter: Some(32_000.0) },
        || {
            black_box(Moments::from_slice(&errs));
        },
    );

    // End-to-end coordinator runs.
    let pop = if quick { 128 } else { 1024 };
    let cfg = BenchmarkConfig::paper_default(device).with_population(pop);
    let coord = Coordinator::new(NativeEngine::default());
    suite.go(
        "e2e-native",
        BenchOpts { samples: 5, warmup: 1, items_per_iter: Some(pop as f64) },
        || {
            black_box(coord.run(&cfg).unwrap());
        },
    );

    let tpop = if quick { 8 } else { 64 };
    let mut cfg128 = BenchmarkConfig::paper_default(device).with_population(tpop);
    cfg128.workload.rows = 128;
    cfg128.workload.cols = 128;
    cfg128.calibration_samples = 16;
    let coord = Coordinator::new(TiledEngine::default());
    suite.go(
        "e2e-tiled-128",
        BenchOpts { samples: 3, warmup: 1, items_per_iter: Some(tpop as f64) },
        || {
            black_box(coord.run(&cfg128).unwrap());
        },
    );

    let spop = if quick { 8 } else { 64 };
    let mut scfg = BenchmarkConfig::paper_default(device).with_population(spop);
    scfg.workload.rows = 128;
    scfg.workload.cols = 128;
    scfg.calibration_samples = 16;
    let coord = Coordinator::new(ShardedEngine::new(4, 4));
    suite.go(
        "e2e-sharded-128",
        BenchOpts { samples: 3, warmup: 1, items_per_iter: Some(spop as f64) },
        || {
            black_box(coord.run(&scfg).unwrap());
        },
    );

    suite.results
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(name: &str, median: f64) -> BenchResult {
        BenchResult {
            name: name.into(),
            median,
            mean: median,
            min: median,
            max: median,
            samples: 3,
            items_per_iter: None,
        }
    }

    #[test]
    fn quick_filtered_suite_runs_and_reports() {
        // One cheap benchmark end-to-end through the real harness.
        let results = run_suite(&SuiteOpts {
            quick: true,
            filter: Some("stats-moments".into()),
        });
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].name, "stats-moments");
        assert!(results[0].median > 0.0);
        assert_eq!(results[0].items_per_iter, Some(32_000.0));
    }

    #[test]
    fn unmatched_filter_returns_empty() {
        let results = run_suite(&SuiteOpts {
            quick: true,
            filter: Some("no-such-bench-name".into()),
        });
        assert!(results.is_empty());
    }

    #[test]
    fn serve_cache_slugs_show_amortization() {
        // The acceptance bar of the serving subsystem: on repeated-
        // weight traffic the cached read path beats reprogram-per-
        // request by >= 3x median throughput (the real margin is an
        // order of magnitude — programming touches every cell with
        // rounding/table work the read path never pays).
        let results = run_suite(&SuiteOpts { quick: true, filter: Some("serve-".into()) });
        // Compare the *minimum* samples: under parallel-test scheduler
        // contention a descheduled quantum can inflate individual
        // samples of the (very short) cached leg, but the min of five
        // approaches the true cost on both sides.
        let min_of = |name: &str| {
            results
                .iter()
                .find(|r| r.name == name)
                .unwrap_or_else(|| panic!("missing slug {name}"))
                .min
        };
        let (cached, uncached) = (min_of("serve-cached-128"), min_of("serve-uncached-128"));
        assert!(cached > 0.0 && uncached > 0.0);
        assert!(
            uncached / cached >= 3.0,
            "serve cache speedup {:.2}x below the 3x bar (cached {cached:.6}s, \
             uncached {uncached:.6}s)",
            uncached / cached
        );
    }

    #[test]
    fn fleet_slugs_cover_both_node_counts() {
        let results = run_suite(&SuiteOpts { quick: true, filter: Some("fleet-n".into()) });
        let names: Vec<&str> = results.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, ["fleet-n1", "fleet-n2"]);
        for r in &results {
            assert!(r.median > 0.0);
            // 4 clients x 8 quick requests through the whole fabric.
            assert_eq!(r.items_per_iter, Some(32.0));
        }
    }

    #[test]
    fn fleet_socket_slug_runs_the_wire_leg() {
        let results = run_suite(&SuiteOpts { quick: true, filter: Some("fleet-sock".into()) });
        let names: Vec<&str> = results.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, ["fleet-sock-n2"]);
        assert!(results[0].median > 0.0);
        assert_eq!(results[0].items_per_iter, Some(32.0));
    }

    #[test]
    fn baseline_comparison_flags_only_regressions() {
        let baseline = vec![result("a", 1.0), result("b", 1.0), result("c", 1.0)];
        let current = vec![
            result("a", 2.5),  // 2.5x: regression
            result("b", 1.9),  // within 2x
            result("d", 50.0), // not in baseline: skipped
        ];
        let regs = compare_to_baseline(&current, &baseline, 2.0);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "a");
        assert!((regs[0].ratio - 2.5).abs() < 1e-12);
        // Faster-than-baseline never fires.
        assert!(compare_to_baseline(&[result("a", 0.1)], &baseline, 2.0).is_empty());
    }

    #[test]
    fn delta_table_reports_every_benchmark_in_either_document() {
        let baseline = vec![result("a", 1.0), result("b", 0.010), result("gone", 1.0)];
        let current = vec![
            result("a", 0.5),   // 2x faster
            result("b", 0.020), // 2x slower
            result("new", 3.0), // no baseline entry
        ];
        let md = delta_table_md(&current, &baseline);
        assert!(md.contains("| `a` | 1.000s | 500.000ms | **2.00x faster** |"), "{md}");
        assert!(md.contains("| `b` | 10.000ms | 20.000ms | 2.00x slower |"), "{md}");
        // Asymmetric names render as explicit rows, not silence.
        assert!(md.contains("| `new` | — | 3.000s | added |"), "{md}");
        assert!(md.contains("| `gone` | 1.000s | — | removed |"), "{md}");
        assert!(md.contains("2 benchmark(s) compared; 1 added; 1 removed."), "{md}");
        // Every data row renders the full 4-column markdown shape.
        for line in md.lines().filter(|l| l.starts_with("| `")) {
            assert_eq!(line.matches(" | ").count(), 3, "{line}");
        }
        // Identical documents: all compared, nothing added/removed.
        let md = delta_table_md(&baseline, &baseline);
        assert!(md.contains("3 benchmark(s) compared; 0 added; 0 removed."), "{md}");
    }
}
