//! Loopback TCP transport for the fleet fabric.
//!
//! The in-process fabric already round-trips serialized MELB envelopes
//! on every hop; this module puts those same bytes on real sockets.
//! Each [`Node`](super::node::Node) sits behind a [`NodeServer`] — a
//! loopback `TcpListener` whose per-connection handlers read
//! length-prefixed request frames, stamp them against the *node's*
//! clock on receipt (a clock reading cannot cross a serialization
//! boundary), and submit into the node's queue, answering each frame
//! with a one-byte [`ACK`] or (for a dead node) [`NAK`] before closing
//! the connection.  The router talks to each server through a
//! [`NodeClient`] with connect/read timeouts and bounded connect
//! retries; every failure is a typed [`TransportError`] the router
//! treats exactly like a [`QueueClosed`](super::scheduler::QueueClosed)
//! rejection — detect, re-route, re-program, never lose the request.
//! Served responses ride their own uplink sockets into a
//! [`ResponseHub`] that forwards frames to the run's collector.
//!
//! ## Wire format
//!
//! One frame is `[u32 little-endian length][length bytes of MELB
//! envelope]` — the same `u32` length discipline as every MELB field,
//! bounded by [`MAX_WIRE_FRAME`] so a corrupt prefix cannot ask the
//! reader to allocate the moon.  A zero-length frame is malformed (a
//! MELB envelope is never empty).  Request connections additionally
//! carry the one-byte ACK/NAK answer per frame, so the client knows a
//! frame was *accepted* (not merely written) before routing the next;
//! uplink connections are one-way streams of response frames.
//!
//! Design: `rust/DESIGN.md` §19.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::node::Node;

/// Largest frame the reader will accept (64 MiB) — far above any real
/// envelope, small enough that a torn or hostile length prefix fails
/// fast instead of exhausting memory.
pub const MAX_WIRE_FRAME: usize = 64 << 20;

/// "Frame accepted" answer byte (ASCII ACK).
pub const ACK: u8 = 0x06;
/// "Node dead, frame rejected" answer byte (ASCII NAK).  The handler
/// closes the connection after a NAK, so the client also observes the
/// disconnect a real dead peer would produce.
pub const NAK: u8 = 0x15;

/// How long a blocked read polls before re-checking stop/liveness.
const POLL: Duration = Duration::from_millis(20);
/// How long the hub waits for its next uplink before giving up — a
/// bound, not a pace: every healthy uplink dials at run start.  Gives
/// up rather than holding the collector's channel open forever when
/// an uplink died before connecting.
const ACCEPT_DEADLINE: Duration = Duration::from_secs(10);
/// Pause between bounded connect retries.
const RETRY_PAUSE: Duration = Duration::from_millis(10);

/// Socket-transport shape: timeouts and the connect retry budget.
#[derive(Debug, Clone, PartialEq)]
pub struct SocketOptions {
    /// Per-attempt connect timeout.
    pub connect_timeout: Duration,
    /// How long a client waits on an ACK/NAK (and a hub reader on the
    /// next frame) before declaring the peer stalled.
    pub read_timeout: Duration,
    /// Additional connect attempts after the first (bounded retry).
    pub retries: u32,
}

impl Default for SocketOptions {
    fn default() -> Self {
        Self {
            connect_timeout: Duration::from_secs(1),
            read_timeout: Duration::from_secs(5),
            retries: 3,
        }
    }
}

/// Typed transport failures.  Every variant is recoverable by the
/// router the same way a [`QueueClosed`](super::scheduler::QueueClosed)
/// rejection is: mark the node dead, re-route the frame elsewhere.
#[derive(Debug)]
pub enum TransportError {
    /// Could not connect (after the bounded retries).
    Connect(io::Error),
    /// Connect attempts timed out (after the bounded retries).
    ConnectTimeout,
    /// The peer stopped mid-frame or never answered within the read
    /// timeout.
    ReadTimeout,
    /// The peer hung up — cleanly between frames on an uplink is EOF,
    /// but mid-frame or before the ACK it is this.
    PeerDisconnect,
    /// A malformed wire frame (zero or oversized length prefix, or an
    /// unknown answer byte).
    Frame(String),
    /// The node answered [`NAK`]: it is dead and the frame was not
    /// accepted.
    Rejected,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Connect(e) => write!(f, "transport: connect failed: {e}"),
            TransportError::ConnectTimeout => write!(f, "transport: connect timed out"),
            TransportError::ReadTimeout => write!(f, "transport: read timed out mid-frame"),
            TransportError::PeerDisconnect => write!(f, "transport: peer disconnected"),
            TransportError::Frame(s) => write!(f, "transport: bad frame: {s}"),
            TransportError::Rejected => write!(f, "transport: node rejected the frame (NAK)"),
        }
    }
}

impl std::error::Error for TransportError {}

/// What one `read_frame` call observed.
#[derive(Debug)]
pub enum FrameRead {
    /// A complete frame.
    Frame(Vec<u8>),
    /// Clean end-of-stream on a frame boundary.
    Eof,
    /// No bytes at all within the socket's read timeout — the stream
    /// is merely quiet, not torn.  Callers poll again (after checking
    /// their stop flag).
    Idle,
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Fill `buf` from `r`, distinguishing the three ways a read can end
/// early.  `got` counts bytes already consumed *of this frame* — with
/// any consumed, a timeout is a torn frame ([`TransportError::ReadTimeout`])
/// and EOF is a disconnect, never `Idle`/`Eof`.
fn read_exact_frame(
    r: &mut impl Read,
    buf: &mut [u8],
    mut got: usize,
) -> Result<FrameRead, TransportError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) if got == 0 => return Ok(FrameRead::Eof),
            Ok(0) => return Err(TransportError::PeerDisconnect),
            Ok(n) => {
                filled += n;
                got += n;
            }
            Err(e) if is_timeout(&e) && got == 0 => return Ok(FrameRead::Idle),
            Err(e) if is_timeout(&e) => return Err(TransportError::ReadTimeout),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            // On loopback the residual I/O failures (reset, broken
            // pipe, unexpected EOF) all mean the peer went away.
            Err(_) => return Err(TransportError::PeerDisconnect),
        }
    }
    Ok(FrameRead::Frame(Vec::new())) // placeholder; callers use `buf`
}

/// Read one length-prefixed frame.  `Idle`/`Eof` only ever happen on a
/// frame boundary; once any byte of a frame has arrived, stopping is a
/// typed error.
pub fn read_frame(r: &mut impl Read) -> Result<FrameRead, TransportError> {
    let mut len_buf = [0u8; 4];
    match read_exact_frame(r, &mut len_buf, 0)? {
        FrameRead::Eof => return Ok(FrameRead::Eof),
        FrameRead::Idle => return Ok(FrameRead::Idle),
        FrameRead::Frame(_) => {}
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 {
        return Err(TransportError::Frame("zero-length frame".into()));
    }
    if len > MAX_WIRE_FRAME {
        return Err(TransportError::Frame(format!(
            "declared length {len} exceeds MAX_WIRE_FRAME ({MAX_WIRE_FRAME})"
        )));
    }
    let mut body = vec![0u8; len];
    match read_exact_frame(r, &mut body, 4)? {
        FrameRead::Frame(_) => Ok(FrameRead::Frame(body)),
        // got > 0 makes Eof/Idle unreachable here.
        _ => Err(TransportError::PeerDisconnect),
    }
}

/// Write one length-prefixed frame.  The length prefix shares the MELB
/// `u32` bound; an oversized frame is refused before any byte is
/// written, so a torn prefix is never emitted.
pub fn write_frame(w: &mut impl Write, bytes: &[u8]) -> Result<(), TransportError> {
    if bytes.is_empty() || bytes.len() > MAX_WIRE_FRAME {
        return Err(TransportError::Frame(format!(
            "refusing to write a {}-byte frame",
            bytes.len()
        )));
    }
    let len = bytes.len() as u32; // <= MAX_WIRE_FRAME < u32::MAX
    let res = w
        .write_all(&len.to_le_bytes())
        .and_then(|()| w.write_all(bytes));
    res.map_err(|e| {
        if is_timeout(&e) {
            TransportError::ReadTimeout
        } else {
            TransportError::PeerDisconnect
        }
    })
}

// ---------------------------------------------------------------------------
// Client side: the router's connection to one node.
// ---------------------------------------------------------------------------

/// The router's handle to one node's request listener: a single
/// pooled connection (lazily established, re-established after any
/// error) plus the timeout/retry discipline.  `send` is
/// request/answer-strict: the ACK is read before the next frame may be
/// written, so frames never interleave on the wire.
pub struct NodeClient {
    addr: SocketAddr,
    opts: SocketOptions,
    conn: Mutex<Option<TcpStream>>,
}

impl NodeClient {
    /// A client for the server at `addr`.  No connection is made yet —
    /// the first `send` pays it (and its retries).
    pub fn new(addr: SocketAddr, opts: SocketOptions) -> Self {
        Self { addr, opts, conn: Mutex::new(None) }
    }

    /// The server address this client dials.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn connect(&self) -> Result<TcpStream, TransportError> {
        let mut last = TransportError::ConnectTimeout;
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                std::thread::sleep(RETRY_PAUSE);
            }
            match TcpStream::connect_timeout(&self.addr, self.opts.connect_timeout) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    let _ = s.set_read_timeout(Some(self.opts.read_timeout));
                    let _ = s.set_write_timeout(Some(self.opts.read_timeout));
                    return Ok(s);
                }
                Err(e) if is_timeout(&e) => last = TransportError::ConnectTimeout,
                Err(e) => last = TransportError::Connect(e),
            }
        }
        Err(last)
    }

    /// Send one request frame and wait for the node's answer byte.
    /// Any failure drops the pooled connection (the next send
    /// re-dials) and returns the typed error; the caller still owns
    /// `frame` and re-routes it.
    pub fn send(&self, frame: &[u8]) -> Result<(), TransportError> {
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(self.connect()?);
        }
        let stream = guard.as_mut().expect("connection just ensured");
        let result = Self::send_on(stream, frame);
        if result.is_err() {
            *guard = None; // poison the pooled connection
        }
        result
    }

    fn send_on(stream: &mut TcpStream, frame: &[u8]) -> Result<(), TransportError> {
        write_frame(stream, frame)?;
        // One answer byte, within the stream's read timeout.  A quiet
        // socket here is a stalled node, not idleness — the frame was
        // already delivered, so `Idle` means the answer never came.
        let mut answer = [0u8; 1];
        match read_exact_frame(stream, &mut answer, 0)? {
            FrameRead::Frame(_) => {}
            FrameRead::Eof => return Err(TransportError::PeerDisconnect),
            FrameRead::Idle => return Err(TransportError::ReadTimeout),
        }
        match answer[0] {
            ACK => Ok(()),
            NAK => Err(TransportError::Rejected),
            b => Err(TransportError::Frame(format!("unknown answer byte {b:#04x}"))),
        }
    }
}

// ---------------------------------------------------------------------------
// Server side: one node behind a listener.
// ---------------------------------------------------------------------------

/// One node's request listener: an accept loop on an ephemeral
/// loopback port, one handler thread per connection.  Handlers stamp
/// each frame with the node's clock *on receipt* — the submit stamp
/// cannot ride the wire — and answer ACK/NAK per frame.
pub struct NodeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NodeServer {
    /// Bind an ephemeral loopback port for `node` and start accepting.
    pub fn spawn(node: Arc<Node>, opts: &SocketOptions) -> io::Result<NodeServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            let read_timeout = opts.read_timeout;
            std::thread::spawn(move || {
                let mut handlers: Vec<JoinHandle<()>> = Vec::new();
                while !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = stream.set_nodelay(true);
                            // Poll-read so the handler can observe the
                            // stop flag while the connection is quiet.
                            let _ = stream.set_read_timeout(Some(POLL));
                            let _ = stream.set_write_timeout(Some(read_timeout));
                            let node = Arc::clone(&node);
                            let stop = Arc::clone(&stop);
                            handlers.push(std::thread::spawn(move || {
                                Self::handle(stream, &node, &stop);
                            }));
                        }
                        Err(ref e) if is_timeout(e) => std::thread::sleep(POLL),
                        Err(_) => break,
                    }
                }
                for h in handlers {
                    let _ = h.join();
                }
            })
        };
        Ok(NodeServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address clients dial.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// One connection: read frames until EOF, error, or stop.
    fn handle(mut stream: TcpStream, node: &Node, stop: &AtomicBool) {
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match read_frame(&mut stream) {
                Ok(FrameRead::Idle) => continue,
                Ok(FrameRead::Eof) | Err(_) => return,
                Ok(FrameRead::Frame(bytes)) => {
                    // The submit stamp is taken here, on the node's
                    // clock: queue-wait and latency subtract readings
                    // of one clock, exactly as in-process.
                    let frame = super::transport::Frame {
                        bytes,
                        submitted_ns: node.now_ns(),
                    };
                    match node.submit(frame) {
                        Ok(()) => {
                            if stream.write_all(&[ACK]).is_err() {
                                return;
                            }
                        }
                        Err(_closed) => {
                            // A dead node NAKs and hangs up — the
                            // client sees both the typed rejection and
                            // the disconnect a real dead peer gives.
                            let _ = stream.write_all(&[NAK]);
                            return;
                        }
                    }
                }
            }
        }
    }

    /// Stop accepting and join every thread.  In-flight handler reads
    /// finish their current poll (bounded by [`POLL`]) first.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NodeServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Response path: per-node uplinks into one hub.
// ---------------------------------------------------------------------------

/// The run's response funnel: accepts exactly `expected` uplink
/// connections (one per node) and forwards every frame they carry
/// into the collector's channel.  Readers exit on uplink EOF; the
/// accept loop exits once all uplinks have arrived (or on shutdown).
pub struct ResponseHub {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ResponseHub {
    /// Bind the hub and start accepting `expected` uplinks, forwarding
    /// their frames into `out`.
    pub fn spawn(expected: usize, out: mpsc::Sender<Vec<u8>>) -> io::Result<ResponseHub> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut readers: Vec<JoinHandle<()>> = Vec::new();
                let mut last = std::time::Instant::now();
                while readers.len() < expected && !stop.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            last = std::time::Instant::now();
                            let _ = stream.set_read_timeout(Some(POLL));
                            let out = out.clone();
                            let stop = Arc::clone(&stop);
                            readers.push(std::thread::spawn(move || {
                                Self::read_uplink(stream, &out, &stop);
                            }));
                        }
                        Err(ref e) if is_timeout(e) => {
                            if last.elapsed() > ACCEPT_DEADLINE {
                                break;
                            }
                            std::thread::sleep(POLL);
                        }
                        Err(_) => break,
                    }
                }
                drop(out); // the collector ends when every reader is done
                for r in readers {
                    let _ = r.join();
                }
            })
        };
        Ok(ResponseHub { addr, stop, accept: Some(accept) })
    }

    /// The bound address uplinks dial.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    fn read_uplink(mut stream: TcpStream, out: &mpsc::Sender<Vec<u8>>, stop: &AtomicBool) {
        loop {
            match read_frame(&mut stream) {
                Ok(FrameRead::Idle) => {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Ok(FrameRead::Eof) | Err(_) => return,
                Ok(FrameRead::Frame(bytes)) => {
                    if out.send(bytes).is_err() {
                        return; // run tearing down
                    }
                }
            }
        }
    }

    /// Join the hub (all uplinks seen and drained, or forced).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ResponseHub {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// One node's response uplink: drain `rx` (the channel the node's
/// workers emit serialized responses into) onto a TCP connection to
/// the hub, then flush and hang up.  Connect failures drop the frames
/// on the floor — the collector's count then misses and the run fails
/// loudly rather than silently.
pub fn spawn_uplink(
    hub: SocketAddr,
    rx: mpsc::Receiver<Vec<u8>>,
    opts: &SocketOptions,
) -> JoinHandle<()> {
    let opts = opts.clone();
    std::thread::spawn(move || {
        let mut last_err = None;
        let mut stream = None;
        for attempt in 0..=opts.retries {
            if attempt > 0 {
                std::thread::sleep(RETRY_PAUSE);
            }
            match TcpStream::connect_timeout(&hub, opts.connect_timeout) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    let _ = s.set_write_timeout(Some(opts.read_timeout));
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        drop(last_err);
        // With no connection (or after a write failure) keep draining
        // the channel so node workers never block on a closed pipe.
        let mut broken = false;
        while let Ok(frame) = rx.recv() {
            if broken {
                continue;
            }
            if let Some(s) = stream.as_mut() {
                broken = write_frame(s, &frame).is_err();
            }
        }
        if let Some(mut s) = stream {
            let _ = s.flush();
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::serve::bench::ServeOptions;
    use crate::serve::transport::RequestEnvelope;
    use crate::vmm::{DynEngine, NativeEngine};
    use std::io::Write as _;

    fn quick_opts() -> SocketOptions {
        SocketOptions {
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(200),
            retries: 1,
        }
    }

    fn serve_opts() -> ServeOptions {
        ServeOptions {
            clients: 1,
            requests_per_client: 4,
            models: 2,
            rows: 8,
            cols: 8,
            queue_capacity: 8,
            batch_max: 4,
            window: Duration::from_micros(0),
            workers: 1,
            cache: true,
            cache_capacity: 4,
            measure_error: false,
            ..ServeOptions::default()
        }
    }

    fn test_node() -> Arc<Node> {
        let engine = DynEngine::new(NativeEngine::default());
        Arc::new(Node::new(0, engine, &serve_opts()))
    }

    #[test]
    fn frame_round_trip_on_a_socket() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            match read_frame(&mut s).unwrap() {
                FrameRead::Frame(b) => write_frame(&mut s, &b).unwrap(),
                other => panic!("expected a frame, got {other:?}"),
            }
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let payload: Vec<u8> = (0..=255).collect();
        write_frame(&mut c, &payload).unwrap();
        match read_frame(&mut c).unwrap() {
            FrameRead::Frame(b) => assert_eq!(b, payload),
            other => panic!("expected the echo, got {other:?}"),
        }
        echo.join().unwrap();
    }

    #[test]
    fn bad_length_prefixes_are_typed_frame_errors() {
        // Zero length.
        let mut z: &[u8] = &0u32.to_le_bytes();
        assert!(matches!(read_frame(&mut z), Err(TransportError::Frame(_))));
        // Oversized length.
        let mut o: &[u8] = &u32::MAX.to_le_bytes();
        assert!(matches!(read_frame(&mut o), Err(TransportError::Frame(_))));
        // Writer refuses the same bounds before emitting anything.
        let mut sink = Vec::new();
        assert!(matches!(
            write_frame(&mut sink, &[]),
            Err(TransportError::Frame(_))
        ));
        assert!(sink.is_empty(), "no bytes of a refused frame hit the wire");
    }

    #[test]
    fn torn_frames_read_as_peer_disconnect_never_idle() {
        // EOF mid-prefix.
        let mut cut: &[u8] = &[9, 0];
        assert!(matches!(
            read_frame(&mut cut),
            Err(TransportError::PeerDisconnect)
        ));
        // EOF mid-body.
        let mut torn: Vec<u8> = 9u32.to_le_bytes().to_vec();
        torn.extend_from_slice(&[1, 2, 3]);
        let mut torn = torn.as_slice();
        assert!(matches!(
            read_frame(&mut torn),
            Err(TransportError::PeerDisconnect)
        ));
        // A clean boundary is Eof, and an empty read source too.
        let mut empty: &[u8] = &[];
        assert!(matches!(read_frame(&mut empty), Ok(FrameRead::Eof)));
    }

    #[test]
    fn mid_stream_disconnect_over_a_real_socket_is_typed() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let half = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Write a torn frame: the prefix promises 100 bytes, only
            // 3 arrive before the peer hangs up.
            s.write_all(&100u32.to_le_bytes()).unwrap();
            s.write_all(&[1, 2, 3]).unwrap();
            // drop(s): mid-frame disconnect
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
        half.join().unwrap();
        assert!(matches!(
            read_frame(&mut c),
            Err(TransportError::PeerDisconnect)
        ));
    }

    #[test]
    fn connect_refused_is_a_typed_error_after_bounded_retries() {
        // Bind then drop: the port is (almost surely) refusing now.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let client = NodeClient::new(addr, quick_opts());
        match client.send(&[1, 2, 3]) {
            Err(TransportError::Connect(_)) | Err(TransportError::ConnectTimeout) => {}
            other => panic!("expected a typed connect failure, got {other:?}"),
        }
    }

    #[test]
    fn silent_server_times_out_the_answer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // Accept, read the frame, answer nothing.
        let mute = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let _ = read_frame(&mut s);
            std::thread::sleep(Duration::from_millis(600));
        });
        let client = NodeClient::new(addr, quick_opts());
        assert!(matches!(
            client.send(&[1, 2, 3]),
            Err(TransportError::ReadTimeout)
        ));
        mute.join().unwrap();
    }

    #[test]
    fn node_server_acks_live_frames_and_naks_dead_ones() {
        let node = test_node();
        let opts = quick_opts();
        let server = NodeServer::spawn(Arc::clone(&node), &opts).unwrap();
        let client = NodeClient::new(server.addr(), opts);
        let env = RequestEnvelope { model: 0, id: 7, x: vec![0.5; 8] };
        let bytes = env.encode().unwrap();
        client.send(&bytes).unwrap();
        assert_eq!(node.load(), 1, "accepted frame is queued");
        // Kill the node: the same send now comes back Rejected, and
        // the handler hangs up (a fresh connection is dialed next).
        node.fail();
        assert!(matches!(
            client.send(&bytes),
            Err(TransportError::Rejected)
        ));
        server.shutdown();
    }

    #[test]
    fn node_behind_socket_serves_bit_identically_to_direct_submit() {
        let opts = serve_opts();
        let device = presets::epiram().params;
        let specs = opts.model_specs();
        let inputs = opts.request_inputs();
        let engine = DynEngine::new(NativeEngine::default());

        // Direct: submit frames into a node in-process.
        let direct = Arc::new(Node::new(0, engine.clone(), &opts));
        let (dtx, drx) = mpsc::channel();
        for id in 0..4u64 {
            let env = RequestEnvelope {
                model: id as usize % 2,
                id,
                x: inputs.sample(id as usize),
            };
            direct
                .submit(super::super::transport::Frame {
                    bytes: env.encode().unwrap(),
                    submitted_ns: direct.now_ns(),
                })
                .unwrap();
        }
        direct.shutdown();
        direct.worker_loop(&device, &specs, &opts, &dtx).unwrap();
        drop(dtx);
        let mut want: Vec<(u64, Vec<u8>)> = drx
            .iter()
            .map(|b| {
                let (r, _) = super::super::transport::ResponseEnvelope::decode(&b).unwrap();
                (r.id, b)
            })
            .collect();
        want.sort_by_key(|(id, _)| *id);

        // Socket: the same frames through listener, queue, and uplink.
        let sock = quick_opts();
        let node = Arc::new(Node::new(0, engine, &opts));
        let server = NodeServer::spawn(Arc::clone(&node), &sock).unwrap();
        let (ctx, crx) = mpsc::channel();
        let hub = ResponseHub::spawn(1, ctx).unwrap();
        let (utx, urx) = mpsc::channel();
        let uplink = spawn_uplink(hub.addr(), urx, &sock);
        let client = NodeClient::new(server.addr(), sock);
        for id in 0..4u64 {
            let env = RequestEnvelope {
                model: id as usize % 2,
                id,
                x: inputs.sample(id as usize),
            };
            client.send(&env.encode().unwrap()).unwrap();
        }
        node.shutdown();
        node.worker_loop(&device, &specs, &opts, &utx).unwrap();
        drop(utx);
        uplink.join().unwrap();
        let mut got: Vec<(u64, Vec<u8>)> = crx
            .iter()
            .map(|b| {
                let (r, _) = super::super::transport::ResponseEnvelope::decode(&b).unwrap();
                (r.id, b)
            })
            .collect();
        got.sort_by_key(|(id, _)| *id);
        hub.shutdown();
        server.shutdown();

        assert_eq!(got.len(), 4);
        assert_eq!(got, want, "socket and direct response bytes are identical");
    }
}
