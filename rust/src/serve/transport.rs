//! Serialized transport boundary of the fleet fabric.
//!
//! Typed request/response envelopes, serialized through the MELB
//! codec's envelope framing ([`crate::util::codec::encode_envelope`])
//! and carried between router and nodes as raw byte frames over
//! in-process `mpsc` channels.  Every hop round-trips *bytes*, not
//! references: the router decodes a client frame to place it, forwards
//! the same bytes, and the node decodes them again before serving — so
//! the fabric pays honest (de)serialization cost on every request from
//! day one, and swapping the channel for a socket later changes no
//! envelope code.
//!
//! `f32` payloads survive exactly: each entry is widened to `f64` for
//! the MELB `Num` tag (every `f32` is exactly representable) and
//! narrowed back on decode, so a served `y` is bit-identical across
//! the wire.  Framing contract: `rust/DESIGN.md` §16.

use crate::error::{Error, Result};
use crate::obs::{self, CounterId, Stage};
use crate::util::codec::{
    decode_envelope, encode_envelope, ENVELOPE_REQUEST, ENVELOPE_RESPONSE,
};
use crate::util::json::{obj, Json};

/// One single-vector VMM request on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestEnvelope {
    /// Deployed model (weight matrix) this request targets.
    pub model: usize,
    /// Global request id.
    pub id: u64,
    /// Input vector (`rows` entries).
    pub x: Vec<f32>,
}

/// One served output on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseEnvelope {
    /// Echo of the request id.
    pub id: u64,
    /// Echo of the model id.
    pub model: usize,
    /// Fleet node that served the request.
    pub node: usize,
    /// The served output vector (`cols` entries).
    pub y: Vec<f32>,
    /// Sum of `|y_hw - y_sw|` over this request's columns when the
    /// node measures error; `0.0` otherwise.
    pub err_abs_sum: f64,
    /// Number of columns behind `err_abs_sum` (`0` when unmeasured).
    pub err_cols: usize,
}

/// A request frame in flight inside a node: the raw bytes plus the
/// submit timestamp the node uses for its queue+service latency
/// telemetry.  The stamp is a [`crate::obs::Clock`] reading in
/// nanoseconds — not an `Instant` — so fleet latency accounting goes
/// through the same mockable clock as the scheduler's, and tests can
/// drive it deterministically.  It rides next to the frame, never
/// inside it: a local clock reading cannot cross a serialization
/// boundary (the socket transport re-stamps on receipt).
#[derive(Debug)]
pub struct Frame {
    /// Serialized [`RequestEnvelope`] bytes.
    pub bytes: Vec<u8>,
    /// Clock reading (ns) when the frame entered the node's queue —
    /// taken from the clock of whichever side did the submitting (the
    /// router in-process, the node's connection handler over sockets).
    pub submitted_ns: u64,
}

fn f32_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&v| Json::Num(f64::from(v))).collect())
}

fn get_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Parse(format!("envelope: missing/invalid '{key}'")))
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Parse(format!("envelope: missing/invalid '{key}'")))
}

fn get_f32_arr(v: &Json, key: &str) -> Result<Vec<f32>> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Parse(format!("envelope: missing/invalid '{key}'")))?;
    arr.iter()
        .map(|e| {
            e.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| Error::Parse(format!("envelope: non-numeric entry in '{key}'")))
        })
        .collect()
}

impl RequestEnvelope {
    /// Serialize to one MELB envelope frame.  Fails (typed
    /// [`Error::Parse`]) only if a payload segment would overflow the
    /// u32 frame field — a corrupt frame is never emitted.
    pub fn encode(&self) -> Result<Vec<u8>> {
        let span = obs::stage_start();
        let payload = obj([
            ("model", Json::Num(self.model as f64)),
            ("id", Json::Num(self.id as f64)),
            ("x", f32_arr(&self.x)),
        ]);
        let frame = encode_envelope(ENVELOPE_REQUEST, &payload)?;
        obs::stage_end(Stage::TransportEncode, span);
        obs::add(CounterId::BytesOut, frame.len() as u64);
        Ok(frame)
    }

    /// Decode one request frame from the head of `bytes`, returning
    /// the envelope and the bytes consumed.
    pub fn decode(bytes: &[u8]) -> Result<(RequestEnvelope, usize)> {
        let span = obs::stage_start();
        let (tag, payload, used) = decode_envelope(bytes)?;
        obs::stage_end(Stage::TransportDecode, span);
        obs::add(CounterId::BytesIn, used as u64);
        if tag != ENVELOPE_REQUEST {
            return Err(Error::Parse(format!(
                "envelope: tag {tag:#x} where a request ({ENVELOPE_REQUEST:#x}) \
                 was expected"
            )));
        }
        Ok((
            RequestEnvelope {
                model: get_usize(&payload, "model")?,
                id: get_f64(&payload, "id")? as u64,
                x: get_f32_arr(&payload, "x")?,
            },
            used,
        ))
    }
}

impl ResponseEnvelope {
    /// Serialize to one MELB envelope frame (fallible like
    /// [`RequestEnvelope::encode`]).
    pub fn encode(&self) -> Result<Vec<u8>> {
        let span = obs::stage_start();
        let payload = obj([
            ("id", Json::Num(self.id as f64)),
            ("model", Json::Num(self.model as f64)),
            ("node", Json::Num(self.node as f64)),
            ("y", f32_arr(&self.y)),
            ("err_abs_sum", Json::Num(self.err_abs_sum)),
            ("err_cols", Json::Num(self.err_cols as f64)),
        ]);
        let frame = encode_envelope(ENVELOPE_RESPONSE, &payload)?;
        obs::stage_end(Stage::TransportEncode, span);
        obs::add(CounterId::BytesOut, frame.len() as u64);
        Ok(frame)
    }

    /// Decode one response frame from the head of `bytes`, returning
    /// the envelope and the bytes consumed.
    pub fn decode(bytes: &[u8]) -> Result<(ResponseEnvelope, usize)> {
        let span = obs::stage_start();
        let (tag, payload, used) = decode_envelope(bytes)?;
        obs::stage_end(Stage::TransportDecode, span);
        obs::add(CounterId::BytesIn, used as u64);
        if tag != ENVELOPE_RESPONSE {
            return Err(Error::Parse(format!(
                "envelope: tag {tag:#x} where a response ({ENVELOPE_RESPONSE:#x}) \
                 was expected"
            )));
        }
        Ok((
            ResponseEnvelope {
                id: get_f64(&payload, "id")? as u64,
                model: get_usize(&payload, "model")?,
                node: get_usize(&payload, "node")?,
                y: get_f32_arr(&payload, "y")?,
                err_abs_sum: get_f64(&payload, "err_abs_sum")?,
                err_cols: get_usize(&payload, "err_cols")?,
            },
            used,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_is_bit_exact() {
        let req = RequestEnvelope {
            model: 3,
            id: 41,
            x: vec![0.1_f32, -2.5, f32::MIN_POSITIVE, 1.0 + f32::EPSILON],
        };
        let bytes = req.encode().unwrap();
        let (back, used) = RequestEnvelope::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back.model, 3);
        assert_eq!(back.id, 41);
        for (a, b) in back.x.iter().zip(&req.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "f32 bits must survive the wire");
        }
    }

    #[test]
    fn response_roundtrip_and_tag_mismatch() {
        let resp = ResponseEnvelope {
            id: 7,
            model: 1,
            node: 2,
            y: vec![3.25, -0.5],
            err_abs_sum: 0.125,
            err_cols: 2,
        };
        let bytes = resp.encode().unwrap();
        let (back, used) = ResponseEnvelope::decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, resp);
        // A response frame is not a request frame, and vice versa.
        assert!(RequestEnvelope::decode(&bytes).is_err());
        let req = RequestEnvelope { model: 0, id: 0, x: vec![1.0] };
        assert!(ResponseEnvelope::decode(&req.encode().unwrap()).is_err());
    }

    #[test]
    fn truncated_frames_are_typed_errors() {
        let bytes = RequestEnvelope { model: 0, id: 9, x: vec![1.0, 2.0] }
            .encode()
            .unwrap();
        for cut in 0..bytes.len() {
            assert!(RequestEnvelope::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }
}
