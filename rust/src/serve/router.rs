//! The fleet router: consistent-hash placement of model digests onto
//! nodes, replication with deterministic replica choice, node-failure
//! injection with detect → re-route → re-program recovery, and the
//! fleet-wide telemetry rollup.
//!
//! ## Placement
//!
//! Each node contributes [`VNODES`] points to a hash ring (FNV-1a over
//! `(ring tag, node, vnode)` — the same stream hash the program cache
//! keys with); a model digest walks the ring clockwise from its own
//! hash, collecting the first `replication` distinct *live* nodes.
//! Because the walk skips dead nodes in place, removing a node only
//! re-places the models whose replica walk passed through it — every
//! other digest sees an unchanged prefix and keeps its assignment
//! (`rust/tests/proptests.rs` checks exactly this).  Within a replica
//! set the router picks the *least-loaded* live replica (each node's
//! queue depth plus in-flight frames, [`super::node::Node::load`]);
//! ties keep the earliest ring-walk position, so equal-load picks are
//! deterministic — and because a served `y` is a pure function of
//! `(spec, device, x)` under program-once, load-dependent placement
//! never changes a single output bit.
//!
//! ## Failure and recovery
//!
//! Failure injection kills a node (its queue closes and drains — see
//! [`super::scheduler::BoundedQueue`]) *without telling the router*.
//! The router discovers the death the way a real fabric does: a
//! submit against the dead node comes back as a typed
//! [`QueueClosed`](super::scheduler::QueueClosed) rejection carrying
//! the frame (or, over sockets, as a typed
//! [`TransportError`](super::socket::TransportError) — NAK, timeout,
//! or disconnect — handled identically), and the router marks the node
//! dead (detect), re-assigns the digest over the surviving ring
//! (re-route), and the surviving replica's cold cache re-programs the
//! model on first touch (re-program).  Replicas already tried for a
//! request are skipped within that request, so two simultaneous deaths
//! cost two detours, never a loop.  Rejected-then-re-routed pushes are
//! counted as `shed`; no request is ever lost.
//!
//! ## Transports
//!
//! [`FleetOptions::transport`] selects how frames travel.
//! [`Transport::InProcess`] (default) submits directly into node
//! queues; [`Transport::Socket`] runs every node behind a loopback TCP
//! listener and the responses over uplink sockets into a hub
//! ([`super::socket`]).  Both lanes carry the identical MELB envelope
//! bytes, so per-request responses are bit-identical across
//! transports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::device::params::DeviceParams;
use crate::error::{Error, Result};
use crate::obs::{self, Clock, CounterId, HistogramSnapshot, MonotonicClock};
use crate::util::progress::Stopwatch;
use crate::util::rng::Xoshiro256;
use crate::vmm::{DynEngine, ProgramSpec, ShardCounts, VmmEngine};

use super::bench::{capacity_projection, ServeOptions, ServeReport};
use super::cache::fnv1a;
use super::node::{Node, NodeReport};
use super::socket::{spawn_uplink, NodeClient, NodeServer, ResponseHub, SocketOptions};
use super::transport::{Frame, RequestEnvelope, ResponseEnvelope};

/// Virtual points each node contributes to the placement ring.
const VNODES: usize = 16;
/// Stream tag separating ring points from every other FNV-1a use.
const RING_TAG: u64 = 0x524F_5554; // "ROUT"

/// Digest identifying one deployed model for placement: geometry,
/// weight bits, and programming-noise label — the same identity the
/// program cache keys on, so two placement-equal models are
/// cache-equal on whichever node they land.
pub fn model_digest(spec: &ProgramSpec) -> u64 {
    fnv1a(
        [spec.rows as u64, spec.cols as u64, spec.program_seed]
            .into_iter()
            .chain(spec.w.iter().map(|v| u64::from(v.to_bits()))),
    )
}

/// Consistent-hash placement of model digests onto a fixed node set
/// with some nodes possibly dead.
#[derive(Debug, Clone)]
pub struct Placement {
    /// `(point, node)`, sorted by point.
    ring: Vec<(u64, usize)>,
    alive: Vec<bool>,
    replication: usize,
}

impl Placement {
    /// A ring over `nodes` nodes (clamped to at least 1) with the
    /// given replication factor (clamped to `1..=nodes`).
    pub fn new(nodes: usize, replication: usize) -> Placement {
        let nodes = nodes.max(1);
        let mut ring: Vec<(u64, usize)> = (0..nodes)
            .flat_map(|n| {
                (0..VNODES).map(move |v| (fnv1a([RING_TAG, n as u64, v as u64]), n))
            })
            .collect();
        ring.sort_unstable();
        Placement {
            ring,
            alive: vec![true; nodes],
            replication: replication.clamp(1, nodes),
        }
    }

    /// Total nodes (live or dead).
    pub fn nodes(&self) -> usize {
        self.alive.len()
    }

    /// Configured replication factor.
    pub fn replication(&self) -> usize {
        self.replication
    }

    /// Live nodes remaining.
    pub fn live(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Is `node` still live? (Out-of-range nodes read as dead.)
    pub fn is_alive(&self, node: usize) -> bool {
        self.alive.get(node).copied().unwrap_or(false)
    }

    /// Mark a node dead; its models re-place onto survivors.
    pub fn fail(&mut self, node: usize) {
        if let Some(a) = self.alive.get_mut(node) {
            *a = false;
        }
    }

    /// The live replica set of `digest`: walk the ring clockwise from
    /// the digest's point, collecting distinct live nodes until
    /// `replication` are found (or every live node has been seen —
    /// fewer live nodes than replicas means the whole survivor set).
    /// Pure function of `(ring, alive, digest)` — deterministic for
    /// any thread count.
    pub fn assign(&self, digest: u64) -> Vec<usize> {
        let want = self.replication.min(self.live());
        let mut out = Vec::with_capacity(want);
        if want == 0 {
            return out;
        }
        let start = self.ring.partition_point(|&(p, _)| p < digest);
        for i in 0..self.ring.len() {
            let (_, n) = self.ring[(start + i) % self.ring.len()];
            if self.alive[n] && !out.contains(&n) {
                out.push(n);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }
}

/// How request and response frames travel between router and nodes.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum Transport {
    /// Frames cross in-process channels (the default): the router
    /// submits into node queues directly, responses ride one `mpsc`.
    #[default]
    InProcess,
    /// Every node sits behind a loopback TCP listener and responses
    /// travel uplink sockets ([`super::socket`]).  Same envelope
    /// bytes, same outputs — plus real connect/read timeouts, framing,
    /// and disconnect semantics.
    Socket(SocketOptions),
}

/// One fleet run's shape.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// The per-run serving shape (clients, requests, models, batching,
    /// per-node cache/queue/worker configuration, seeds).
    pub serve: ServeOptions,
    /// Fleet size.
    pub nodes: usize,
    /// Replicas per model digest (clamped to the fleet size).
    pub replication: usize,
    /// Failure-injection intensity: `ceil(fail_rate * (nodes - 1))`
    /// victims (clamped to keep at least one node alive; 0.0 disables,
    /// as does a 1-node fleet).  Victims are the heaviest model owners
    /// so the recovery path is actually exercised, each dying at a
    /// seeded point mid-stream.
    pub fail_rate: f64,
    /// Seed of the failure-point draws.
    pub fail_seed: u64,
    /// Keep every served output (id-ordered) in the report — the
    /// bit-identity harness; off for pure benchmarking.
    pub collect_responses: bool,
    /// How frames travel between router and nodes.
    pub transport: Transport,
}

impl Default for FleetOptions {
    fn default() -> Self {
        Self {
            serve: ServeOptions::default(),
            nodes: 2,
            replication: 1,
            fail_rate: 0.0,
            fail_seed: 0x464C_4554, // "FLET"
            collect_responses: false,
            transport: Transport::InProcess,
        }
    }
}

/// Fleet-wide telemetry rollup plus the per-node breakdown.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// The familiar serving rollup: requests, batches, end-to-end
    /// latency percentiles, summed cache counters, programs, error,
    /// and the capacity projection (see [`FleetReport::per_node_rps`]).
    pub aggregate: ServeReport,
    /// Per-node telemetry (cache, latency, shard counters, bytes).
    pub nodes: Vec<NodeReport>,
    /// Replication factor the run actually used.
    pub replication: usize,
    /// Typed push rejections against dead nodes that were re-routed to
    /// a surviving replica.  Every shed request was served — shed
    /// counts detours, not losses.
    pub shed: u64,
    /// Nodes that died during the run.
    pub failed_nodes: Vec<usize>,
    /// Models whose replica set included a failed node — re-placed
    /// onto survivors and re-programmed there on first touch.
    pub recovered_models: u64,
    /// Serialized bytes through the transport boundary (request frames
    /// decoded by nodes + response frames emitted).
    pub transport_bytes: u64,
    /// Fleet-wide ABFT rollup (summed per-node deltas; `None` when no
    /// engine shards).
    pub shard: Option<ShardCounts>,
    /// Fitted requests/sec of a single node of this fabric
    /// (`aggregate.fitted_rps / nodes`); the aggregate's
    /// `nodes_for_1e8_per_day` projects from this per-node rate.
    pub per_node_rps: f64,
    /// Served outputs by request id, when collected.
    pub responses: Option<Vec<(u64, Vec<f32>)>>,
}

/// What the response collector accumulates.
struct Collected {
    count: usize,
    latency: HistogramSnapshot,
    /// Per-request `sum |err|` by id (0.0 when unmeasured).
    err_by_id: Vec<f64>,
    /// Total measured columns.
    err_cols: usize,
    /// `(wall secs, cumulative responses)` capacity-projection points.
    points: Vec<(f64, f64)>,
    responses: Option<Vec<Option<(u64, Vec<f32>)>>>,
}

/// Least-loaded live replica not yet tried for this request; `None`
/// only if every replica was already tried.  Strictly-less comparison
/// keeps the earliest ring-walk position on ties, so equal-load picks
/// are deterministic regardless of iteration timing.
fn pick_replica(
    replicas: &[usize],
    tried: &[usize],
    load: impl Fn(usize) -> u64,
) -> Option<usize> {
    let mut best: Option<(u64, usize)> = None;
    for &n in replicas {
        if tried.contains(&n) {
            continue;
        }
        let l = load(n);
        if best.map_or(true, |(bl, _)| l < bl) {
            best = Some((l, n));
        }
    }
    best.map(|(_, n)| n)
}

/// The submit lane the router pushes frames down.
enum Lane<'a> {
    /// Direct submits into node queues.
    Direct,
    /// Per-node socket clients.
    Socket(&'a [NodeClient]),
}

struct Router<'a> {
    /// The nodes themselves — the load signal (and failure injection)
    /// read these directly even in socket mode; in a real deployment
    /// load would ride a heartbeat, the routing logic is the same.
    nodes: &'a [Arc<Node>],
    lane: Lane<'a>,
    /// The run's shared clock: submit stamps and collector latency
    /// subtract readings of this one instance.
    clock: Arc<dyn Clock>,
    placement: Mutex<Placement>,
    digests: &'a [u64],
    /// Requests routed so far (drives failure injection).
    routed: AtomicU64,
    shed: AtomicU64,
    /// `(routed-count threshold, victim)`, ascending by threshold.
    pending_failures: Mutex<Vec<(u64, usize)>>,
}

impl Router<'_> {
    /// Route one serialized request frame: decode (the router pays the
    /// transport boundary too), place, pick the least-loaded untried
    /// replica, submit — and on a typed rejection (queue-closed in
    /// process, NAK/timeout/disconnect over sockets), detect the dead
    /// node, re-place, and re-submit until a live replica accepts.
    /// Errors only when every node is dead.
    fn route(&self, frame: Vec<u8>) -> Result<()> {
        let (req, _) = RequestEnvelope::decode(&frame)?;
        let digest = self.digests[req.model];
        let mut bytes = frame;
        let mut tried: Vec<usize> = Vec::new();
        loop {
            let replicas = self.placement.lock().unwrap().assign(digest);
            if replicas.is_empty() {
                return Err(Error::Config("fleet: every node is dead".into()));
            }
            let pick = match pick_replica(&replicas, &tried, |n| self.nodes[n].load()) {
                Some(n) => n,
                None => {
                    // Every replica of this assignment was tried and
                    // found dead, so the next assignment (which skips
                    // dead nodes) can only contain fresh candidates.
                    tried.clear();
                    continue;
                }
            };
            let accepted = match &self.lane {
                Lane::Direct => {
                    let frame = Frame {
                        bytes: std::mem::take(&mut bytes),
                        submitted_ns: self.clock.now_ns(),
                    };
                    match self.nodes[pick].submit(frame) {
                        Ok(()) => true,
                        Err(rejected) => {
                            // The frame comes back typed; keep routing it.
                            bytes = rejected.into_inner().bytes;
                            false
                        }
                    }
                }
                // A socket send failure leaves `bytes` with the caller
                // by construction.  An ack lost to a timeout may mean
                // the node actually accepted the frame — the re-routed
                // duplicate is harmless, the collector dedups by id
                // and both copies carry identical outputs.
                Lane::Socket(clients) => clients[pick].send(&bytes).is_ok(),
            };
            if accepted {
                break;
            }
            // Detect → re-route: never this replica again for this
            // request, and the placement drops it for future ones.
            tried.push(pick);
            self.placement.lock().unwrap().fail(pick);
            self.shed.fetch_add(1, Ordering::Relaxed);
            obs::incr(CounterId::RequestsShed);
        }
        let routed = self.routed.fetch_add(1, Ordering::Relaxed) + 1;
        self.maybe_inject(routed);
        Ok(())
    }

    /// Kill any victim whose routed-count threshold has passed.  The
    /// placement is deliberately *not* updated here: the router must
    /// discover the death through the typed push rejection.
    fn maybe_inject(&self, routed: u64) {
        let mut pending = self.pending_failures.lock().unwrap();
        while let Some(&(threshold, victim)) = pending.first() {
            if routed < threshold {
                break;
            }
            pending.remove(0);
            self.nodes[victim].fail();
        }
    }
}

/// The injection plan: `ceil(fail_rate * (nodes-1))` victims (at least
/// one survivor always remains), chosen heaviest-owner-first from the
/// initial placement so killing them actually forces re-placement,
/// each at a seeded mid-stream routed-count threshold.
fn failure_plan(opts: &FleetOptions, digests: &[u64], initial: &Placement) -> Vec<(u64, usize)> {
    if opts.fail_rate <= 0.0 || opts.nodes < 2 {
        return Vec::new();
    }
    let max_victims = opts.nodes - 1;
    let k = ((opts.fail_rate * max_victims as f64).ceil() as usize).clamp(1, max_victims);
    let mut owned = vec![0usize; opts.nodes];
    for &d in digests {
        for n in initial.assign(d) {
            owned[n] += 1;
        }
    }
    let mut order: Vec<usize> = (0..opts.nodes).collect();
    order.sort_by(|&a, &b| owned[b].cmp(&owned[a]).then(a.cmp(&b)));
    let mut rng = Xoshiro256::seed_from_u64(opts.fail_seed);
    let total = opts.serve.total_requests() as f64;
    let mut plan: Vec<(u64, usize)> = order
        .into_iter()
        .take(k)
        .map(|victim| {
            // Mid-stream: enough traffic before the death to warm the
            // victim, enough after to exercise recovery.
            let at = (total * rng.uniform_in(0.35, 0.65)) as u64;
            (at.max(1), victim)
        })
        .collect();
    plan.sort_unstable();
    plan
}

/// Run one fleet simulation with every node serving through a clone of
/// `engine` (shared instance: per-node shard attribution is not
/// meaningful, so the ABFT rollup is taken from the engine directly
/// and the per-node `shard` fields are cleared).  For per-node
/// engines — and honest per-node shard telemetry — use
/// [`run_fleet_nodes`].
pub fn run_fleet(
    engine: &DynEngine,
    device: &DeviceParams,
    opts: &FleetOptions,
) -> Result<FleetReport> {
    let base = engine.shard_counts();
    let engines = vec![engine.clone(); opts.nodes.max(1)];
    let mut report = run_fleet_nodes(engines, device, opts)?;
    if let (Some(now), Some(base)) = (engine.shard_counts(), base) {
        report.shard = Some(ShardCounts {
            injected: now.injected.saturating_sub(base.injected),
            detected: now.detected.saturating_sub(base.detected),
            corrected: now.corrected.saturating_sub(base.corrected),
            uncorrectable: now.uncorrectable.saturating_sub(base.uncorrectable),
        });
        for nr in &mut report.nodes {
            nr.shard = None;
        }
    }
    Ok(report)
}

/// Run one fleet simulation with one engine per node (`engines[i]`
/// serves node `i`).
pub fn run_fleet_nodes(
    engines: Vec<DynEngine>,
    device: &DeviceParams,
    opts: &FleetOptions,
) -> Result<FleetReport> {
    opts.serve.validate()?;
    device.validate().map_err(Error::Config)?;
    if opts.nodes == 0 {
        return Err(Error::Config("fleet: nodes must be > 0".into()));
    }
    if engines.len() != opts.nodes {
        return Err(Error::Config(format!(
            "fleet: {} engines for {} nodes",
            engines.len(),
            opts.nodes
        )));
    }
    let specs = opts.serve.model_specs();
    let inputs = opts.serve.request_inputs();
    let digests: Vec<u64> = specs.iter().map(model_digest).collect();
    let initial = Placement::new(opts.nodes, opts.replication);
    let replication = initial.replication();
    let plan = failure_plan(opts, &digests, &initial);
    // One clock for the whole run: router submit stamps, node latency
    // math, and collector end-to-end latency all subtract readings of
    // this single instance (two `MonotonicClock`s have different
    // anchors, so cross-component subtraction needs a shared one).
    let clock: Arc<dyn Clock> = Arc::new(MonotonicClock::new());
    let nodes: Vec<Arc<Node>> = engines
        .into_iter()
        .enumerate()
        .map(|(i, e)| Arc::new(Node::new(i, e, &opts.serve).with_clock(Arc::clone(&clock))))
        .collect();
    let (tx, rx) = mpsc::channel::<Vec<u8>>();
    // The socket rig, when requested: every node behind a loopback
    // listener, per-node response uplinks into a hub that forwards to
    // the collector.  Rig threads are unscoped (they own `Arc`s) and
    // are joined after the serving scope ends.
    let mut rig: Option<(Vec<NodeServer>, ResponseHub, Vec<std::thread::JoinHandle<()>>)> = None;
    let mut uplink_senders: Vec<mpsc::Sender<Vec<u8>>> = Vec::new();
    let mut lane_clients: Vec<NodeClient> = Vec::new();
    if let Transport::Socket(sock) = &opts.transport {
        let hub = ResponseHub::spawn(opts.nodes, tx.clone())?;
        let mut servers = Vec::with_capacity(opts.nodes);
        let mut uplinks = Vec::with_capacity(opts.nodes);
        for node in &nodes {
            let server = NodeServer::spawn(Arc::clone(node), sock)?;
            let (utx, urx) = mpsc::channel::<Vec<u8>>();
            uplinks.push(spawn_uplink(hub.addr(), urx, sock));
            lane_clients.push(NodeClient::new(server.addr(), sock.clone()));
            uplink_senders.push(utx);
            servers.push(server);
        }
        rig = Some((servers, hub, uplinks));
    }
    // What each node's workers emit responses into: its uplink sender
    // over sockets, the collector channel directly in process.
    let node_senders: Vec<mpsc::Sender<Vec<u8>>> = if uplink_senders.is_empty() {
        nodes.iter().map(|_| tx.clone()).collect()
    } else {
        uplink_senders
    };
    let router = Router {
        nodes: &nodes,
        lane: if lane_clients.is_empty() {
            Lane::Direct
        } else {
            Lane::Socket(&lane_clients)
        },
        clock: Arc::clone(&clock),
        placement: Mutex::new(initial.clone()),
        digests: &digests,
        routed: AtomicU64::new(0),
        shed: AtomicU64::new(0),
        pending_failures: Mutex::new(plan),
    };
    let total = opts.serve.total_requests();
    let enqueued: Mutex<Vec<Option<u64>>> = Mutex::new(vec![None; total]);
    let engine_failure: Mutex<Option<Error>> = Mutex::new(None);
    let collected_slot: Mutex<Option<Result<Collected>>> = Mutex::new(None);
    let workers = opts.serve.workers.max(1);
    let wall = Stopwatch::start();

    std::thread::scope(|scope| {
        // Per-node scheduler worker pools.
        for node in &nodes {
            for _ in 0..workers {
                let tx = node_senders[node.id()].clone();
                let specs = &specs;
                let serve_opts = &opts.serve;
                let engine_failure = &engine_failure;
                let nodes = &nodes;
                scope.spawn(move || {
                    if let Err(e) = node.worker_loop(device, specs, serve_opts, &tx) {
                        let mut slot = engine_failure.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        drop(slot);
                        // Tear the whole fleet down so clients and
                        // sibling workers drain out.
                        for n in nodes.iter() {
                            n.fail();
                        }
                    }
                });
            }
        }
        // Collector ends when the last sender drops: the main handle
        // and per-node senders here, worker clones as workers exit,
        // hub forwarders as uplinks close (socket mode).
        drop(tx);
        drop(node_senders);

        // Response collector: decode every response frame, account
        // end-to-end latency and error by request id.
        {
            let enqueued = &enqueued;
            let wall = &wall;
            let collected_slot = &collected_slot;
            let collect_responses = opts.collect_responses;
            let clock = &clock;
            scope.spawn(move || {
                let run = || -> Result<Collected> {
                    let mut c = Collected {
                        count: 0,
                        latency: HistogramSnapshot::empty(),
                        err_by_id: vec![0.0; total],
                        err_cols: 0,
                        points: Vec::with_capacity(total),
                        responses: collect_responses.then(|| {
                            let mut v = Vec::with_capacity(total);
                            v.resize_with(total, || None);
                            v
                        }),
                    };
                    let mut seen = vec![false; total];
                    for frame in rx.iter() {
                        let (resp, _) = ResponseEnvelope::decode(&frame)?;
                        let idx = resp.id as usize;
                        if idx >= total || seen[idx] {
                            // A duplicate serve after a lost socket
                            // ack: both copies are bit-identical, the
                            // first one already counted.
                            continue;
                        }
                        seen[idx] = true;
                        c.count += 1;
                        if let Some(t0) = enqueued.lock().unwrap()[idx] {
                            c.latency.record(clock.now_ns().saturating_sub(t0));
                        }
                        c.err_by_id[idx] = resp.err_abs_sum;
                        c.err_cols += resp.err_cols;
                        c.points.push((wall.elapsed_secs(), c.count as f64));
                        if let Some(store) = c.responses.as_mut() {
                            store[idx] = Some((resp.id, resp.y));
                        }
                    }
                    Ok(c)
                };
                *collected_slot.lock().unwrap() = Some(run());
            });
        }

        // Simulated clients: encode, route through the fabric.
        let client_handles: Vec<_> = (0..opts.serve.clients)
            .map(|cl| {
                let router = &router;
                let inputs = &inputs;
                let enqueued = &enqueued;
                let serve_opts = &opts.serve;
                let clock = &clock;
                scope.spawn(move || {
                    for i in 0..serve_opts.requests_per_client {
                        let id = (cl * serve_opts.requests_per_client + i) as u64;
                        let env = RequestEnvelope {
                            model: id as usize % serve_opts.models,
                            id,
                            x: inputs.sample(id as usize),
                        };
                        let frame = env.encode().expect("request frames fit the u32 bound");
                        enqueued.lock().unwrap()[id as usize] = Some(clock.now_ns());
                        if router.route(frame).is_err() {
                            break; // fleet torn down mid-stream
                        }
                    }
                })
            })
            .collect();
        for h in client_handles {
            h.join().expect("fleet client panicked");
        }
        // Graceful end-of-run: close every intake, workers drain.
        for node in &nodes {
            node.shutdown();
        }
    });

    // Socket rig teardown: the scope joined every worker, so uplinks
    // have flushed and the hub has drained; stop the listeners and
    // join the rig's own threads before reporting.
    if let Some((servers, hub, uplinks)) = rig.take() {
        for s in servers {
            s.shutdown();
        }
        for u in uplinks {
            let _ = u.join();
        }
        hub.shutdown();
    }

    if let Some(e) = engine_failure.into_inner().unwrap() {
        return Err(e);
    }
    let wall_secs = wall.elapsed_secs();
    let collected = collected_slot
        .into_inner()
        .unwrap()
        .ok_or_else(|| Error::Config("fleet: collector never ran".into()))??;
    let node_reports: Vec<NodeReport> = nodes.iter().map(|n| n.report()).collect();

    let failed_nodes: Vec<usize> = node_reports
        .iter()
        .filter(|r| !r.alive)
        .map(|r| r.id)
        .collect();
    let recovered_models = digests
        .iter()
        .filter(|&&d| initial.assign(d).iter().any(|n| failed_nodes.contains(n)))
        .count() as u64;

    let lat = collected.latency;
    let requests = collected.count;
    let mean_rps = if wall_secs > 0.0 {
        requests as f64 / wall_secs
    } else {
        0.0
    };
    let (fitted_rps, _) = capacity_projection(&collected.points, mean_rps);
    let per_node_rps = fitted_rps / opts.nodes as f64;
    let target_rps = 1e8 / 86_400.0;
    let nodes_for_1e8_per_day = if per_node_rps > 0.0 && per_node_rps.is_finite() {
        (target_rps / per_node_rps).ceil() as u64
    } else {
        0
    };
    // Deterministic error rollup: sum per-request sums in id order.
    let err_sum: f64 = collected.err_by_id.iter().sum();
    let batches: usize = node_reports.iter().map(|r| r.batches).sum();
    let batched: f64 = node_reports
        .iter()
        .map(|r| r.mean_batch * r.batches as f64)
        .sum();
    let cache = node_reports.iter().fold(
        super::cache::CacheCounts::default(),
        |acc, r| super::cache::CacheCounts {
            hits: acc.hits + r.cache.hits,
            misses: acc.misses + r.cache.misses,
            evictions: acc.evictions + r.cache.evictions,
            entries: acc.entries + r.cache.entries,
        },
    );
    let programs: u64 = node_reports.iter().map(|r| r.programs).sum();
    let shard = node_reports
        .iter()
        .filter_map(|r| r.shard)
        .fold(None, |acc: Option<ShardCounts>, s| {
            let a = acc.unwrap_or_default();
            Some(ShardCounts {
                injected: a.injected + s.injected,
                detected: a.detected + s.detected,
                corrected: a.corrected + s.corrected,
                uncorrectable: a.uncorrectable + s.uncorrectable,
            })
        });
    let transport_bytes: u64 = node_reports
        .iter()
        .map(|r| r.bytes_in + r.bytes_out)
        .sum();
    let responses = collected
        .responses
        .map(|v| v.into_iter().flatten().collect::<Vec<_>>());

    Ok(FleetReport {
        aggregate: ServeReport {
            requests,
            // Node queues are deadline-free and blocking, so the fleet
            // has no admission sheds: every offered request is served.
            // [`FleetReport::shed`] counts *detours* — re-routed and
            // still served — a different taxonomy (DESIGN.md §18).
            offered: requests,
            shed: 0,
            batches,
            mean_batch: if batches > 0 { batched / batches as f64 } else { 0.0 },
            wall_secs,
            throughput: mean_rps,
            p50_ms: lat.percentile_ms(50.0),
            p95_ms: lat.percentile_ms(95.0),
            p99_ms: lat.percentile_ms(99.0),
            latency: lat,
            cache,
            programs,
            mean_abs_error: if collected.err_cols > 0 {
                err_sum / collected.err_cols as f64
            } else {
                f64::NAN
            },
            fitted_rps,
            nodes_for_1e8_per_day,
        },
        nodes: node_reports,
        replication,
        shed: router.shed.load(Ordering::Relaxed),
        failed_nodes,
        recovered_models,
        transport_bytes,
        shard,
        per_node_rps,
        responses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::vmm::NativeEngine;
    use std::time::Duration;

    fn tiny_fleet(nodes: usize, replication: usize, fail_rate: f64) -> FleetOptions {
        FleetOptions {
            serve: ServeOptions {
                clients: 3,
                requests_per_client: 10,
                models: 5,
                rows: 16,
                cols: 16,
                queue_capacity: 8,
                batch_max: 4,
                window: Duration::from_micros(100),
                workers: 1,
                cache: true,
                cache_capacity: 8,
                measure_error: true,
                ..ServeOptions::default()
            },
            nodes,
            replication,
            fail_rate,
            collect_responses: true,
            ..FleetOptions::default()
        }
    }

    #[test]
    fn placement_is_deterministic_and_respects_replication() {
        let p = Placement::new(5, 2);
        for digest in [0u64, 1, u64::MAX, 0xDEAD_BEEF] {
            let a = p.assign(digest);
            assert_eq!(a, p.assign(digest), "assignment is pure");
            assert_eq!(a.len(), 2);
            assert_ne!(a[0], a[1], "replicas are distinct nodes");
        }
        // Replication clamps to the fleet size.
        assert_eq!(Placement::new(2, 9).replication(), 2);
        assert_eq!(Placement::new(1, 1).assign(42), vec![0]);
    }

    #[test]
    fn dead_node_disappears_from_assignments() {
        let mut p = Placement::new(4, 2);
        p.fail(2);
        assert_eq!(p.live(), 3);
        for digest in 0..64u64 {
            assert!(!p.assign(digest).contains(&2));
        }
        // More deaths than replicas: the survivor set is returned.
        p.fail(0);
        p.fail(1);
        for digest in 0..8u64 {
            assert_eq!(p.assign(digest), vec![3]);
        }
    }

    #[test]
    fn fleet_serves_all_requests_across_nodes() {
        let engine = DynEngine::new(NativeEngine::default());
        let device = presets::epiram().params;
        let opts = tiny_fleet(3, 1, 0.0);
        let r = run_fleet(&engine, &device, &opts).unwrap();
        assert_eq!(r.aggregate.requests, 30);
        assert_eq!(r.shed, 0);
        assert!(r.failed_nodes.is_empty());
        assert_eq!(r.recovered_models, 0);
        assert_eq!(r.nodes.len(), 3);
        let by_node: usize = r.nodes.iter().map(|n| n.requests).sum();
        assert_eq!(by_node, 30, "every request served by exactly one node");
        assert!(r.transport_bytes > 0, "the wire was paid");
        assert!(r.aggregate.mean_abs_error.is_finite());
        let got = r.responses.unwrap();
        assert_eq!(got.len(), 30);
    }

    #[test]
    fn replicated_fleet_spreads_a_model_over_distinct_nodes() {
        let engine = DynEngine::new(NativeEngine::default());
        let device = presets::epiram().params;
        let opts = tiny_fleet(3, 2, 0.0);
        let r = run_fleet(&engine, &device, &opts).unwrap();
        assert_eq!(r.aggregate.requests, 30);
        assert_eq!(r.replication, 2);
        // With two replicas per model the fleet programs more arrays
        // than models, never more than models x replication.
        assert!(r.aggregate.programs as usize >= 5);
        assert!(r.aggregate.programs as usize <= 10);
    }

    #[test]
    fn engine_failure_fails_the_run_not_hangs() {
        let engine = DynEngine::new(NativeEngine::default());
        let device = presets::epiram().params;
        let mut opts = tiny_fleet(2, 1, 0.0);
        opts.serve.models = 0; // invalid shape
        assert!(run_fleet(&engine, &device, &opts).is_err());
    }

    #[test]
    fn pick_replica_prefers_least_loaded_and_breaks_ties_by_walk_order() {
        let loads = [5u64, 1, 3];
        let load = |n: usize| loads[n];
        assert_eq!(pick_replica(&[2, 0, 1], &[], load), Some(1));
        assert_eq!(pick_replica(&[2, 0, 1], &[1], load), Some(2));
        assert_eq!(pick_replica(&[2, 0, 1], &[1, 2], load), Some(0));
        assert_eq!(pick_replica(&[2, 0, 1], &[0, 1, 2], load), None);
        // Equal loads: the earliest ring-walk position always wins, so
        // the pick stays deterministic when nothing separates replicas.
        let flat = |_: usize| 7u64;
        assert_eq!(pick_replica(&[2, 0, 1], &[], flat), Some(2));
        assert_eq!(pick_replica(&[2, 0, 1], &[2], flat), Some(0));
    }

    #[test]
    fn reroute_skips_tried_replicas_with_two_simultaneous_victims() {
        let serve = tiny_fleet(3, 3, 0.0).serve;
        let clock: Arc<dyn Clock> = Arc::new(MonotonicClock::new());
        let nodes: Vec<Arc<Node>> = (0..3)
            .map(|i| {
                let engine = DynEngine::new(NativeEngine::default());
                Arc::new(Node::new(i, engine, &serve).with_clock(Arc::clone(&clock)))
            })
            .collect();
        let digests = vec![model_digest(&serve.model_specs()[0])];
        let placement = Placement::new(3, 3);
        let replicas = placement.assign(digests[0]);
        assert_eq!(replicas.len(), 3);
        // Two of the three replicas die at once — silently, so the
        // router must discover both through typed rejections and skip
        // each exactly once within the same request.
        nodes[replicas[0]].fail();
        nodes[replicas[1]].fail();
        let router = Router {
            nodes: &nodes,
            lane: Lane::Direct,
            clock: Arc::clone(&clock),
            placement: Mutex::new(placement),
            digests: &digests,
            routed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            pending_failures: Mutex::new(Vec::new()),
        };
        let env = RequestEnvelope { model: 0, id: 0, x: vec![0.0; serve.rows] };
        router.route(env.encode().unwrap()).unwrap();
        assert_eq!(router.shed.load(Ordering::Relaxed), 2, "one detour per victim");
        assert_eq!(nodes[replicas[2]].load(), 1, "the survivor holds the frame");
        assert!(!router.placement.lock().unwrap().is_alive(replicas[0]));
        assert!(!router.placement.lock().unwrap().is_alive(replicas[1]));
    }

    #[test]
    fn socket_fleet_matches_in_process_bit_for_bit() {
        let engine = DynEngine::new(NativeEngine::default());
        let device = presets::epiram().params;
        let mut opts = tiny_fleet(2, 1, 0.0);
        let base = run_fleet(&engine, &device, &opts).unwrap();
        opts.transport = Transport::Socket(SocketOptions {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Duration::from_secs(2),
            retries: 2,
        });
        let sock = run_fleet(&engine, &device, &opts).unwrap();
        assert_eq!(sock.aggregate.requests, 30);
        assert_eq!(sock.aggregate.shed, 0);
        assert!(sock.transport_bytes > 0);
        let a = base.responses.unwrap();
        let b = sock.responses.unwrap();
        assert_eq!(a.len(), b.len());
        for ((ia, ya), (ib, yb)) in a.iter().zip(b.iter()) {
            assert_eq!(ia, ib);
            assert_eq!(ya.len(), yb.len());
            for (u, v) in ya.iter().zip(yb) {
                assert_eq!(u.to_bits(), v.to_bits(), "request {ia}: outputs must match");
            }
        }
    }
}
