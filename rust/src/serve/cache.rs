//! [`ProgramCache`]: the programmed-crossbar cache behind the serving
//! subsystem.
//!
//! Keyed by `(weights digest, device digest, program seed, engine
//! config)` — everything that determines the programmed conductances
//! bit-for-bit — so repeated requests against the same deployed model
//! skip reprogramming entirely.  What is cached is the **program**
//! (the arrays), never a read result: reads are recomputed per request
//! against the cached conductances, which is what keeps any read-path
//! variation fresh per request (see DESIGN.md §14).  Parallelism knobs
//! are deliberately absent from the key: engine results are
//! bit-identical for any thread count, so differently-fanned clones of
//! one configuration share entries.
//!
//! The cache is a bounded LRU behind one mutex; programming itself
//! runs **outside** the lock, so a slow program never stalls hits on
//! other models.  Two workers racing on the same cold key may both
//! program (both count as misses); the first insert wins and both get
//! handles over identical arrays, so results are unaffected.

use std::collections::hash_map::Entry as MapEntry;
use std::collections::HashMap;
use std::sync::Mutex;

use crate::device::params::DeviceParams;
use crate::error::Result;
use crate::obs::{self, Counter, CounterId, GaugeId, Stage};
use crate::vmm::{ProgramSpec, ProgrammedVmm, VmmEngine};

/// FNV-1a over a stream of 64-bit words (64-bit offset basis and
/// prime, `0x100000001b3`).  Shared with the fleet router, whose
/// consistent-hash ring and model digests use the same stream hash.
pub(crate) fn fnv1a(words: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        // Fold the full word through in two halves so every bit of the
        // input reaches the accumulator.
        for part in [w as u32 as u64, w >> 32] {
            h = (h ^ part).wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Cache identity of one programmed model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Digest of `(rows, cols, w bits)`.
    pub weights: u64,
    /// Digest of the device parameter bits.
    pub device: u64,
    /// The spec's programming-noise seed label.
    pub program_seed: u64,
    /// Digest of [`VmmEngine::cache_config`].
    pub engine: u64,
}

impl CacheKey {
    /// Digest the `(engine, spec, device)` triple into a cache key.
    ///
    /// Device parameters are hashed at full `f64` precision — the
    /// programmed conductances are computed in `f64`, so sub-`f32`
    /// parameter differences must produce distinct keys.
    pub fn new<E: VmmEngine + ?Sized>(
        engine: &E,
        spec: &ProgramSpec,
        params: &DeviceParams,
    ) -> Self {
        let weights = fnv1a(
            [spec.rows as u64, spec.cols as u64]
                .into_iter()
                .chain(spec.w.iter().map(|v| v.to_bits() as u64)),
        );
        // Full f64 bits of every field: the programmed conductances
        // are computed in f64, so an f32-truncated digest would let
        // sub-f32 parameter differences collide on one key.
        let device = fnv1a(
            [
                params.states,
                params.memory_window,
                params.nu_ltp,
                params.nu_ltd,
                params.sigma_c2c,
                params.k_c2c,
                params.k_base,
                params.s_exp,
            ]
            .map(f64::to_bits),
        );
        let engine = fnv1a(engine.cache_config().bytes().map(u64::from));
        Self {
            weights,
            device,
            program_seed: spec.program_seed,
            engine,
        }
    }
}

struct CacheEntry {
    handle: ProgrammedVmm,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<CacheKey, CacheEntry>,
    tick: u64,
}

/// Consistent counter snapshot of a [`ProgramCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounts {
    /// Lookups that found a resident program.
    pub hits: u64,
    /// Lookups that had to program (racing workers may both miss).
    pub misses: u64,
    /// Entries displaced by the LRU bound.
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: u64,
}

impl CacheCounts {
    /// Hit fraction of all lookups (NaN with zero lookups).
    pub fn hit_rate(&self) -> f64 {
        self.hits as f64 / (self.hits + self.misses) as f64
    }
}

/// Bounded LRU cache of programmed models.
///
/// Per-instance counters are [`obs::Counter`]s (always active — the
/// serve reports depend on them); each event additionally mirrors into
/// the global registry when telemetry is enabled, so `meliso metrics`
/// and the per-cache reports quote the same ledger.
///
/// # Example
///
/// ```
/// use meliso::device::presets;
/// use meliso::serve::ProgramCache;
/// use meliso::vmm::{NativeEngine, ProgramSpec};
///
/// let cache = ProgramCache::new(4);
/// let engine = NativeEngine::sequential();
/// let params = presets::epiram().params;
/// let spec = ProgramSpec::from_seed(2, 2, vec![0.5; 4], 7);
///
/// // First lookup programs (a miss); the repeat is a hit, and both
/// // handles serve bit-identical reads.
/// let a = cache.get_or_program(&engine, &spec, &params).unwrap();
/// let b = cache.get_or_program(&engine, &spec, &params).unwrap();
/// assert_eq!(a.read(&[1.0, 1.0], 1).unwrap(), b.read(&[1.0, 1.0], 1).unwrap());
///
/// let counts = cache.counts();
/// assert_eq!((counts.hits, counts.misses, counts.entries), (1, 1, 1));
/// ```
pub struct ProgramCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
}

impl std::fmt::Debug for ProgramCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let c = self.counts();
        f.debug_struct("ProgramCache")
            .field("capacity", &self.capacity)
            .field("counts", &c)
            .finish()
    }
}

impl ProgramCache {
    /// Cache holding at most `capacity` programmed models (clamped to
    /// at least 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(1),
            inner: Mutex::new(CacheInner { map: HashMap::new(), tick: 0 }),
            hits: Counter::new(),
            misses: Counter::new(),
            evictions: Counter::new(),
        }
    }

    /// Maximum number of resident programmed models.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Look the program up, programming (outside the lock) on a miss.
    pub fn get_or_program<E: VmmEngine + ?Sized>(
        &self,
        engine: &E,
        spec: &ProgramSpec,
        params: &DeviceParams,
    ) -> Result<ProgrammedVmm> {
        let key = CacheKey::new(engine, spec, params);
        let lookup = obs::stage_start();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.last_used = tick;
                let handle = e.handle.clone();
                drop(inner);
                obs::stage_end(Stage::CacheLookup, lookup);
                self.hit();
                return Ok(handle);
            }
        }
        obs::stage_end(Stage::CacheLookup, lookup);
        self.miss();
        let fresh = obs::time_stage(Stage::Program, || engine.program(spec, params))?;
        obs::incr(CounterId::ProgramsExecuted);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let handle = match inner.map.entry(key) {
            MapEntry::Occupied(mut o) => {
                // A racing worker inserted first; its arrays are
                // bit-identical (same key), keep them.
                o.get_mut().last_used = tick;
                o.get().handle.clone()
            }
            MapEntry::Vacant(v) => {
                v.insert(CacheEntry { handle: fresh.clone(), last_used: tick });
                fresh
            }
        };
        while inner.map.len() > self.capacity {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("map over capacity is non-empty");
            inner.map.remove(&victim);
            self.evicted();
        }
        obs::gauge_set(GaugeId::CacheEntries, inner.map.len() as u64);
        Ok(handle)
    }

    /// Fused lookup for the serving hot path: on a hit, return the
    /// cached handle and `None` (the caller reads through the handle);
    /// on a miss, run the engine's fused
    /// [`VmmEngine::program_read`] **outside the lock** and return the
    /// first batch's outputs alongside the fresh handle — the cold
    /// model's first batch is programmed and answered in one pass.
    ///
    /// Counter semantics match [`ProgramCache::get_or_program`]
    /// exactly (one miss per cold lookup, racing workers may both
    /// miss).  If a racing worker's insert wins, its arrays are
    /// bit-identical (same key), so the `y` computed against the local
    /// program is still the served answer.
    pub fn get_or_program_read<E: VmmEngine + ?Sized>(
        &self,
        engine: &E,
        spec: &ProgramSpec,
        params: &DeviceParams,
        x: &[f32],
        batch: usize,
    ) -> Result<(ProgrammedVmm, Option<Vec<f32>>)> {
        let key = CacheKey::new(engine, spec, params);
        let lookup = obs::stage_start();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(&key) {
                e.last_used = tick;
                let handle = e.handle.clone();
                drop(inner);
                obs::stage_end(Stage::CacheLookup, lookup);
                self.hit();
                return Ok((handle, None));
            }
        }
        obs::stage_end(Stage::CacheLookup, lookup);
        self.miss();
        // The fused program+read is attributed wholly to Program: the
        // cold model's first batch rides along with programming.
        let (fresh, y) =
            obs::time_stage(Stage::Program, || engine.program_read(spec, params, x, batch))?;
        obs::incr(CounterId::ProgramsExecuted);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let handle = match inner.map.entry(key) {
            MapEntry::Occupied(mut o) => {
                o.get_mut().last_used = tick;
                o.get().handle.clone()
            }
            MapEntry::Vacant(v) => {
                v.insert(CacheEntry { handle: fresh.clone(), last_used: tick });
                fresh
            }
        };
        while inner.map.len() > self.capacity {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("map over capacity is non-empty");
            inner.map.remove(&victim);
            self.evicted();
        }
        obs::gauge_set(GaugeId::CacheEntries, inner.map.len() as u64);
        Ok((handle, Some(y)))
    }

    fn hit(&self) {
        self.hits.incr();
        obs::incr(CounterId::CacheHits);
    }

    fn miss(&self) {
        self.misses.incr();
        obs::incr(CounterId::CacheMisses);
    }

    fn evicted(&self) {
        self.evictions.incr();
        obs::incr(CounterId::CacheEvictions);
    }

    /// Consistent snapshot of the hit/miss/eviction/residency ledger.
    pub fn counts(&self) -> CacheCounts {
        let entries = self.inner.lock().unwrap().map.len() as u64;
        CacheCounts {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            entries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::util::rng::Xoshiro256;
    use crate::vmm::{NativeEngine, TiledEngine};

    fn spec(rows: usize, cols: usize, seed: u64) -> ProgramSpec {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xABCD);
        let mut w = vec![0.0f32; rows * cols];
        rng.fill_uniform_f32(&mut w, -1.0, 1.0);
        ProgramSpec::from_seed(rows, cols, w, seed)
    }

    #[test]
    fn hit_on_repeat_miss_on_change() {
        let cache = ProgramCache::new(8);
        let engine = NativeEngine::sequential();
        let params = presets::epiram().params;
        let a = spec(8, 8, 1);
        cache.get_or_program(&engine, &a, &params).unwrap();
        cache.get_or_program(&engine, &a, &params).unwrap();
        assert_eq!(cache.counts().hits, 1);
        assert_eq!(cache.counts().misses, 1);
        // Different weights, program seed, or device: all misses.
        cache.get_or_program(&engine, &spec(8, 8, 2), &params).unwrap();
        let reseeded = ProgramSpec::from_seed(8, 8, a.w.clone(), 99);
        cache.get_or_program(&engine, &reseeded, &params).unwrap();
        cache
            .get_or_program(&engine, &a, &presets::ag_si().params)
            .unwrap();
        // Sub-f32 device differences are distinct programs too: the
        // physics runs in f64.
        let mut tweaked = params;
        tweaked.k_base += 1e-9;
        cache.get_or_program(&engine, &a, &tweaked).unwrap();
        let c = cache.counts();
        assert_eq!(c.misses, 5);
        assert_eq!(c.hits, 1);
        assert_eq!(c.entries, 5);
    }

    #[test]
    fn engine_config_separates_entries_but_parallelism_does_not() {
        let cache = ProgramCache::new(8);
        let params = presets::epiram().params;
        let s = spec(8, 8, 3);
        cache
            .get_or_program(&NativeEngine::sequential(), &s, &params)
            .unwrap();
        // Same engine config, different fan-out: a hit.
        cache
            .get_or_program(&NativeEngine::default(), &s, &params)
            .unwrap();
        assert_eq!(cache.counts().hits, 1);
        // A different engine (or tile geometry) is a different program.
        cache
            .get_or_program(&TiledEngine::with_tile(4), &s, &params)
            .unwrap();
        cache
            .get_or_program(&TiledEngine::with_tile(8), &s, &params)
            .unwrap();
        let c = cache.counts();
        assert_eq!(c.misses, 3);
        assert_eq!(c.entries, 3);
    }

    #[test]
    fn lru_eviction_bounds_residency() {
        let cache = ProgramCache::new(2);
        let engine = NativeEngine::sequential();
        let params = presets::epiram().params;
        let (a, b, c) = (spec(4, 4, 10), spec(4, 4, 11), spec(4, 4, 12));
        cache.get_or_program(&engine, &a, &params).unwrap();
        cache.get_or_program(&engine, &b, &params).unwrap();
        // Touch a so b is the LRU victim when c arrives.
        cache.get_or_program(&engine, &a, &params).unwrap();
        cache.get_or_program(&engine, &c, &params).unwrap();
        let counts = cache.counts();
        assert_eq!(counts.entries, 2);
        assert_eq!(counts.evictions, 1);
        // a stayed resident, b was evicted.
        cache.get_or_program(&engine, &a, &params).unwrap();
        assert_eq!(cache.counts().hits, 2);
        cache.get_or_program(&engine, &b, &params).unwrap();
        assert_eq!(cache.counts().misses, 4);
        assert!((cache.counts().hit_rate() - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn fused_miss_answers_first_batch() {
        let cache = ProgramCache::new(4);
        let engine = NativeEngine::default();
        let params = presets::ag_si().params;
        let s = spec(16, 16, 31);
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut x = vec![0.0f32; 2 * 16];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let (h1, y1) = cache
            .get_or_program_read(&engine, &s, &params, &x, 2)
            .unwrap();
        let y1 = y1.expect("cold lookup answers the batch inline");
        assert_eq!(y1, h1.read(&x, 2).unwrap());
        let (h2, y2) = cache
            .get_or_program_read(&engine, &s, &params, &x, 2)
            .unwrap();
        assert!(y2.is_none(), "hit defers to the cached handle");
        assert_eq!(h2.read(&x, 2).unwrap(), y1);
        let c = cache.counts();
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn cached_handle_serves_identical_outputs() {
        let cache = ProgramCache::new(4);
        let engine = NativeEngine::default();
        let params = presets::ag_si().params;
        let s = spec(16, 16, 21);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut x = vec![0.0f32; 3 * 16];
        rng.fill_uniform_f32(&mut x, 0.0, 1.0);
        let first = cache.get_or_program(&engine, &s, &params).unwrap();
        let second = cache.get_or_program(&engine, &s, &params).unwrap();
        assert_eq!(
            first.read(&x, 3).unwrap(),
            second.read(&x, 3).unwrap()
        );
    }
}
