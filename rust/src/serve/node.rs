//! One crossbar node of the fleet fabric.
//!
//! A node owns everything a single serving process owned before the
//! fleet refactor: its [`ProgramCache`] (programmed-handle ownership
//! is strictly per node — a model re-placed onto another node
//! re-programs there), its [`BoundedQueue`] scheduler, its worker
//! pool, and its telemetry (per-node cache counters, submit-to-served
//! latency, and the engine's ABFT [`ShardCounts`] when the engine
//! shards).  Requests arrive as serialized
//! [`RequestEnvelope`](super::transport::RequestEnvelope) frames and
//! leave as serialized response frames — the node decodes and encodes
//! on every hop, paying the transport boundary honestly.
//!
//! The batch-serving core ([`serve_model_group`]) is the exact logic
//! `run_serve`'s worker loop used to carry inline; both the
//! single-process driver and the fleet nodes now call it, which is
//! what makes a 1-node fleet bit-identical to `run_serve` on the same
//! seeds.
//!
//! Node intake queues are deliberately deadline-free and blocking
//! (the [`BoundedQueue`] facade, not the admission core): the fleet's
//! zero-lost-requests contract turns a closed queue into a *detour*
//! (re-route and serve elsewhere), never a shed — the other half of
//! the shed-vs-detour taxonomy (DESIGN.md §18).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::device::params::DeviceParams;
use crate::error::Result;
use crate::obs::{self, Clock, CounterId, GaugeId, HistogramSnapshot, MonotonicClock, Stage};
use crate::vmm::{DynEngine, ProgramSpec, ShardCounts, VmmEngine};

use super::bench::ServeOptions;
use super::cache::{CacheCounts, ProgramCache};
use super::scheduler::{BoundedQueue, QueueClosed};
use super::transport::{Frame, RequestEnvelope, ResponseEnvelope};

/// Outcome of serving one model group of a coalesced batch.
pub(crate) struct GroupOutcome {
    /// Programming cycles executed outside the cache (0 or 1).
    pub fresh_programs: u64,
    /// Per-request `sum |y_hw - y_sw|` in group order (empty unless
    /// error is measured).
    pub err_per_req: Vec<f64>,
    /// Columns behind each `err_per_req` entry (0 unless measured).
    pub err_cols: usize,
    /// Flat `(n, cols)` served outputs, when the caller keeps them.
    pub y: Option<Vec<f32>>,
}

/// Serve one model group: resolve the program (cache hit, fused
/// program+read on a miss, or fresh), then read.  This is the shared
/// core of `run_serve` and the fleet nodes; the three paths preserve
/// the pre-fleet semantics exactly:
///
/// * measured — `forward` against the programmed handle, keeping the
///   exact software reference per request;
/// * cached hot path — fused program+read on a miss, plain read on a
///   hit;
/// * uncached — reprogram per group (the measurable baseline).
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_model_group(
    engine: &DynEngine,
    device: &DeviceParams,
    cache: Option<&ProgramCache>,
    spec: &ProgramSpec,
    x: &[f32],
    n: usize,
    measure_error: bool,
    keep_outputs: bool,
) -> Result<GroupOutcome> {
    let mut fresh_programs = 0u64;
    if measure_error {
        let handle = match cache {
            Some(c) => c.get_or_program(engine, spec, device)?,
            None => {
                fresh_programs += 1;
                let h = obs::time_stage(Stage::Program, || engine.program(spec, device))?;
                obs::incr(CounterId::ProgramsExecuted);
                h
            }
        };
        let out = obs::time_stage(Stage::Read, || handle.forward(x, n))?;
        let errs = out.errors();
        let cols = out.y_hw.len() / n.max(1);
        let err_per_req = (0..n)
            .map(|r| errs[r * cols..(r + 1) * cols].iter().map(|e| e.abs()).sum())
            .collect();
        Ok(GroupOutcome {
            fresh_programs,
            err_per_req,
            err_cols: cols,
            y: keep_outputs.then_some(out.y_hw),
        })
    } else {
        let y = match cache {
            Some(c) => {
                let (handle, fused) = c.get_or_program_read(engine, spec, device, x, n)?;
                match fused {
                    Some(y) => y,
                    None => obs::time_stage(Stage::Read, || handle.read(x, n))?,
                }
            }
            None => {
                fresh_programs += 1;
                // The uncached fused call is attributed wholly to
                // Program, matching the cache's miss accounting.
                let (_, y) =
                    obs::time_stage(Stage::Program, || engine.program_read(spec, device, x, n))?;
                obs::incr(CounterId::ProgramsExecuted);
                y
            }
        };
        Ok(GroupOutcome {
            fresh_programs,
            err_per_req: Vec::new(),
            err_cols: 0,
            y: keep_outputs.then_some(y),
        })
    }
}

/// Per-node mutable tallies.
struct NodeTallies {
    requests: usize,
    batches: usize,
    batched_requests: usize,
    fresh_programs: u64,
    latency: HistogramSnapshot,
    bytes_in: u64,
    bytes_out: u64,
}

/// Telemetry snapshot of one node after a run.
#[derive(Debug, Clone)]
pub struct NodeReport {
    /// The node's fleet index.
    pub id: usize,
    /// `false` once the node failed (injected or detected).
    pub alive: bool,
    /// Requests this node served to completion.
    pub requests: usize,
    /// Coalesced batches it processed.
    pub batches: usize,
    /// Mean realized batch size.
    pub mean_batch: f64,
    /// Programming cycles executed (cache misses, or one per batch
    /// group with the cache off) — re-programs after a re-placement
    /// land here on the surviving node.
    pub programs: u64,
    /// This node's program-cache counters.
    pub cache: CacheCounts,
    /// Submit-to-served latency percentiles (queue wait + service),
    /// milliseconds — quoted from [`NodeReport::latency`], the same
    /// bucket semantics every other report uses (DESIGN.md §17).
    pub p50_ms: f64,
    /// 95th-percentile submit-to-served latency, milliseconds.
    pub p95_ms: f64,
    /// 99th-percentile submit-to-served latency, milliseconds.
    pub p99_ms: f64,
    /// The full submit-to-served latency distribution (nanoseconds);
    /// the fleet rollup merges these per-node histograms.
    pub latency: HistogramSnapshot,
    /// ABFT checksum counters accumulated by this node's engine over
    /// the run; `None` for engines without shard correction.  Nodes
    /// sharing one engine clone share counters — per-node attribution
    /// needs per-node engine instances (the fleet-sweep builds them).
    pub shard: Option<ShardCounts>,
    /// Serialized request bytes decoded by this node.
    pub bytes_in: u64,
    /// Serialized response bytes it emitted.
    pub bytes_out: u64,
}

/// One fleet node: per-node cache, bounded queue, worker pool,
/// telemetry.
pub struct Node {
    id: usize,
    engine: DynEngine,
    cache: Option<ProgramCache>,
    queue: BoundedQueue<Frame>,
    alive: AtomicBool,
    tallies: Mutex<NodeTallies>,
    /// The node's time base: submit stamps, queue-wait, and
    /// submit-to-served latency all read this clock (shared with the
    /// intake queue), so one [`crate::obs::MockClock`] drives the whole
    /// latency path deterministically in tests.  A fleet run hands
    /// every node (and the router) one shared clock instance so stamps
    /// taken on different sides of a hop subtract meaningfully.
    clock: Arc<dyn Clock>,
    /// Frames popped from the queue and not yet served — together with
    /// the queue depth, the node's load signal
    /// ([`Node::load`], [`GaugeId::NodeInflight`]).
    inflight: AtomicU64,
    /// Engine shard counters at node construction; the report carries
    /// the delta accumulated during the run.
    shard_base: Option<ShardCounts>,
}

impl Node {
    /// A node serving through `engine`, shaped by the run options.
    pub fn new(id: usize, engine: DynEngine, opts: &ServeOptions) -> Self {
        let shard_base = engine.shard_counts();
        let clock: Arc<dyn Clock> = Arc::new(MonotonicClock::new());
        Self {
            id,
            cache: opts.cache.then(|| ProgramCache::new(opts.cache_capacity)),
            queue: BoundedQueue::new(opts.queue_capacity).with_clock(Arc::clone(&clock)),
            alive: AtomicBool::new(true),
            tallies: Mutex::new(NodeTallies {
                requests: 0,
                batches: 0,
                batched_requests: 0,
                fresh_programs: 0,
                latency: HistogramSnapshot::empty(),
                bytes_in: 0,
                bytes_out: 0,
            }),
            clock,
            inflight: AtomicU64::new(0),
            shard_base,
            engine,
        }
    }

    /// Replace the node's clock (construction-time only; the fleet run
    /// shares one clock across router and nodes, tests inject a
    /// [`crate::obs::MockClock`]).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        let queue = std::mem::replace(&mut self.queue, BoundedQueue::new(1));
        self.queue = queue.with_clock(Arc::clone(&clock));
        self.clock = clock;
        self
    }

    /// The node's fleet index.
    pub fn id(&self) -> usize {
        self.id
    }

    /// A reading of the node's clock, in nanoseconds — submitters
    /// stamp [`Frame::submitted_ns`] with this so the node's latency
    /// math subtracts readings of one clock.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// The node's instantaneous load: queued frames plus popped-but-
    /// unserved frames.  This is the signal the router's load-aware
    /// placement compares across live replicas; in a real deployment
    /// it would ride a heartbeat, here the router reads it directly.
    pub fn load(&self) -> u64 {
        self.queue.len() as u64 + self.inflight.load(Ordering::Relaxed)
    }

    /// Has the node not been failed?
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Submit one serialized request frame.  A dead (or shut-down)
    /// node rejects with the typed [`QueueClosed`] carrying the frame
    /// back, which is exactly what the router's detect-and-re-route
    /// path recovers.
    pub fn submit(&self, frame: Frame) -> std::result::Result<(), QueueClosed<Frame>> {
        self.queue.push(frame)
    }

    /// Kill the node: stop accepting, let workers drain what was
    /// already accepted (close-and-drain), and report not-alive.
    pub fn fail(&self) {
        self.alive.store(false, Ordering::SeqCst);
        self.queue.close();
    }

    /// Graceful end-of-run: stop accepting, drain, stay "alive" in the
    /// report.
    pub fn shutdown(&self) {
        self.queue.close();
    }

    /// One scheduler worker: coalesce frames from the node queue,
    /// decode, serve by model group through this node's cache, encode
    /// and emit response frames.  Returns when the queue is closed and
    /// drained; an engine error propagates to the caller (which fails
    /// the fleet run, mirroring `run_serve`).
    pub fn worker_loop(
        &self,
        device: &DeviceParams,
        specs: &[ProgramSpec],
        opts: &ServeOptions,
        responses: &mpsc::Sender<Vec<u8>>,
    ) -> Result<()> {
        loop {
            let batch = self.queue.pop_batch(opts.batch_max, opts.window);
            if batch.is_empty() {
                return Ok(()); // closed and drained
            }
            // Popped frames count toward load until served (or failed).
            self.inflight.fetch_add(batch.len() as u64, Ordering::Relaxed);
            obs::gauge_set(GaugeId::NodeInflight, self.inflight.load(Ordering::Relaxed));
            let served = self.serve_frames(&batch, device, specs, opts, responses);
            self.inflight.fetch_sub(batch.len() as u64, Ordering::Relaxed);
            obs::gauge_set(GaugeId::NodeInflight, self.inflight.load(Ordering::Relaxed));
            served?;
        }
    }

    fn serve_frames(
        &self,
        batch: &[Frame],
        device: &DeviceParams,
        specs: &[ProgramSpec],
        opts: &ServeOptions,
        responses: &mpsc::Sender<Vec<u8>>,
    ) -> Result<()> {
        // Queue wait ends here: a worker has the coalesced frames.
        if obs::enabled() {
            let picked_up = self.clock.now_ns();
            for frame in batch {
                obs::record_ns(Stage::QueueWait, picked_up.saturating_sub(frame.submitted_ns));
            }
        }
        // Transport boundary: every frame decodes from bytes.
        let mut bytes_in = 0u64;
        let mut reqs = Vec::with_capacity(batch.len());
        for frame in batch {
            bytes_in += frame.bytes.len() as u64;
            let (req, _) = RequestEnvelope::decode(&frame.bytes)?;
            reqs.push(req);
        }
        // Group by model, preserving arrival order within groups.
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            match groups.iter_mut().find(|(m, _)| *m == req.model) {
                Some((_, g)) => g.push(i),
                None => groups.push((req.model, vec![i])),
            }
        }
        let mut fresh_programs = 0u64;
        let mut bytes_out = 0u64;
        for (model, members) in &groups {
            let spec = &specs[*model];
            let n = members.len();
            let mut x = Vec::with_capacity(n * opts.rows);
            for &i in members {
                x.extend_from_slice(&reqs[i].x);
            }
            let outcome = serve_model_group(
                &self.engine,
                device,
                self.cache.as_ref(),
                spec,
                &x,
                n,
                opts.measure_error,
                true,
            )?;
            fresh_programs += outcome.fresh_programs;
            let y = outcome.y.expect("fleet nodes keep outputs");
            let cols = y.len() / n.max(1);
            for (slot, &i) in members.iter().enumerate() {
                let resp = ResponseEnvelope {
                    id: reqs[i].id,
                    model: *model,
                    node: self.id,
                    y: y[slot * cols..(slot + 1) * cols].to_vec(),
                    err_abs_sum: outcome.err_per_req.get(slot).copied().unwrap_or(0.0),
                    err_cols: outcome.err_cols,
                };
                let frame = resp.encode()?;
                bytes_out += frame.len() as u64;
                // A dropped receiver means the run is tearing down;
                // nothing useful remains for this worker to do.
                let _ = responses.send(frame);
            }
        }
        let done = self.clock.now_ns();
        obs::add(CounterId::RequestsServed, batch.len() as u64);
        obs::incr(CounterId::BatchesServed);
        let mut t = self.tallies.lock().unwrap();
        for frame in batch {
            t.latency.record(done.saturating_sub(frame.submitted_ns));
        }
        t.requests += batch.len();
        t.batches += 1;
        t.batched_requests += batch.len();
        t.fresh_programs += fresh_programs;
        t.bytes_in += bytes_in;
        t.bytes_out += bytes_out;
        Ok(())
    }

    /// This node's cache counters (zeroed when the cache is off).
    pub fn cache_counts(&self) -> CacheCounts {
        self.cache.as_ref().map(|c| c.counts()).unwrap_or_default()
    }

    /// Telemetry snapshot after the run.
    pub fn report(&self) -> NodeReport {
        let t = self.tallies.lock().unwrap();
        let lat = t.latency.clone();
        let cache = self.cache_counts();
        let shard = match (self.engine.shard_counts(), self.shard_base) {
            (Some(now), Some(base)) => Some(ShardCounts {
                injected: now.injected.saturating_sub(base.injected),
                detected: now.detected.saturating_sub(base.detected),
                corrected: now.corrected.saturating_sub(base.corrected),
                uncorrectable: now.uncorrectable.saturating_sub(base.uncorrectable),
            }),
            _ => None,
        };
        NodeReport {
            id: self.id,
            alive: self.is_alive(),
            requests: t.requests,
            batches: t.batches,
            mean_batch: if t.batches > 0 {
                t.batched_requests as f64 / t.batches as f64
            } else {
                0.0
            },
            programs: if self.cache.is_some() {
                cache.misses
            } else {
                t.fresh_programs
            },
            cache,
            p50_ms: lat.percentile_ms(50.0),
            p95_ms: lat.percentile_ms(95.0),
            p99_ms: lat.percentile_ms(99.0),
            latency: lat,
            shard,
            bytes_in: t.bytes_in,
            bytes_out: t.bytes_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::vmm::NativeEngine;
    use std::time::Duration;

    fn opts() -> ServeOptions {
        ServeOptions {
            clients: 1,
            requests_per_client: 6,
            models: 2,
            rows: 16,
            cols: 16,
            queue_capacity: 8,
            batch_max: 4,
            window: Duration::from_micros(0),
            workers: 1,
            cache: true,
            cache_capacity: 4,
            measure_error: true,
            ..ServeOptions::default()
        }
    }

    #[test]
    fn node_serves_submitted_frames_and_reports() {
        let opts = opts();
        let engine = DynEngine::new(NativeEngine::default());
        let device = presets::epiram().params;
        let specs = opts.model_specs();
        let inputs = opts.request_inputs();
        let node = Node::new(0, engine, &opts);
        let (tx, rx) = mpsc::channel();
        for id in 0..6u64 {
            let env = super::super::transport::RequestEnvelope {
                model: id as usize % 2,
                id,
                x: inputs.sample(id as usize),
            };
            node.submit(Frame { bytes: env.encode().unwrap(), submitted_ns: node.now_ns() })
                .unwrap();
        }
        node.shutdown();
        node.worker_loop(&device, &specs, &opts, &tx).unwrap();
        drop(tx);
        let mut got: Vec<u64> = rx
            .iter()
            .map(|b| ResponseEnvelope::decode(&b).unwrap().0.id)
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<_>>());
        let r = node.report();
        assert!(r.alive);
        assert_eq!(r.requests, 6);
        assert_eq!(r.cache.misses, 2, "two models, one worker");
        assert_eq!(r.programs, 2);
        assert!(r.bytes_in > 0 && r.bytes_out > 0);
        assert!(r.shard.is_none(), "native engine has no shard counters");
    }

    #[test]
    fn dead_node_rejects_with_recoverable_frame() {
        let opts = opts();
        let engine = DynEngine::new(NativeEngine::default());
        let node = Node::new(3, engine, &opts);
        node.fail();
        assert!(!node.is_alive());
        let frame = Frame { bytes: vec![1, 2, 3], submitted_ns: node.now_ns() };
        let back = node.submit(frame).expect_err("dead node must reject");
        assert_eq!(back.into_inner().bytes, vec![1, 2, 3]);
    }

    #[test]
    fn mock_clock_makes_node_latency_exact() {
        let opts = opts();
        let engine = DynEngine::new(NativeEngine::default());
        let device = presets::epiram().params;
        let specs = opts.model_specs();
        let inputs = opts.request_inputs();
        let mock = Arc::new(crate::obs::MockClock::new());
        let node =
            Node::new(0, engine, &opts).with_clock(Arc::clone(&mock) as Arc<dyn Clock>);
        let (tx, rx) = mpsc::channel();
        for id in 0..6u64 {
            let env = super::super::transport::RequestEnvelope {
                model: id as usize % 2,
                id,
                x: inputs.sample(id as usize),
            };
            node.submit(Frame { bytes: env.encode().unwrap(), submitted_ns: node.now_ns() })
                .unwrap();
        }
        // The mock clock ticks once between submit and serve; nothing
        // else moves it, so every request's latency is exactly 2^20 ns.
        mock.advance(1 << 20);
        node.shutdown();
        node.worker_loop(&device, &specs, &opts, &tx).unwrap();
        drop(tx);
        assert_eq!(rx.iter().count(), 6);
        let r = node.report();
        assert_eq!(r.latency.count, 6);
        assert_eq!(r.latency.sum, 6 << 20);
    }

    #[test]
    fn load_counts_queued_frames() {
        let opts = opts();
        let engine = DynEngine::new(NativeEngine::default());
        let node = Node::new(0, engine, &opts);
        assert_eq!(node.load(), 0);
        for _ in 0..3 {
            node.submit(Frame { bytes: vec![0], submitted_ns: node.now_ns() }).unwrap();
        }
        assert_eq!(node.load(), 3, "queued frames are load");
    }
}
