//! Request serving: concurrent VMM inference over programmed-crossbar
//! caching and batched scheduling — single-process, or as a node/router
//! fleet fabric over a serialized transport boundary.
//!
//! MELISO's batch engines characterize error populations; a deployed
//! RRAM fabric *serves traffic* — weights are programmed once and read
//! millions of times (the serving-oriented case of arXiv:2508.13298).
//! This subsystem is that deployment layer, built on the
//! program-once/read-many engine contract ([`crate::vmm::program`]):
//!
//! ```text
//! clients ──> AdmissionQueue (lanes, deadlines, ──> scheduler workers
//!             backpressure or load shedding)       │  coalesce ≤ batch_max
//!                                                  │  within the window
//!                                                  ▼
//!                                     ProgramCache ──miss──> VmmEngine::program
//!                                               │hit
//!                                               ▼
//!                                     ProgrammedVmm::read  (fresh per request)
//! ```
//!
//! The fleet fabric stacks a router in front of N such nodes:
//!
//! ```text
//! clients ──encode──> router (consistent-hash placement, replication)
//!                       │ serialized frames (MELB envelopes)
//!          ┌────────────┼────────────┐
//!          ▼            ▼            ▼
//!        node 0       node 1  ...  node N-1     each: own cache +
//!          └────────────┴─────┬──────┘          queue + workers
//!                             ▼
//!                   response collector (rollup)
//! ```
//!
//! * [`cache::ProgramCache`] — bounded LRU of programmed models keyed
//!   by `(weights digest, device, program seed, engine config)`;
//!   caches **programs**, never reads.
//! * [`scheduler`] — the admission-controlled queue core: per-client
//!   fairness lanes over per-worker shards, SLO deadlines, typed
//!   [`Shed`] reasons, and window-based batch coalescing.  Full
//!   queues either throttle producers (backpressure, the default) or
//!   reject (load shedding); a closed queue rejects with a typed,
//!   recoverable error either way.
//! * [`transport`] — typed request/response envelopes serialized
//!   through the MELB codec; every node hop round-trips bytes.
//! * [`node`] — one fleet node: per-node cache, queue, worker pool,
//!   telemetry.
//! * [`router`] — consistent-hash placement with load-aware replica
//!   choice, replication, failure detection and recovery, fleet-wide
//!   rollup ([`router::run_fleet`], behind `meliso fleet-bench` and
//!   the `fleet-sweep` experiment).
//! * [`socket`] — the loopback TCP transport: length-prefixed frames,
//!   connect/read timeouts with bounded retry, typed
//!   [`socket::TransportError`]s the router recovers from exactly
//!   like queue rejections (`--transport socket`).
//! * [`bench::run_serve`] — the single-process simulation driver
//!   behind `meliso serve-bench` and the `serve-sweep` experiment,
//!   reporting p50/p95/p99 latency, throughput, realized batch sizes,
//!   cache counters, and (optionally) the exact-reference error.
//!
//! Architecture, cache-keying rationale, and backpressure semantics:
//! DESIGN.md §14; fleet fabric: DESIGN.md §16; admission control and
//! overload behavior: DESIGN.md §18.  Operator-facing knobs and
//! artifacts: OPERATIONS.md.

#![warn(missing_docs)]

pub mod bench;
pub mod cache;
pub mod node;
pub mod router;
pub mod scheduler;
pub mod socket;
pub mod transport;

pub use bench::{run_serve, ServeOptions, ServeReport};
pub use cache::{CacheCounts, CacheKey, ProgramCache};
pub use node::{Node, NodeReport};
pub use router::{
    model_digest, run_fleet, run_fleet_nodes, FleetOptions, FleetReport, Placement, Transport,
};
pub use scheduler::{AdmissionQueue, BoundedQueue, QueueClosed, Rejected, Request, Shed};
pub use socket::{NodeClient, NodeServer, ResponseHub, SocketOptions, TransportError};
pub use transport::{Frame, RequestEnvelope, ResponseEnvelope};
