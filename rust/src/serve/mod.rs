//! Request serving: concurrent VMM inference over programmed-crossbar
//! caching and batched scheduling.
//!
//! MELISO's batch engines characterize error populations; a deployed
//! RRAM fabric *serves traffic* — weights are programmed once and read
//! millions of times (the serving-oriented case of arXiv:2508.13298).
//! This subsystem is that deployment layer, built on the
//! program-once/read-many engine contract ([`crate::vmm::program`]):
//!
//! ```text
//! clients ──> BoundedQueue (backpressure) ──> scheduler workers
//!                                               │  coalesce ≤ batch_max
//!                                               │  within the window
//!                                               ▼
//!                                     ProgramCache ──miss──> VmmEngine::program
//!                                               │hit
//!                                               ▼
//!                                     ProgrammedVmm::read  (fresh per request)
//! ```
//!
//! * [`cache::ProgramCache`] — bounded LRU of programmed models keyed
//!   by `(weights digest, device, program seed, engine config)`;
//!   caches **programs**, never reads.
//! * [`scheduler`] — the bounded blocking queue (producers throttle
//!   when it fills) and window-based batch coalescing.
//! * [`bench::run_serve`] — the simulation driver behind
//!   `meliso serve-bench` and the `serve-sweep` experiment, reporting
//!   p50/p95/p99 latency, throughput, realized batch sizes, cache
//!   counters, and (optionally) the exact-reference error.
//!
//! Architecture, cache-keying rationale, and backpressure semantics:
//! DESIGN.md §14.

pub mod bench;
pub mod cache;
pub mod scheduler;

pub use bench::{run_serve, ServeOptions, ServeReport};
pub use cache::{CacheCounts, CacheKey, ProgramCache};
pub use scheduler::{percentile, BoundedQueue, Request};
