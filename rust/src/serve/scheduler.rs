//! Admission-controlled batching scheduler: sharded per-client lanes,
//! SLO deadlines, typed shedding, and the bounded blocking queue.
//!
//! `tokio` is not in the offline registry; the serving substrate is
//! therefore the same honest one the engines use — OS threads over
//! `Mutex`/`Condvar` state.  Two queue types share one core:
//!
//! * [`AdmissionQueue`] — the overload-hardened core (DESIGN.md §18).
//!   Requests enter per-client **lanes** grouped into per-worker
//!   **shards** (one small mutex each instead of one global one);
//!   consumers drain lanes round-robin so one hot client cannot
//!   starve the rest.  Admission is deadline-aware: work whose SLO
//!   deadline (read from a mockable [`Clock`]) has already passed is
//!   rejected at `push`, and work that expires while queued is
//!   dropped at [`AdmissionQueue::pop_batch`] — with a typed [`Shed`]
//!   reason either way, never silently queued forever.  With
//!   `shed_on_full`, a full queue rejects instead of blocking (load
//!   shedding); otherwise producers block (backpressure).
//! * [`BoundedQueue`] — the historical blocking facade: one shard,
//!   one lane, no deadlines, blocking `push`.  At this width the core
//!   degenerates to a strict FIFO, so the facade is bit-identical in
//!   pop order to the pre-admission scheduler (proptested), and the
//!   fleet fabric keeps its recoverable [`QueueClosed`] contract.
//!
//! Every shed increments the metrics registry (`admission_*`
//! counters) and a pop-side deadline drop records the request's
//! queued time into the `shed_wait` stage, so load shedding is
//! observable end-to-end through serve-bench, node, and router
//! rollups.
//!
//! **Close-and-drain contract** (both queues): an item accepted by
//! `push` before [`AdmissionQueue::close`] is either served by a
//! subsequent `pop_batch` or (if its deadline expires) counted as
//! shed — never silently dropped; a push that races `close` returns
//! the item to the caller inside the typed rejection.  The argument:
//! enqueue and the shard `closed` flag are updates under the same
//! shard mutex, the gate `closed` flag is set *after* every shard
//! flag, and a consumer only returns empty after observing the gate
//! flag and then re-scanning every shard — so any enqueue that beat
//! `close` happens-before that final scan and is found by it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{self, Clock, CounterId, GaugeId, MonotonicClock, Stage};

/// One single-vector VMM request from a simulated client.
#[derive(Debug, Clone)]
pub struct Request {
    /// Which deployed model (weight matrix) this request targets.
    pub model: usize,
    /// Global request id (client id x per-client sequence).
    pub id: u64,
    /// The input vector (`rows` entries).
    pub x: Vec<f32>,
    /// Enqueue timestamp as a queue-clock reading in nanoseconds
    /// ([`AdmissionQueue::now_ns`]) — latency is measured
    /// enqueue-to-decode against the same mockable [`Clock`] the
    /// deadline accounting uses, never a raw `Instant`.
    pub enqueued_ns: u64,
    /// Originating client — the admission queue's fairness lane id.
    pub client: usize,
    /// Absolute SLO deadline in queue-clock nanoseconds
    /// ([`AdmissionQueue::now_ns`] plus the SLO), or `None` for no
    /// deadline.
    pub deadline_ns: Option<u64>,
}

/// Typed rejection of a push against a closed queue.  The item is
/// handed back untouched so the caller can recover it — the fleet
/// router re-routes a rejected request to a surviving replica instead
/// of losing it (or blocking forever) on a dead node's queue.
#[derive(Debug)]
pub struct QueueClosed<T>(pub T);

impl<T> QueueClosed<T> {
    /// Recover the rejected item.
    pub fn into_inner(self) -> T {
        self.0
    }
}

impl<T> std::fmt::Display for QueueClosed<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "queue closed: push rejected")
    }
}

impl<T: std::fmt::Debug> std::error::Error for QueueClosed<T> {}

/// Why admission control refused or dropped a request (DESIGN.md §18
/// — the *shed* side of the shed-vs-detour taxonomy: a shed request
/// is never served; a fleet detour is re-routed and served
/// elsewhere).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shed {
    /// The queue is closed (shutdown, or a dead fleet node).  The
    /// item is returned to the caller for recovery or re-routing.
    Closed,
    /// The queue was at capacity and the policy sheds instead of
    /// blocking (`shed_on_full`).
    QueueFull,
    /// The request's SLO deadline had already passed at admission.
    AdmitExpired,
    /// The deadline expired while queued; the request was dropped at
    /// [`AdmissionQueue::pop_batch`] instead of being served late.
    DeadlineMissed,
}

impl Shed {
    /// Stable snake_case name (used in tables and summaries).
    pub fn name(&self) -> &'static str {
        match self {
            Shed::Closed => "closed",
            Shed::QueueFull => "queue_full",
            Shed::AdmitExpired => "admit_expired",
            Shed::DeadlineMissed => "deadline_missed",
        }
    }
}

/// A typed push rejection from [`AdmissionQueue::push`]: the unserved
/// item plus the [`Shed`] reason, so callers can count, recover, or
/// re-route — never lose — refused work.
#[derive(Debug)]
pub struct Rejected<T> {
    /// The item, handed back untouched.
    pub item: T,
    /// Why admission refused it.
    pub reason: Shed,
}

impl<T> Rejected<T> {
    /// Recover the rejected item.
    pub fn into_inner(self) -> T {
        self.item
    }
}

impl<T> std::fmt::Display for Rejected<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request shed: {}", self.reason.name())
    }
}

impl<T: std::fmt::Debug> std::error::Error for Rejected<T> {}

/// One queued entry: the item plus its admission timestamps.
struct Entry<T> {
    item: T,
    enqueued_ns: u64,
    deadline_ns: Option<u64>,
}

/// One client's FIFO lane within a shard.
struct Lane<T> {
    id: usize,
    items: VecDeque<Entry<T>>,
}

/// Mutable state of one shard: its lanes, the round-robin cursor,
/// the queued count, and the closed flag.
struct ShardState<T> {
    lanes: Vec<Lane<T>>,
    cursor: usize,
    len: usize,
    closed: bool,
}

struct Shard<T> {
    state: Mutex<ShardState<T>>,
    /// Parks producers blocked on a full shard (blocking mode only).
    not_full: Condvar,
}

/// The consumer slow path: a single park point bumped by every push.
struct Gate {
    epoch: u64,
    closed: bool,
}

/// The admission-controlled MPMC core: per-client lanes sharded per
/// worker, round-robin fairness, SLO deadlines, and typed shedding.
///
/// Lane `l` lives in shard `l % shards` for the queue's lifetime, so
/// a client's requests form one FIFO; consumers scan shards starting
/// from their home shard (`worker % shards`) and take one item per
/// lane in cursor order.  The fast path touches only one shard's
/// mutex; a consumer that finds every shard empty parks on a single
/// gate `Condvar` whose epoch every push bumps (the lock-light
/// layout: producers and consumers on different shards never contend,
/// and the gate critical section is two integer ops).
///
/// All lane/shard state is mutex-protected, so the memory-ordering
/// argument is the mutexes' acquire/release edges; the only atomics
/// are the depth gauge and the drop counter, which are telemetry
/// (`Relaxed`, exact only after joining — DESIGN.md §18).
pub struct AdmissionQueue<T> {
    shards: Vec<Shard<T>>,
    gate: Mutex<Gate>,
    gate_cv: Condvar,
    capacity: usize,
    per_shard: usize,
    shed_on_full: bool,
    clock: Arc<dyn Clock>,
    depth: AtomicUsize,
    dropped: AtomicU64,
}

impl<T> AdmissionQueue<T> {
    /// Queue holding at most ~`capacity` items over `shards` shards
    /// (both clamped to at least 1).  Capacity splits per shard as
    /// `ceil(capacity / shards)`, so the exact total is
    /// `per-shard x shards >= capacity`.  Blocking (backpressure)
    /// mode by default; see [`AdmissionQueue::with_shed_on_full`].
    pub fn new(capacity: usize, shards: usize) -> Self {
        let shards = shards.max(1);
        let capacity = capacity.max(1);
        Self {
            shards: (0..shards)
                .map(|_| Shard {
                    state: Mutex::new(ShardState {
                        lanes: Vec::new(),
                        cursor: 0,
                        len: 0,
                        closed: false,
                    }),
                    not_full: Condvar::new(),
                })
                .collect(),
            gate: Mutex::new(Gate { epoch: 0, closed: false }),
            gate_cv: Condvar::new(),
            capacity,
            per_shard: capacity.div_ceil(shards),
            shed_on_full: false,
            clock: Arc::new(MonotonicClock::new()),
            depth: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Shed-on-full admission: a push against a full shard returns
    /// [`Shed::QueueFull`] immediately instead of blocking.
    pub fn with_shed_on_full(mut self, shed: bool) -> Self {
        self.shed_on_full = shed;
        self
    }

    /// Replace the deadline clock (tests drive expiry with a
    /// [`crate::obs::MockClock`] instead of sleeping).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    /// Configured capacity (the construction-time request; the exact
    /// bound is `ceil(capacity / shards) x shards`).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of shards (one per worker by convention).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Items currently queued across all shards.
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Requests dropped at `pop_batch` because their deadline expired
    /// while queued (exact after consumers are joined).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Current queue-clock reading in nanoseconds — compute absolute
    /// deadlines against this (`now_ns() + slo_ns`).
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Admit one item into lane `lane` with an optional absolute
    /// deadline (queue-clock nanoseconds).  Blocks while the lane's
    /// shard is full unless `shed_on_full` is set.  Refusals are
    /// typed and lossless: the item comes back inside [`Rejected`]
    /// with the [`Shed`] reason ([`Shed::Closed`],
    /// [`Shed::QueueFull`], or [`Shed::AdmitExpired`] for a deadline
    /// that has already passed).
    pub fn push(
        &self,
        item: T,
        lane: usize,
        deadline_ns: Option<u64>,
    ) -> Result<(), Rejected<T>> {
        let now = self.clock.now_ns();
        if let Some(d) = deadline_ns {
            if now >= d {
                obs::incr(CounterId::AdmissionExpired);
                return Err(Rejected { item, reason: Shed::AdmitExpired });
            }
        }
        let shard = &self.shards[lane % self.shards.len()];
        let mut st = shard.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(Rejected { item, reason: Shed::Closed });
            }
            if st.len < self.per_shard {
                break;
            }
            if self.shed_on_full {
                obs::incr(CounterId::AdmissionRejected);
                return Err(Rejected { item, reason: Shed::QueueFull });
            }
            st = shard.not_full.wait(st).unwrap();
        }
        let entry = Entry { item, enqueued_ns: now, deadline_ns };
        match st.lanes.iter_mut().find(|l| l.id == lane) {
            Some(l) => l.items.push_back(entry),
            None => {
                let mut items = VecDeque::new();
                items.push_back(entry);
                st.lanes.push(Lane { id: lane, items });
            }
        }
        st.len += 1;
        drop(st);
        let depth = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        obs::gauge_set(GaugeId::QueueDepth, depth as u64);
        let mut g = self.gate.lock().unwrap();
        g.epoch = g.epoch.wrapping_add(1);
        drop(g);
        self.gate_cv.notify_one();
        Ok(())
    }

    /// Close the queue: producers are refused (blocked ones wake with
    /// their item returned), consumers drain what remains.  Shard
    /// flags are set before the gate flag, which is what makes the
    /// module-level close-and-drain argument hold.
    pub fn close(&self) {
        for shard in &self.shards {
            let mut st = shard.state.lock().unwrap();
            st.closed = true;
            shard.not_full.notify_all();
        }
        let mut g = self.gate.lock().unwrap();
        g.closed = true;
        drop(g);
        self.gate_cv.notify_all();
    }

    /// One round-robin sweep: scan every shard starting at `home`,
    /// taking one item per non-empty lane in cursor order until
    /// `batch` holds `max` items.  Entries whose deadline has passed
    /// are dropped here — counted, recorded into the `shed_wait`
    /// stage, never returned.
    fn take_round(&self, home: usize, max: usize, batch: &mut Vec<T>) {
        let nshards = self.shards.len();
        let now = self.clock.now_ns();
        for i in 0..nshards {
            if batch.len() >= max {
                break;
            }
            let shard = &self.shards[(home + i) % nshards];
            let mut st = shard.state.lock().unwrap();
            let mut removed = 0usize;
            let mut dropped = 0usize;
            while batch.len() < max && st.len > 0 {
                let nlanes = st.lanes.len();
                let mut cur = st.cursor % nlanes;
                while st.lanes[cur].items.is_empty() {
                    cur = (cur + 1) % nlanes;
                }
                let entry = st.lanes[cur].items.pop_front().expect("non-empty lane");
                st.cursor = (cur + 1) % nlanes;
                st.len -= 1;
                removed += 1;
                match entry.deadline_ns {
                    Some(d) if now >= d => {
                        dropped += 1;
                        obs::incr(CounterId::AdmissionDeadlineMissed);
                        obs::record_ns(
                            Stage::ShedWait,
                            now.saturating_sub(entry.enqueued_ns),
                        );
                    }
                    _ => batch.push(entry.item),
                }
            }
            drop(st);
            if removed > 0 {
                shard.not_full.notify_all();
                self.depth.fetch_sub(removed, Ordering::Relaxed);
            }
            if dropped > 0 {
                self.dropped.fetch_add(dropped as u64, Ordering::Relaxed);
            }
        }
        obs::gauge_set(GaugeId::QueueDepth, self.depth.load(Ordering::Relaxed) as u64);
    }

    /// Pop one coalesced batch of up to `max` items for `worker`:
    /// block for the first live item, then keep draining (home shard
    /// first, then the others) until the batch is full or `window`
    /// has elapsed since the first item was taken.  Expired entries
    /// are shed in place and never returned.  An empty return means
    /// the queue is closed and fully drained — the consumer's stop
    /// signal.
    pub fn pop_batch(&self, worker: usize, max: usize, window: Duration) -> Vec<T> {
        let max = max.max(1);
        let home = worker % self.shards.len();
        let mut batch = Vec::new();
        // Phase 1: block until at least one live item is taken, or
        // the queue is closed and a post-close scan finds nothing.
        loop {
            let seen = {
                let g = self.gate.lock().unwrap();
                if g.closed {
                    None
                } else {
                    Some(g.epoch)
                }
            };
            self.take_round(home, max, &mut batch);
            if !batch.is_empty() {
                break;
            }
            match seen {
                // Closed, and the scan after observing the flag found
                // nothing: drained (see the module-level argument).
                None => return batch,
                Some(seen) => {
                    let mut g = self.gate.lock().unwrap();
                    while g.epoch == seen && !g.closed {
                        g = self.gate_cv.wait(g).unwrap();
                    }
                }
            }
        }
        // Phase 2: coalesce. The span covers first-item-taken to
        // batch-returned — the window time spent growing the batch,
        // not the idle block waiting for work to exist.
        let coalesce = obs::stage_start();
        let deadline = Instant::now() + window;
        loop {
            if batch.len() >= max {
                break;
            }
            let (seen, closed) = {
                let g = self.gate.lock().unwrap();
                (g.epoch, g.closed)
            };
            self.take_round(home, max, &mut batch);
            if batch.len() >= max || closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let mut g = self.gate.lock().unwrap();
            loop {
                if g.epoch != seen || g.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _timeout) =
                    self.gate_cv.wait_timeout(g, deadline - now).unwrap();
                g = guard;
            }
        }
        obs::stage_end(Stage::BatchCoalesce, coalesce);
        batch
    }
}

/// Bounded MPMC queue: blocking producers (backpressure), batching
/// consumers, explicit close-and-drain shutdown.  A single-shard,
/// single-lane, no-deadline facade over [`AdmissionQueue`] — at this
/// width the core is a strict FIFO, bit-identical in pop order to
/// the pre-admission scheduler (proptested).
///
/// ```
/// use std::time::Duration;
/// use meliso::serve::BoundedQueue;
///
/// let q: BoundedQueue<u32> = BoundedQueue::new(4);
/// q.push(1).unwrap();
/// q.push(2).unwrap();
/// q.close();
/// // After close, pushes hand the item back (typed, recoverable)...
/// assert_eq!(q.push(3).unwrap_err().into_inner(), 3);
/// // ...and consumers drain what was accepted before the close.
/// assert_eq!(q.pop_batch(8, Duration::ZERO), vec![1, 2]);
/// assert!(q.pop_batch(8, Duration::ZERO).is_empty());
/// ```
pub struct BoundedQueue<T> {
    inner: AdmissionQueue<T>,
}

impl<T> BoundedQueue<T> {
    /// Queue holding at most `capacity` items (clamped to at least 1).
    pub fn new(capacity: usize) -> Self {
        Self { inner: AdmissionQueue::new(capacity, 1) }
    }

    /// Replace the queue's clock (shared with the owning node so
    /// queue-wait and latency telemetry read one mockable time base).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.inner = self.inner.with_clock(clock);
        self
    }

    /// A reading of the queue's clock, in nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.inner.now_ns()
    }

    /// Maximum queued items.
    pub fn capacity(&self) -> usize {
        self.inner.capacity()
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    /// Is the queue empty?
    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Enqueue, blocking while the queue is full.  A push against a
    /// closed queue — including a pusher that was already blocked on a
    /// full queue when [`BoundedQueue::close`] fired — returns the
    /// item inside a typed [`QueueClosed`] error instead of dropping
    /// it, so producers can stop on shutdown and the fleet router can
    /// re-route the very request that detected a dead node.
    pub fn push(&self, item: T) -> Result<(), QueueClosed<T>> {
        self.inner.push(item, 0, None).map_err(|r| QueueClosed(r.item))
    }

    /// Close the queue: producers stop, consumers drain what remains.
    /// Items pushed concurrently with the close are either drained by
    /// a later `pop_batch` or returned to their pusher via
    /// [`QueueClosed`] — never dropped (regression-tested under the
    /// `MELISO_THREADS` matrix).
    pub fn close(&self) {
        self.inner.close();
    }

    /// Pop one coalesced batch of up to `max` items: block for the
    /// first item, then drain until the batch is full or `window` has
    /// elapsed since the first item was taken.  An empty return means
    /// the queue is closed and fully drained — the consumer's stop
    /// signal.
    pub fn pop_batch(&self, max: usize, window: Duration) -> Vec<T> {
        self.inner.pop_batch(0, max, window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::MockClock;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_drain_on_close() {
        let q = BoundedQueue::new(16);
        for i in 0..5 {
            assert!(q.push(i).is_ok());
        }
        q.close();
        let rejected = q.push(99).expect_err("closed queue must refuse new items");
        assert_eq!(rejected.into_inner(), 99, "the rejected item comes back");
        let batch = q.pop_batch(3, Duration::from_millis(0));
        assert_eq!(batch, vec![0, 1, 2]);
        let batch = q.pop_batch(8, Duration::from_millis(0));
        assert_eq!(batch, vec![3, 4]);
        assert!(q.pop_batch(8, Duration::from_millis(0)).is_empty());
    }

    #[test]
    fn window_coalesces_trickling_producers() {
        let q = Arc::new(BoundedQueue::new(16));
        let producer = Arc::clone(&q);
        let handle = std::thread::spawn(move || {
            for i in 0..4 {
                producer.push(i).unwrap();
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        // A generous window sees more than the first item.
        let batch = q.pop_batch(4, Duration::from_millis(500));
        assert!(!batch.is_empty());
        assert_eq!(batch[0], 0);
        handle.join().unwrap();
        q.close();
        let rest = q.pop_batch(16, Duration::from_millis(0));
        assert_eq!(batch.len() + rest.len(), 4);
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let q = Arc::new(BoundedQueue::new(2));
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        let producer = Arc::clone(&q);
        let handle = std::thread::spawn(move || producer.push(3));
        // The producer is blocked on a full queue; popping frees it.
        std::thread::sleep(Duration::from_millis(5));
        let batch = q.pop_batch(1, Duration::from_millis(0));
        assert_eq!(batch, vec![1]);
        assert!(handle.join().unwrap().is_ok());
        q.close();
        let rest = q.pop_batch(8, Duration::from_millis(0));
        assert_eq!(rest, vec![2, 3]);
    }

    #[test]
    fn close_unblocks_stuck_pusher_with_recoverable_item() {
        // Regression for the node-failure path: a producer blocked on
        // a dead node's *full* queue must not wait forever — close()
        // wakes it and hands the request back for re-routing.
        let q = Arc::new(BoundedQueue::new(1));
        assert!(q.push(10).is_ok());
        let producer = Arc::clone(&q);
        let handle = std::thread::spawn(move || producer.push(11));
        std::thread::sleep(Duration::from_millis(5));
        q.close(); // the node dies with its queue full
        let rejected = handle
            .join()
            .unwrap()
            .expect_err("blocked pusher must be rejected, not stuck");
        assert_eq!(rejected.into_inner(), 11, "re-routable item recovered");
        // The close-and-drain contract still holds for what was queued.
        assert_eq!(q.pop_batch(8, Duration::from_millis(0)), vec![10]);
        assert!(q.pop_batch(8, Duration::from_millis(0)).is_empty());
    }

    #[test]
    fn pop_batch_records_coalesce_spans_when_enabled() {
        let _guard = crate::obs::test_lock();
        crate::obs::registry().reset();
        crate::obs::set_enabled(true);
        let q = BoundedQueue::new(8);
        for i in 0..3 {
            assert!(q.push(i).is_ok());
        }
        let batch = q.pop_batch(3, Duration::from_millis(0));
        crate::obs::set_enabled(false);
        assert_eq!(batch, vec![0, 1, 2]);
        let snap = crate::obs::registry().snapshot();
        crate::obs::registry().reset();
        // `>=`: while the gate is on, parallel tests traversing
        // instrumented paths may also record — exact accounting is
        // pinned in the isolated `integration_obs` binary.
        assert!(snap.stage(Stage::BatchCoalesce).count >= 1);
    }

    #[test]
    fn lanes_round_robin_within_a_shard() {
        // One hot lane (0) and one trickle lane (1): the hot lane
        // cannot starve the trickle — the pop interleaves them.
        let q: AdmissionQueue<u32> = AdmissionQueue::new(16, 1);
        for v in [10, 11, 12] {
            q.push(v, 0, None).unwrap();
        }
        q.push(20, 1, None).unwrap();
        let batch = q.pop_batch(0, 4, Duration::ZERO);
        assert_eq!(batch, vec![10, 20, 11, 12]);
    }

    #[test]
    fn expired_at_admission_is_rejected_with_reason() {
        let clock = Arc::new(MockClock::new());
        clock.set(1_000);
        let q: AdmissionQueue<u32> =
            AdmissionQueue::new(8, 1).with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        let err = q.push(7, 0, Some(500)).unwrap_err();
        assert_eq!(err.reason, Shed::AdmitExpired);
        assert_eq!(err.into_inner(), 7);
        // A live deadline admits fine.
        assert!(q.push(8, 0, Some(2_000)).is_ok());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn expired_in_queue_is_dropped_at_pop() {
        let clock = Arc::new(MockClock::new());
        let q: AdmissionQueue<u32> =
            AdmissionQueue::new(8, 1).with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        q.push(1, 0, Some(100)).unwrap(); // will expire
        q.push(2, 0, Some(10_000)).unwrap(); // stays live
        q.push(3, 0, None).unwrap(); // no deadline
        clock.advance(5_000);
        let batch = q.pop_batch(0, 8, Duration::ZERO);
        assert_eq!(batch, vec![2, 3], "expired entry shed, never served");
        assert_eq!(q.dropped(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn shed_on_full_rejects_instead_of_blocking() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(1, 1).with_shed_on_full(true);
        assert!(q.push(1, 0, None).is_ok());
        let err = q.push(2, 0, None).unwrap_err();
        assert_eq!(err.reason, Shed::QueueFull);
        assert_eq!(err.into_inner(), 2);
        // Draining reopens admission.
        assert_eq!(q.pop_batch(0, 8, Duration::ZERO), vec![1]);
        assert!(q.push(3, 0, None).is_ok());
    }

    #[test]
    fn sharded_pop_steals_from_other_shards() {
        // Lane 1 maps to shard 1; a worker homed on shard 0 must
        // still find the work instead of parking forever.
        let q: AdmissionQueue<u32> = AdmissionQueue::new(16, 2);
        q.push(42, 1, None).unwrap();
        let batch = q.pop_batch(0, 4, Duration::ZERO);
        assert_eq!(batch, vec![42]);
    }

    #[test]
    fn admission_sheds_increment_registry_counters() {
        let _guard = crate::obs::test_lock();
        crate::obs::registry().reset();
        crate::obs::set_enabled(true);
        let clock = Arc::new(MockClock::new());
        clock.set(1_000);
        let q: AdmissionQueue<u32> = AdmissionQueue::new(1, 1)
            .with_shed_on_full(true)
            .with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        let _ = q.push(1, 0, Some(10)); // admit-expired
        q.push(2, 0, Some(9_000)).unwrap();
        let _ = q.push(3, 0, None); // queue-full
        clock.advance(50_000);
        // The one admitted entry expired while queued: the pop sheds
        // it (deadline-missed) and returns empty once closed.
        q.close();
        assert!(q.pop_batch(0, 8, Duration::ZERO).is_empty());
        crate::obs::set_enabled(false);
        let snap = crate::obs::registry().snapshot();
        crate::obs::registry().reset();
        assert!(snap.counter(CounterId::AdmissionExpired) >= 1);
        assert!(snap.counter(CounterId::AdmissionRejected) >= 1);
        assert!(snap.counter(CounterId::AdmissionDeadlineMissed) >= 1);
        assert!(snap.stage(Stage::ShedWait).count >= 1);
    }
}
