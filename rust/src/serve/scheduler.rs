//! Batching scheduler primitives: a bounded blocking queue with
//! backpressure, the request type, and latency accounting.
//!
//! `tokio` is not in the offline registry; the serving substrate is
//! therefore the same honest one the engines use — OS threads over a
//! `Mutex`/`Condvar` queue.  Clients block in
//! [`BoundedQueue::push`] when the queue is full (bounded-queue
//! backpressure: a slow fabric throttles its producers instead of
//! buffering unboundedly), and scheduler workers coalesce queued
//! single-vector requests into engine-sized batches with
//! [`BoundedQueue::pop_batch`]: block for the first request, then keep
//! draining until the batch is full or the batching window has
//! elapsed.  A zero window degenerates to "whatever is already
//! queued"; a long window trades tail latency for larger batches —
//! the `serve-sweep` experiment measures exactly this trade.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::obs::{self, GaugeId, Stage};

/// One single-vector VMM request from a simulated client.
#[derive(Debug, Clone)]
pub struct Request {
    /// Which deployed model (weight matrix) this request targets.
    pub model: usize,
    /// Global request id (client id x per-client sequence).
    pub id: u64,
    /// The input vector (`rows` entries).
    pub x: Vec<f32>,
    /// Enqueue timestamp — latency is measured enqueue-to-decode.
    pub enqueued: Instant,
}

/// Typed rejection of a push against a closed queue.  The item is
/// handed back untouched so the caller can recover it — the fleet
/// router re-routes a rejected request to a surviving replica instead
/// of losing it (or blocking forever) on a dead node's queue.
#[derive(Debug)]
pub struct QueueClosed<T>(pub T);

impl<T> QueueClosed<T> {
    /// Recover the rejected item.
    pub fn into_inner(self) -> T {
        self.0
    }
}

impl<T> std::fmt::Display for QueueClosed<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "queue closed: push rejected")
    }
}

impl<T: std::fmt::Debug> std::error::Error for QueueClosed<T> {}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC queue: blocking producers (backpressure), batching
/// consumers, explicit close-and-drain shutdown.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// Queue holding at most `capacity` items (clamped to at least 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue, blocking while the queue is full.  A push against a
    /// closed queue — including a pusher that was already blocked on a
    /// full queue when [`BoundedQueue::close`] fired — returns the
    /// item inside a typed [`QueueClosed`] error instead of dropping
    /// it, so producers can stop on shutdown and the fleet router can
    /// re-route the very request that detected a dead node.
    pub fn push(&self, item: T) -> Result<(), QueueClosed<T>> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.closed {
                return Err(QueueClosed(item));
            }
            if st.items.len() < self.capacity {
                st.items.push_back(item);
                obs::gauge_set(GaugeId::QueueDepth, st.items.len() as u64);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Close the queue: producers stop, consumers drain what remains.
    pub fn close(&self) {
        let mut st = self.inner.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Pop one coalesced batch of up to `max` items: block for the
    /// first item, then drain until the batch is full or `window` has
    /// elapsed since the first item was taken.  An empty return means
    /// the queue is closed and fully drained — the consumer's stop
    /// signal.
    pub fn pop_batch(&self, max: usize, window: Duration) -> Vec<T> {
        let max = max.max(1);
        let mut st = self.inner.lock().unwrap();
        while st.items.is_empty() {
            if st.closed {
                return Vec::new();
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let mut batch = Vec::with_capacity(max.min(st.items.len()));
        // The coalesce span covers first-item-taken to batch-returned:
        // the window time spent growing the batch, not the idle block
        // waiting for work to exist.
        let coalesce = obs::stage_start();
        let deadline = Instant::now() + window;
        loop {
            while batch.len() < max {
                match st.items.pop_front() {
                    Some(item) => batch.push(item),
                    None => break,
                }
            }
            if !batch.is_empty() {
                self.not_full.notify_all();
            }
            if batch.len() >= max || st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _timeout) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if st.items.is_empty() && Instant::now() >= deadline {
                break;
            }
        }
        obs::gauge_set(GaugeId::QueueDepth, st.items.len() as u64);
        drop(st);
        obs::stage_end(Stage::BatchCoalesce, coalesce);
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_drain_on_close() {
        let q = BoundedQueue::new(16);
        for i in 0..5 {
            assert!(q.push(i).is_ok());
        }
        q.close();
        let rejected = q.push(99).expect_err("closed queue must refuse new items");
        assert_eq!(rejected.into_inner(), 99, "the rejected item comes back");
        let batch = q.pop_batch(3, Duration::from_millis(0));
        assert_eq!(batch, vec![0, 1, 2]);
        let batch = q.pop_batch(8, Duration::from_millis(0));
        assert_eq!(batch, vec![3, 4]);
        assert!(q.pop_batch(8, Duration::from_millis(0)).is_empty());
    }

    #[test]
    fn window_coalesces_trickling_producers() {
        let q = Arc::new(BoundedQueue::new(16));
        let producer = Arc::clone(&q);
        let handle = std::thread::spawn(move || {
            for i in 0..4 {
                producer.push(i);
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        // A generous window sees more than the first item.
        let batch = q.pop_batch(4, Duration::from_millis(500));
        assert!(!batch.is_empty());
        assert_eq!(batch[0], 0);
        handle.join().unwrap();
        q.close();
        let rest = q.pop_batch(16, Duration::from_millis(0));
        assert_eq!(batch.len() + rest.len(), 4);
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let q = Arc::new(BoundedQueue::new(2));
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        let producer = Arc::clone(&q);
        let handle = std::thread::spawn(move || producer.push(3));
        // The producer is blocked on a full queue; popping frees it.
        std::thread::sleep(Duration::from_millis(5));
        let batch = q.pop_batch(1, Duration::from_millis(0));
        assert_eq!(batch, vec![1]);
        assert!(handle.join().unwrap().is_ok());
        q.close();
        let rest = q.pop_batch(8, Duration::from_millis(0));
        assert_eq!(rest, vec![2, 3]);
    }

    #[test]
    fn close_unblocks_stuck_pusher_with_recoverable_item() {
        // Regression for the node-failure path: a producer blocked on
        // a dead node's *full* queue must not wait forever — close()
        // wakes it and hands the request back for re-routing.
        let q = Arc::new(BoundedQueue::new(1));
        assert!(q.push(10).is_ok());
        let producer = Arc::clone(&q);
        let handle = std::thread::spawn(move || producer.push(11));
        std::thread::sleep(Duration::from_millis(5));
        q.close(); // the node dies with its queue full
        let rejected = handle
            .join()
            .unwrap()
            .expect_err("blocked pusher must be rejected, not stuck");
        assert_eq!(rejected.into_inner(), 11, "re-routable item recovered");
        // The close-and-drain contract still holds for what was queued.
        assert_eq!(q.pop_batch(8, Duration::from_millis(0)), vec![10]);
        assert!(q.pop_batch(8, Duration::from_millis(0)).is_empty());
    }

    #[test]
    fn pop_batch_records_coalesce_spans_when_enabled() {
        let _guard = crate::obs::test_lock();
        crate::obs::registry().reset();
        crate::obs::set_enabled(true);
        let q = BoundedQueue::new(8);
        for i in 0..3 {
            assert!(q.push(i).is_ok());
        }
        let batch = q.pop_batch(3, Duration::from_millis(0));
        crate::obs::set_enabled(false);
        assert_eq!(batch, vec![0, 1, 2]);
        let snap = crate::obs::registry().snapshot();
        crate::obs::registry().reset();
        // `>=`: while the gate is on, parallel tests traversing
        // instrumented paths may also record — exact accounting is
        // pinned in the isolated `integration_obs` binary.
        assert!(snap.stage(Stage::BatchCoalesce).count >= 1);
    }
}
